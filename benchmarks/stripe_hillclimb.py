"""§Perf hillclimb 3 — the paper's technique itself: the Stripe autotiler
iterating a llama-shaped TP matmul shard toward the TPU roofline.

The op is the per-chip shard of llama3-8b's LOGITS matmul during
train_4k on the 16x16 mesh: M = 8,192-token microbatch slice, K = 4096,
N = 128256-vocab / 16 model shards = 8,016 — large enough on both output
dims that the tiling decides how often each operand streams from HBM.

Iterations (each = hypothesis -> change -> re-cost):
  0  flat (untiled) op               — infeasible: tile > VMEM cap
  1  naive square tiles 256^3        — feasible; HBM-bound
  2  autotile (roofline cost model)  — picks K-resident tiles, fewer fetches
  3  + MXU stencil pass              — aligns to 128x128x128, util -> 1.0
  4  + fusion (bias+silu epilogue)   — removes intermediate HBM round trip

Prints CSV: name,us_per_call,derived (us_per_call = modeled step time of
the dominant roofline term; derived = roofline fraction vs MXU peak).
"""
import sys

from repro.core.cost import evaluate_tiling
from repro.core.frontend import TileProgram, single_op_program
from repro.core.hwconfig import TPU_V5E
from repro.core.passes import get_pass
from repro.core.passes.autotile import choose_tiling

M, K, N = 8192, 4096, 8016
PEAK = TPU_V5E.peak_flops
PARAMS = {"cost": "roofline", "search": "pow2", "mem_cap_frac": 0.45, "count_untiled": True}


def _block():
    prog = single_op_program(
        "O[i, j] += X[i, c] * W[c, j]",
        {"X": ((M, K), "bfloat16"), "W": ((K, N), "bfloat16"), "O": ((M, N), "bfloat16")},
        out="O",
    )
    return prog, prog.entry.stmts[0]


def _default_emit(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


def _report(name, cost, extra="", emit=_default_emit):
    ideal = 2.0 * M * K * N / PEAK
    t = max(cost.t_mem, cost.t_compute)
    frac = ideal / t if t else 0.0
    emit(f"stripe_hillclimb/{name}", t * 1e6, f"{frac:.4f}{extra}")
    return t, frac


def main(emit=_default_emit) -> None:
    prog, blk = _block()

    # it0: whole-op "tile" (flat): footprint check
    c0 = evaluate_tiling(blk, {}, TPU_V5E, PARAMS)
    emit("stripe_hillclimb/flat_infeasible", 0.0, f"{int(c0.feasible)}  # {c0.why or 'fits'}")

    # it1: naive 256^3 square tiles
    c1 = evaluate_tiling(blk, {"i": 128, "c": 128, "j": 128}, TPU_V5E, PARAMS)
    _report("naive_128cube", c1, emit=emit)
    c1b = evaluate_tiling(blk, {"i": 512, "c": 512, "j": 512}, TPU_V5E, PARAMS)
    _report("naive_512cube", c1b, emit=emit)

    # it2: autotile
    tiles, c2 = choose_tiling(blk, TPU_V5E, PARAMS)
    _report("autotile", c2, extra=f"  # tiles={tiles}", emit=emit)

    # it3: stencil utilization — force MXU multiples
    snapped = {v: max(128, (t // 128) * 128) if t >= 128 else t for v, t in tiles.items()}
    c3 = evaluate_tiling(blk, snapped, TPU_V5E, {**PARAMS, "stencil": "mxu"})
    _report("stenciled", c3, extra=f"  # tiles={snapped}", emit=emit)

    # it4: fusion — bias+silu epilogue folded into the same tiles (the
    # intermediate T never goes to HBM): model it by dropping one full
    # output write + read (2 x M*N*2 bytes)
    saved = 2 * (M * N * 2)
    import dataclasses

    c4 = dataclasses.replace(c3, bytes_hbm=c3.bytes_hbm - saved,
                             t_mem=(c3.bytes_hbm - saved) / TPU_V5E.mem_units[0].bandwidth)
    _report("fused_epilogue", c4, emit=emit)

    # confirm the fused kernel actually builds through the real pipeline
    from repro.core.ir import Block
    from repro.core.passes import compile_program

    tp = TileProgram("ffn")
    tp.input("X", (M, K), "bfloat16")
    tp.input("W", (K, N), "bfloat16")
    tp.input("B", (N,), "float32")
    tp.temp("T", (M, N))
    tp.output("O", (M, N), "bfloat16")
    tp.op("T[i, j] += X[i, c] * W[c, j]")
    tp.op("O[i, j] = silu(T[i, j] + B[j])")
    out = compile_program(tp.build(), TPU_V5E)
    blocks = [s for s in out.entry.stmts if isinstance(s, Block)]
    # boundary may split a fused grid into interior/boundary pieces
    fused = len(blocks) >= 1 and all("fused" in b.tags for b in blocks)
    emit("stripe_hillclimb/pipeline_fuses_ffn", 0.0, int(fused))


if __name__ == "__main__":
    main()
