"""Benchmark harness — one function per paper table/figure plus framework
benches.  Prints ``name,us_per_call,derived`` CSV rows; ``--json OUT.json``
additionally writes the same records as machine-readable JSON
(``[{name, us_per_call, derived}, ...]``) so CI can archive perf
trajectories; ``--only fig4,fig5`` selects a subset.

Paper artifacts (Stripe has no numeric tables; its quantitative artifacts
are the Fig. 1 engineering-effort comparison and the Fig. 4/5 autotiling
example, both reproduced exactly):

* fig1: engineering-effort counts (kernel-library vs schedule-space vs
  Stripe) computed from this repo's actual artifact counts.
* fig4: the cache-line cost model on the 3x3 conv — cost of the Fig.5b
  tiling (54 lines / tile pair) and the autotiler's pick.
* fig5: the tiling rewrite — wall-clock of the XLA-compiled lowering
  before/after the pass pipeline (semantics asserted equal).

Framework benches: the api.stripe_jit compile cache (cold vs warm-memory vs
warm-disk), whole-program fusion groups, the liveness-based VMEM memory
planner (arena before/after reuse + the capacity-unlock speedup),
Stripe-matmul kernel vs plain einsum (CPU wall time), per-arch reduced
train step, flash-attention block-size choice, and the design-space
exploration smoke sweep.
"""
import argparse
import json
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import api, obs

RESULTS: List[Dict[str, Any]] = []
# --profile: compile with stripe_jit(..., profile=True) in the cache and
# serving benches (measured per-unit latencies + cost-model residual rows)
PROFILE = False


def emit(name: str, us_per_call: float, derived: Any) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
    RESULTS.append({"name": name, "us_per_call": round(float(us_per_call), 2),
                    "derived": derived})


def _timeit(fn, *args, n=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def bench_fig1_engineering_effort() -> None:
    """Fig 1: artifacts needed per approach for our 10 archs x 3 hw
    configs x K ops.  Stripe: ops + hw-configs; kernel library:
    ops x hw x versions."""

    n_ops = 4          # matmul, attention-score, gla-chunk, conv (frontend ops)
    n_hw = len(api.HW_REGISTRY)
    n_arch = len(api.configs.names())
    kernel_lib = n_ops * n_hw * n_arch          # per-op-per-hw-per-shape family
    schedule_space = n_ops * n_hw + n_ops       # spaces + algorithms
    stripe = n_ops + n_hw                       # algorithms + configs
    emit("fig1_artifacts_kernel_library", 0.0, kernel_lib)
    emit("fig1_artifacts_schedule_space", 0.0, schedule_space)
    emit("fig1_artifacts_stripe", 0.0, stripe)


def bench_fig4_autotile() -> None:

    prog = api.single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), "int8"), "F": ((3, 3, 8, 16), "int8"),
         "O": ((12, 16, 16), "int32")},
        out="O",
    )
    blk = prog.entry.stmts[0]
    hw = api.get_config("paper_fig4")
    params = dict(hw.passes[0][1])
    ref = api.evaluate_tiling(blk, {"x": 3, "y": 4}, hw, params)
    t0 = time.perf_counter()
    tiles, best = api.choose_tiling(blk, hw, params)
    dt = (time.perf_counter() - t0) * 1e6
    emit("fig4_cost_fig5b_tiling", 0.0, f"{ref.cost:.6f}")
    emit("fig4_lines_per_tilepair", 0.0, f"{ref.lines / ref.n_tiles:.0f}")
    emit("fig4_autotile_best_cost", dt, f"{best.cost:.6f}")
    emit("fig4_autotile_tiles", 0.0, f"\"{tiles}\"")


def bench_fig5_rewrite() -> None:
    """Tiling-rewrite overhead + executable equivalence (reduced shape)."""
    import copy


    prog = api.single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), "float32"), "F": ((3, 3, 8, 16), "float32"),
         "O": ((12, 16, 16), "float32")},
        out="O",
    )
    src = copy.deepcopy(prog)
    t0 = time.perf_counter()
    opt = api.compile_program(prog, api.get_config("cpu_test"))
    dt_compile = (time.perf_counter() - t0) * 1e6
    rng = np.random.RandomState(0)
    arrays = {"I": rng.randn(12, 16, 8).astype(np.float32),
              "F": rng.randn(3, 3, 8, 16).astype(np.float32)}
    a = api.execute_reference(src, arrays)["O"]
    b = api.execute_reference(opt, arrays)["O"]
    equal = bool(np.allclose(a, b, rtol=1e-4, atol=1e-5))
    fn = jax.jit(lambda d: api.lower_program_jnp(opt.source)(d)["O"])
    dt_exec = _timeit(fn, {k: jnp.asarray(v) for k, v in arrays.items()})
    emit("fig5_pass_pipeline_compile", dt_compile, 1)
    emit("fig5_semantics_preserved", 0.0, int(equal))
    emit("fig5_conv_exec_jnp", dt_exec, 1)


def bench_stripe_jit_cache() -> None:
    """Tentpole metric: warm vs cold ``api.stripe_jit`` compile of the Fig. 5
    conv — in-memory hit and cross-process (disk tiling replay) warm."""
    import tempfile


    def conv():
        return api.single_op_program(
            "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
            {"I": ((12, 16, 8), "float32"), "F": ((3, 3, 8, 16), "float32"),
             "O": ((12, 16, 16), "float32")},
            out="O",
        )

    with tempfile.TemporaryDirectory() as d:
        cache = api.CompilationCache(disk_dir=d)
        t0 = time.perf_counter()
        api.stripe_jit(conv(), api.get_config("cpu_test"), cache=cache)
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        api.stripe_jit(conv(), api.get_config("cpu_test"), cache=cache)
        warm_mem = time.perf_counter() - t0
        # fresh cache instance over the same disk dir = a new process
        cache2 = api.CompilationCache(disk_dir=d)
        t0 = time.perf_counter()
        cp = api.stripe_jit(conv(), api.get_config("cpu_test"), cache=cache2)
        warm_disk = time.perf_counter() - t0
        assert cp.record.disk_hit
    emit("stripe_jit_compile_cold", cold * 1e6, 1)
    emit("stripe_jit_compile_warm_mem", warm_mem * 1e6, f"{cold / warm_mem:.0f}x")
    emit("stripe_jit_compile_warm_disk", warm_disk * 1e6, f"{cold / warm_disk:.1f}x")

    if PROFILE:
        # profiled compile: first dispatch wall-times each lowered unit and
        # appends (predicted, measured) rows to the residual log
        with tempfile.TemporaryDirectory() as d:
            cache = api.CompilationCache(disk_dir=d)
            cp = api.stripe_jit(conv(), api.get_config("cpu_test"),
                                cache=cache, profile=True)
            rng = np.random.RandomState(0)
            cp({"I": rng.randn(12, 16, 8).astype(np.float32),
                "F": rng.randn(3, 3, 8, 16).astype(np.float32)})
            rows = obs.read_residuals(obs.residual_log_path(cache))
            emit("stripe_jit_profiled_units", 0.0,
                 len(cp.record.measured_latency_s))
            emit("stripe_jit_residual_rows", 0.0, len(rows))


def _fusion_chain_prog(act_ops):
    """matmul -> bias -> <act chain> -> matmul on wide activations with a
    skinny contraction dim, so intermediate-tensor traffic (what fusion
    eliminates) dominates compute."""

    m, k, n, n2 = 1024, 8, 4096, 8
    tp = api.TileProgram("fusion_bench")
    tp.input("A", (m, k))
    tp.input("B", (k, n))
    tp.input("b", (n,))
    tp.input("W2", (n, n2))
    tp.temp("T", (m, n))
    tp.temp("U0", (m, n))
    tp.output("O", (m, n2))
    tp.op("T[i, j] += A[i, c] * B[c, j]", name="mm1")
    tp.op("U0[i, j] = T[i, j] + b[j]", name="bias")
    cur = "U0"
    for idx, opname in enumerate(act_ops):
        nxt = f"U{idx + 1}"
        tp.temp(nxt, (m, n))
        tp.op(f"{nxt}[i, j] = {opname}({cur}[i, j])", name=f"act{idx}")
        cur = nxt
    tp.op(f"O[i, j2] += {cur}[i, j] * W2[j, j2]", name="mm2")
    return tp.build()


def _fusion_measure(prog):
    """(t_unfused, t_fused, n_unfused, n_fused): interleaved rounds with
    min-of-rounds per path — scheduling contention on shared hosts only
    ever *adds* time, so the per-path minimum is the noise-robust
    estimator (timeit's rationale), and interleaving spreads contention
    bursts across both paths."""
    import copy


    semantic = copy.deepcopy(prog)
    # CPU parameterization: prologue-preferred grouping ends each group's
    # executable with its contraction, keeping XLA:CPU's gemm on its
    # library path (the default epilogue grouping is the right shape for
    # the Pallas/TPU backend, which applies epilogues on the accumulator
    # tile).
    hw_cpu = api.get_config("tpu_v5e").with_params(**{"fuse.prefer": "prologue"})
    compiled = api.stripe_jit(copy.deepcopy(prog), hw_cpu, backend="jnp")
    unfused_fn = api.lower_program_jnp(semantic, groups=None, jit_scope="op")
    fused_fn = api.lower_program_jnp(semantic, groups=compiled.record.groups,
                                 jit_scope="group")
    rng = np.random.RandomState(0)
    arrays = {nm: jnp.asarray(rng.randn(*semantic.buffers[nm].shape), jnp.float32)
              for nm in semantic.inputs}
    a = unfused_fn(arrays)["O"]
    c = fused_fn(arrays)["O"]
    assert np.allclose(np.asarray(a), np.asarray(c), rtol=1e-4, atol=1e-4)
    for _ in range(2):
        _timeit(unfused_fn, arrays, n=2, warmup=1)
        _timeit(fused_fn, arrays, n=2, warmup=1)
    t_u, t_f = [], []
    for r in range(12):
        pair = [(_timeit(unfused_fn, arrays, n=3, warmup=0), t_u),
                (_timeit(fused_fn, arrays, n=3, warmup=0), t_f)]
        if r % 2:
            pair.reverse()
        for t, acc in pair:
            acc.append(t)
    return min(t_u), min(t_f), unfused_fn.n_kernels, fused_fn.n_kernels


def bench_fusion() -> None:
    """Whole-program fusion groups: fused (per-group lowering — one
    dispatch/kernel per fusion group, group-internal intermediates never
    materialized) vs unfused (per-op lowering — one dispatch per op,
    every intermediate round-tripping through memory).

    Two chains are measured.  The canonical matmul->bias->gelu->matmul
    chain reports kernels launched + µs/call, but its CPU wall time is
    dominated by XLA:CPU's erf codegen, whose vectorization is
    nondeterministic *per compilation* — the measured ratio swings with
    that coin flip, not with fusion.  The headline ``fusion_speedup``
    therefore comes from the transcendental-free relu² variant
    (nemotron-style squared-ReLU FFN, also exercised by this repo's
    configs), where the eliminated intermediate traffic is the whole
    story and the measurement is stable.  The Pallas lowering of the
    gelu chain is also compiled to record kernels-per-chain (4 ops -> 2
    fusion groups -> 2 pallas_calls)."""
    import copy


    gelu_prog = _fusion_chain_prog(["gelu"])
    semantic = copy.deepcopy(gelu_prog)
    t_u, t_f, n_u, n_f = _fusion_measure(gelu_prog)
    emit("fusion_unfused_per_op", t_u, n_u)
    emit("fusion_fused_groups", t_f, n_f)
    emit("fusion_gelu_speedup", 0.0, f"{t_u / t_f:.2f}x")

    relu2_prog = _fusion_chain_prog(["relu", "square"])
    t_u2, t_f2, n_u2, n_f2 = _fusion_measure(relu2_prog)
    emit("fusion_relu2_unfused_per_op", t_u2, n_u2)
    emit("fusion_relu2_fused_groups", t_f2, n_f2)
    emit("fusion_speedup", 0.0, f"{t_u2 / t_f2:.2f}x")

    pallas = api.stripe_jit(semantic, api.get_config("tpu_v5e"), backend="pallas", interpret=True)
    emit("fusion_pallas_kernels", 0.0,
         f"\"{n_u}->{pallas.record.n_kernels} "
         f"(backend={pallas.record.backend})\"")


def bench_memplan() -> None:
    """Liveness-based VMEM memory planner (core/memplan.py).

    Part 1 — arena before/after reuse: compile the explore ``default``
    corpus on stock tpu_v5e and report, per workload, the planner's peak
    arena vs the legacy bump model (no liveness, every view blanket-
    double-buffered) from the ``arena:``/``arena_bump:`` tags of the
    same compile.

    Part 2 — capacity unlock: a relu->square->abs chain feeding a
    skinny matmul with a reduction-resident weight, on a VMEM-tight
    config whose capacity sits *between* the legacy ``2x`` pressure and
    the planner's exact footprint.  The legacy model both rejects the
    chain inline (4 kernels, 3 materialized intermediates) and caps the
    matmul at a smaller tile; the planner fuses the whole chain into
    one kernel and picks a larger tile that the ``2x`` rule called
    infeasible.  Measured jnp latency (per-group lowering, min-of-
    rounds) quantifies the unlock."""
    import copy


    # ---- part 1: default-corpus arena peaks (planner vs bump) -------------
    # read from the schedule pass's report: the planner's per-block arena
    # vs the legacy bump model priced on the same views (NOT the score's
    # vmem_peak_bytes, which also floors at the autotile tile footprint)
    hw0 = api.get_config("tpu_v5e")
    workloads = api.get_workloads("default")
    lower = 0
    for w in workloads:
        _, rec = api.compile_cached(w.build(), hw0, use_disk=False)
        sched = [r for e in rec.pass_trace if e[0] == "schedule"
                 for r in e[2] if isinstance(r, dict)]
        planner_peak = max((r.get("arena_bytes", 0) for r in sched), default=0)
        bump_peak = max((r.get("arena_bump_bytes", 0) for r in sched), default=0)
        if 0 < planner_peak < bump_peak:
            lower += 1
        emit(f"memplan_arena/{w.name}", 0.0, f"\"{planner_peak}/{bump_peak}B\"")
    emit("memplan_arena_workloads_lower", 0.0, f"{lower}/{len(workloads)}")

    # ---- part 2: capacity unlock on a VMEM-tight config -------------------
    m, n, n2 = 1024, 4096, 32

    def chain():
        tp = api.TileProgram("memplan_chain")
        tp.input("X", (m, n))
        tp.input("W2", (n, n2))
        tp.temp("Y1", (m, n))
        tp.temp("Y2", (m, n))
        tp.temp("X2", (m, n))
        tp.output("O", (m, n2))
        tp.op("Y1[i, j] = relu(X[i, j])", name="pre1")
        tp.op("Y2[i, j] = square(Y1[i, j])", name="pre2")
        tp.op("X2[i, j] = abs(Y2[i, j])", name="pre3")
        tp.op("O[i, j2] += X2[i, j] * W2[j, j2]", name="mm")
        return tp.build()

    # cap = 0.29 * 16 MiB = 4.87 MB sits between the planner's exact
    # pressure of the chain-inline trial (~4.6 MB: W2 resident, one
    # accumulator slot) and the legacy 2x rule (~5.06 MB)
    hw = (api.get_config("tpu_v5e").with_mem("VMEM", size_bytes=16 * 2**20)
          .with_params(**{"autotile.mem_cap_frac": 0.29,
                          "fuse.mem_cap_frac": 0.29}))
    legacy = hw.with_params(**{"fuse.memplan": False, "autotile.memplan": False,
                               "schedule.memplan": False})
    recs = {}
    for name, cfg in (("planner", hw), ("legacy", legacy)):
        c = api.stripe_jit(chain(), cfg, backend="jnp", use_disk=False)
        recs[name] = c.record
    assert recs["planner"].n_kernels == 1 and recs["legacy"].n_kernels == 4

    def mm_rec(rec):
        for e in rec.pass_trace:
            if e[0] == "autotile":
                for r in e[2]:
                    if r["block"] == "mm":
                        return r
        raise AssertionError("no autotile record for mm")

    mm_p, mm_l = mm_rec(recs["planner"]), mm_rec(recs["legacy"])
    cap = int(16 * 2**20 * 0.29)
    # the planner's (larger) tile was infeasible under the legacy 2x rule
    assert mm_p["mem_bytes"] > mm_l["mem_bytes"]
    assert 2 * mm_p["mem_bytes"] > cap >= mm_p["plan_bytes"]
    lat_p = api.score_pass_trace(recs["planner"].pass_trace).latency_s
    lat_l = api.score_pass_trace(recs["legacy"].pass_trace).latency_s
    emit("memplan_tiles_planner", 0.0, f"\"{mm_p['tiles']} ({mm_p['mem_bytes']}B)\"")
    emit("memplan_tiles_legacy", 0.0, f"\"{mm_l['tiles']} ({mm_l['mem_bytes']}B)\"")
    emit("memplan_pred_speedup", 0.0, f"{lat_l / lat_p:.2f}x")

    prog = chain()
    rng = np.random.RandomState(0)
    arrays = {"X": jnp.asarray(rng.randn(m, n), jnp.float32),
              "W2": jnp.asarray(rng.randn(n, n2), jnp.float32)}
    fn_p = api.lower_program_jnp(copy.deepcopy(prog), groups=recs["planner"].groups,
                             jit_scope="group")
    fn_l = api.lower_program_jnp(copy.deepcopy(prog), groups=recs["legacy"].groups,
                             jit_scope="group")
    a = np.asarray(fn_p(arrays)["O"])
    b = np.asarray(fn_l(arrays)["O"])
    assert np.allclose(a, b, rtol=1e-4, atol=1e-4)
    for _ in range(2):
        _timeit(fn_l, arrays, n=2, warmup=1)
        _timeit(fn_p, arrays, n=2, warmup=1)
    t_l, t_p = [], []
    for r in range(8):
        pair = [(_timeit(fn_l, arrays, n=3, warmup=0), t_l),
                (_timeit(fn_p, arrays, n=3, warmup=0), t_p)]
        if r % 2:
            pair.reverse()
        for t, acc in pair:
            acc.append(t)
    emit("memplan_measured_legacy", min(t_l), recs["legacy"].n_kernels)
    emit("memplan_measured_planner", min(t_p), recs["planner"].n_kernels)
    emit("memplan_measured_speedup", 0.0, f"{min(t_l) / min(t_p):.2f}x")


def bench_conv() -> None:
    """Halo-aware conv lowering + per-block hybrid backend.

    * fig4/fig5: the paper's conv now compiles to real ``pallas_call``
      kernels (previously any halo view forced a whole-program jnp
      fallback); interpret-mode output is asserted equal to the reference
      interpreter — bit-exact for the int8 fig4 program.  This is the CI
      path that runs fig4/fig5 through pallas-interpret.
    * measured: the kernelized conv (pallas-interpret under jit) vs the
      jnp fallback path it replaces, at a serving-ish shape,
      min-of-interleaved-rounds.  Interpret mode emulates the kernel with
      jax ops on CPU, so the wall-clock ratio reflects only the
      structural savings (shifted-slice dots, masks confined to
      constraint-carrying pieces) — the VMEM-locality/MXU win needs
      hardware; the ratio is tracked to catch structural regressions.
    * hybrid: a mixed program (conv + channel-mix matmul + an
      unsupported max-aggregation head) keeps its conv and matmul
      kernels; only the max block falls back, per
      ``CompileRecord.block_backends``."""
    import copy


    hw = api.get_config("tpu_v5e")
    rng = np.random.RandomState(0)

    # ---- fig4/fig5 through pallas-interpret, asserted vs the reference ----
    for build, name in ((api.explore.workloads.fig4_conv, "fig4"),
                        (api.explore.workloads.fig5_conv_f32, "fig5")):
        prog = build()
        src = copy.deepcopy(prog)
        c = api.stripe_jit(prog, hw, backend="pallas", interpret=True, use_disk=False)
        assert c.record.backend == "pallas", c.record.fallback_reasons()
        assert c.record.n_kernels >= 1
        ins = {}
        for n in src.inputs:
            d = src.buffers[n]
            ins[n] = (rng.randint(-4, 5, d.shape).astype(np.int8)
                      if d.dtype == "int8"
                      else rng.randn(*d.shape).astype(np.float32))
        got = np.asarray(c(ins)["O"])
        want = api.execute_reference(src, ins)["O"]
        if want.dtype.kind in "iu":
            assert (got == want).all(), "int8 conv must be bit-exact"
        else:
            assert np.allclose(got, want, rtol=1e-4, atol=1e-4)
        emit(f"conv_{name}_pallas_kernels", 0.0,
             f"\"{c.record.n_kernels} (backend={c.record.backend})\"")

    # ---- measured: kernelized conv vs the jnp fallback it replaces --------
    x, y, ci, co = 96, 96, 16, 16
    prog = api.single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((x, y, ci), "float32"), "F": ((3, 3, ci, co), "float32"),
         "O": ((x, y, co), "float32")}, out="O", name="conv_serving")
    pal = api.stripe_jit(copy.deepcopy(prog), hw, backend="pallas",
                     interpret=True, use_disk=False)
    assert pal.record.backend == "pallas", pal.record.fallback_reasons()
    ref = api.stripe_jit(copy.deepcopy(prog), hw, backend="jnp", use_disk=False)
    ins = {"I": jnp.asarray(rng.randn(x, y, ci), jnp.float32),
           "F": jnp.asarray(rng.randn(3, 3, ci, co), jnp.float32)}
    pf = jax.jit(lambda a: pal(a)["O"])
    jf = jax.jit(lambda a: ref(a)["O"])
    assert np.allclose(np.asarray(pf(ins)), np.asarray(jf(ins)),
                       rtol=1e-3, atol=1e-3)
    for _ in range(2):
        _timeit(pf, ins, n=2, warmup=1)
        _timeit(jf, ins, n=2, warmup=1)
    t_p, t_j = [], []
    for r in range(10):
        pair = [(_timeit(pf, ins, n=3, warmup=0), t_p),
                (_timeit(jf, ins, n=3, warmup=0), t_j)]
        if r % 2:
            pair.reverse()
        for t, acc in pair:
            acc.append(t)
    emit("conv_exec_pallas_interpret", min(t_p), pal.record.n_kernels)
    emit("conv_exec_jnp_fallback", min(t_j), ref.record.n_kernels)
    emit("conv_measured_speedup", 0.0, f"{min(t_j) / min(t_p):.2f}x")

    # ---- hybrid: mixed program keeps its kernels --------------------------
    tp = api.TileProgram("conv_mixed")
    tp.input("I", (24, 24, 8))
    tp.input("F", (3, 3, 8, 16))
    tp.input("W", (16, 32))
    tp.temp("C", (24, 24, 16))
    tp.output("O", (24, 24, 32))
    tp.output("M", (24, 24))
    tp.op("C[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]", name="conv")
    tp.op("O[x, y, m] += C[x, y, k] * W[k, m]", name="proj")
    tp.op("M[x, y] max= C[x, y, k]", name="headmax")  # no Pallas path
    mixed = tp.build()
    src = copy.deepcopy(mixed)
    hy = api.stripe_jit(mixed, hw, backend="pallas", interpret=True, use_disk=False)
    rec = hy.record
    assert rec.backend == "pallas"
    assert rec.block_backends.get("headmax") == "jnp"
    assert all(b == "pallas" for u, b in rec.block_backends.items()
               if u != "headmax"), rec.block_backends
    ins = {"I": rng.randn(24, 24, 8).astype(np.float32),
           "F": rng.randn(3, 3, 8, 16).astype(np.float32),
           "W": rng.randn(16, 32).astype(np.float32)}
    got = hy(ins)
    want = api.execute_reference(src, ins)
    for out in ("O", "M"):
        assert np.allclose(np.asarray(got[out]), want[out], rtol=1e-3, atol=1e-3)
    n_jnp = sum(1 for b in rec.block_backends.values() if b == "jnp")
    emit("conv_hybrid_kernels", 0.0,
         f"\"pallas={rec.n_kernels - n_jnp} jnp={n_jnp} "
         f"({' '.join(f'{u}={b}' for u, b in sorted(rec.block_backends.items()))})\"")


def bench_stripe_matmul() -> None:

    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(256, 512), jnp.float32)
    w = jnp.asarray(rng.randn(512, 384), jnp.float32)
    t_ref = _timeit(jax.jit(lambda a, b: api.matmul_ref(a, b)), x, w)
    got = api.matmul(x, w, interpret=True)
    err = float(jnp.max(jnp.abs(got - api.matmul_ref(x, w))))
    emit("stripe_matmul_ref_xla", t_ref, 1)
    emit("stripe_matmul_pallas_interpret_maxerr", 0.0, f"{err:.2e}")


def bench_flash_attention_blocks() -> None:
    import tempfile


    # isolate from ~/.cache/stripe-repro so the "cold" rows are really cold
    with tempfile.TemporaryDirectory() as d:
        api.set_default_cache(api.CompilationCache(disk_dir=d))
        try:
            for s in (4096, 32768):
                t0 = time.perf_counter()
                bq, bk = api.choose_block_sizes(s, s, 128)
                dt = (time.perf_counter() - t0) * 1e6
                emit(f"flash_attn_autotile_s{s}", dt, f"\"bq={bq} bk={bk}\"")
                # second call: served from the compilation cache
                t0 = time.perf_counter()
                api.choose_block_sizes(s, s, 128)
                dt_warm = (time.perf_counter() - t0) * 1e6
                emit(f"flash_attn_autotile_s{s}_cached", dt_warm, f"\"bq={bq} bk={bk}\"")
        finally:
            api.set_default_cache(None)


def bench_arch_steps() -> None:

    for name in api.configs.names():
        cfg = api.configs.get(name).scaled()
        m = api.build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = api.make_batch(cfg, "train", 2, 32)
        fn = jax.jit(lambda p, b: m.loss(p, b, remat=False)[0])
        dt = _timeit(fn, params, batch, n=3, warmup=1)
        emit(f"arch_train_step_reduced/{name}", dt, 1)


def bench_hillclimb() -> None:
    # the narrative lives in the explore subsystem now (one search impl)

    api.roofline_hillclimb(emit=emit)


def bench_explore() -> None:
    """Design-space exploration smoke: a tiny cost-model-only grid (<= 8
    points) over the TPU sweep on the quick corpus — asserts the sweep
    completes, dedupes the stock point against the baseline, and that at
    least one swept config beats stock predicted latency somewhere."""
    import tempfile


    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        sweep = api.run_sweep(api.get_space("tpu-sweep"), "quick", budget=8,
                          strategy="grid", cache_dir=d, measure_top_k=0)
        dt = (time.perf_counter() - t0) * 1e6
    n_dedup = sum(1 for p in sweep.points if p.dedup_of is not None)
    n_dominating = sum(1 for v in api.dominating_baseline(sweep).values() if v)
    emit("explore_sweep_8pt", dt, f"\"points={len(sweep.points)} dedup={n_dedup}\"")
    emit("explore_pareto_size", 0.0, len(api.pareto_front(sweep.points)))
    emit("explore_workloads_dominating_baseline", 0.0, n_dominating)
    best = min(sweep.unique_points(), key=lambda p: p.latency_s)
    emit("explore_best_vs_baseline_predicted", 0.0,
         f"{sweep.baseline.latency_s / max(best.latency_s, 1e-30):.2f}x")


def bench_serving() -> None:
    """Serving smoke: ~100 synthetic requests (Poisson arrival stamps,
    mixed prompt lengths) through the continuous-batching engine vs the
    wave baseline at equal slot count, on a reduced dense LM.

    Two legs:

    * **parity** — uniform prompt length (the wave engine left-pads
      without masking, so mixed lengths are not numerically comparable),
      asserting *identical output tokens* from both engines;
    * **traffic** — 100 mixed-length requests queued per Poisson arrival
      order, reporting tokens/s, p50/p99 request completion latency and
      slot utilization for each engine.  Both engines are warmed on a
      throwaway request set first so the leg measures steady-state
      serving, not jit/stripe compile time (cold-boot cost is the
      compile-cache warm-start story, reported by ``compile_log()``).
    """
    cfg = api.configs.get("llama3-8b").scaled(
        d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=97, dtype="float32")
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots, max_len = 4, 64
    rng = np.random.RandomState(0)

    # ---- parity leg: identical tokens, wave vs continuous -----------------
    prompts = [rng.randint(1, cfg.vocab, size=6).astype(np.int32)
               for _ in range(2 * slots)]
    cont = api.ServingEngine(
        model, api.EngineConfig(slots=slots, max_len=max_len, page_size=8))
    wave = api.WaveEngine(model, slots, max_len)
    for i, p in enumerate(prompts):
        for eng in (cont, wave):
            eng.submit(api.Request(uid=i, prompt=p.copy(),
                                   sampling=api.SamplingParams(max_new_tokens=8)))
    got_c = {r.uid: r.out_tokens for r in cont.run(params, max_steps=10_000)}
    got_w = {r.uid: r.out_tokens for r in wave.run(params, max_steps=10_000)}
    assert got_c == got_w, "continuous engine diverged from the wave baseline"
    rec = cont.compile_records()["decode/mlp"]
    emit("serving_parity_requests", 0.0, len(got_c))
    emit("serving_decode_stripe_kernels", 0.0,
         f"\"mlp={rec.n_kernels} groups={len(rec.groups)}\"")

    # ---- traffic leg: 100 mixed-length requests, Poisson arrivals ---------
    n_req = 100

    def mixed_requests(seed=7, base_uid=0):
        r = np.random.RandomState(seed)
        arrivals = np.cumsum(r.exponential(1.0, size=n_req))  # Poisson process
        reqs = []
        for i in range(n_req):
            plen = int(r.choice([4, 8, 16, 24]))
            new = int(r.randint(4, 17))
            reqs.append((arrivals[i], api.Request(
                uid=base_uid + i,
                prompt=r.randint(1, cfg.vocab, size=plen).astype(np.int32),
                sampling=api.SamplingParams(max_new_tokens=new))))
        return reqs

    for label, eng in (
            ("continuous", api.ServingEngine(
                model, api.EngineConfig(slots=slots, max_len=max_len,
                                        page_size=8, profile=PROFILE))),
            ("wave", api.WaveEngine(model, slots, max_len))):
        # warm-up pass (compiles every bucket), then the timed run
        for _, r in mixed_requests(seed=1, base_uid=10_000):
            eng.submit(r)
        eng.run(params, max_steps=100_000)
        reqs = mixed_requests()
        t0 = time.perf_counter()
        for _, r in reqs:  # arrival order; all queued (closed-loop smoke)
            eng.submit(r)
        done = eng.run(params, max_steps=100_000)
        wall = time.perf_counter() - t0
        assert len(done) == n_req, f"{label}: {len(done)}/{n_req} finished"
        toks = sum(len(r.out_tokens) for r in done)
        lats = np.sort([r.finish_time - t0 for r in done])
        p50, p99 = lats[int(0.50 * n_req)], lats[int(0.99 * n_req)]
        util = (eng.metrics()["slot_utilization"]
                if isinstance(eng, api.ServingEngine) else float("nan"))
        emit(f"serving_{label}_tok_per_s", wall / max(toks, 1) * 1e6,
             f"\"{toks / wall:.0f} tok/s p50={p50:.2f}s p99={p99:.2f}s "
             f"util={util:.2f}\"")

    # ---- tracing-overhead leg: traced vs untraced continuous serving ------
    # same warm engine, interleaved alternating-order rounds; the estimate
    # is the ratio of per-mode MEDIAN throughput — per-round scheduling
    # noise on a 2-core CI host is comparable to the effect being measured,
    # so extreme rounds in either direction must not decide the assertion.
    # Runs are 3x the traffic leg so each wall averages scheduler jitter,
    # and noisy hosts get extra rounds before the <= 5% assertion fires.
    import statistics

    from repro.obs import trace as obs_trace

    n_ov = 3 * n_req
    rng_ov = np.random.RandomState(11)
    plens = rng_ov.choice([4, 8, 16, 24], size=n_ov)
    news = rng_ov.randint(4, 17, size=n_ov)

    def overhead_requests(base_uid):
        r = np.random.RandomState(11)
        return [api.Request(
            uid=base_uid + i,
            prompt=r.randint(1, cfg.vocab, size=int(plens[i])).astype(np.int32),
            sampling=api.SamplingParams(max_new_tokens=int(news[i])))
            for i in range(n_ov)]

    eng = api.ServingEngine(
        model, api.EngineConfig(slots=slots, max_len=max_len, page_size=8,
                                profile=PROFILE))
    for _, r in mixed_requests(seed=1, base_uid=20_000):
        eng.submit(r)
    eng.run(params, max_steps=100_000)
    saved = obs_trace.get_tracer()
    tput = {False: [], True: []}
    uid, rounds, ratio = 30_000, 0, 0.0
    try:
        while True:
            order = (False, True) if rounds % 2 == 0 else (True, False)
            for traced in order:
                obs_trace.set_tracer(obs_trace.Tracer(enabled=traced))
                reqs = overhead_requests(uid)
                uid += n_ov
                t0 = time.perf_counter()
                for r in reqs:
                    eng.submit(r)
                done = eng.run(params, max_steps=100_000)
                wall = time.perf_counter() - t0
                assert len(done) == n_ov
                toks = sum(len(r.out_tokens) for r in done)
                tput[traced].append(toks / wall)
            rounds += 1
            ratio = (statistics.median(tput[True])
                     / statistics.median(tput[False]))
            if rounds >= 10 or (rounds >= 3 and ratio >= 0.95):
                break
    finally:
        obs_trace.set_tracer(saved)
    emit("serving_tracing_overhead", 0.0, f"\"{ratio:.3f}x ({rounds} rounds)\"")
    assert ratio >= 0.95, (
        f"traced serving throughput is {ratio:.3f}x untraced (< 0.95x) "
        f"after {rounds} interleaved rounds")


def bench_chaos() -> None:
    """Chaos smoke: the ``serve_traffic.py --faults`` leg at reduced
    scale.  Replays one Poisson trace through a clean and a faulted
    continuous engine (fault classes: prefill-compile crash, torn
    disk-cache writes, device-step errors, prep-thread death, page-alloc
    failure) and publishes the injected-fault and recovery-event counts;
    the leg itself asserts exactly-once token-identical completion,
    fault->event matching, and >= 70% of fault-free throughput."""
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import serve_traffic as st

    args = argparse.Namespace(requests=250, slots=4, max_len=96,
                              page_size=16, rate=300.0)
    cfg = api.configs.get("llama3-8b").scaled(
        d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=97, dtype="float32")
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    results = st.bench_faults(args, cfg, model, params)
    injected = results["faulted"]["faults_injected"]
    recovered = results["faulted"]["recovery_events"]
    emit("chaos_fault_classes", 0.0, len(injected))
    emit("chaos_faults_injected", 0.0, sum(injected.values()))
    emit("chaos_recovery_events", 0.0, sum(recovered.values()))
    emit("chaos_requests_exactly_once", 0.0, args.requests)
    emit("chaos_retries", 0.0, results["faulted"]["retries"])
    emit("chaos_quarantine_clears", 0.0,
         results["faulted"]["quarantine_stats"]["clears"])
    emit("chaos_throughput_ratio", 0.0, results["faulted_throughput_ratio"])


def bench_autotune() -> None:
    """Measured-feedback autotuning (tune/ + measure-mode explore).

    Three legs over one temp cache+DB directory:

    * **measure** — a cold sweep with ``measure=3`` wall-times candidate
      tilings per default-corpus workload (pallas-interpret; tile sizes
      change the interpreted grid, so the signal is real) and records
      every measurement into the tuning DB.  The measured winner must be
      no worse than the analytic choice everywhere (the analytic tiling
      is always candidate 0, so the min over candidates can't lose) and
      strictly better somewhere.
    * **replay** — a fresh cache instance over the same directory (= a
      new process) compiles each workload with ``tune=db``: every record
      must carry ``decision_source == "tuned"``, the DB must not grow
      (replay never re-measures), and the tuned fig4 conv must stay
      bit-exact vs the reference interpreter.
    * **calibrate** — profiled jnp compiles append (predicted, measured)
      residual rows; a per-term calibration is fit from them, activated,
      persisted next to the DB, and a second profiled pass must shrink
      the |log gmean(measured/predicted)| bias.

    Artifacts ``tuning_db.json`` and ``calibration_report.json`` are
    copied into the CWD for CI upload.
    """
    import math
    import shutil
    import tempfile

    from repro.tune import clear_calibrations, save_calibrations

    rng = np.random.RandomState(0)
    space = api.get_space("tpu-sweep")
    hw = space.base_config()
    workloads = api.get_workloads("default")

    def rand_inputs(prog):
        ins = {}
        for nm in prog.inputs:
            d = prog.buffers[nm]
            ins[nm] = (rng.randint(-4, 5, d.shape).astype(np.int8)
                       if d.dtype == "int8"
                       else rng.randn(*d.shape).astype(np.float32))
        return ins

    with tempfile.TemporaryDirectory() as d:
        db = api.TuningDB(dir=d)

        # ---- leg 1: cold sweep + measure populates the DB -----------------
        t0 = time.perf_counter()
        sweep = api.run_sweep(space, "default", budget=4, strategy="grid",
                              cache_dir=d, measure=3, tune_db=db)
        dt_measure = (time.perf_counter() - t0) * 1e6
        ms = sweep.measurement
        assert ms is not None
        assert len(db) == len(workloads), (len(db), ms["workloads"])
        no_worse = better = 0
        for name, wl in sorted(ms["workloads"].items()):
            assert not wl.get("error"), f"{name}: {wl['error']}"
            speed = wl.get("speedup_vs_analytic") or 1.0
            if wl["best_s"] <= wl["analytic_s"] * 1.05:
                no_worse += 1
            if wl["improved"]:
                better += 1
            emit(f"autotune_measured/{name}", wl["best_s"] * 1e6,
                 f"\"{speed:.2f}x vs analytic "
                 f"({wl['n_candidates']} cands, {wl['n_rejected']} rejected)\"")
        assert no_worse >= 3 and better >= 1, (no_worse, better)
        emit("autotune_measure_sweep", dt_measure,
             f"\"db={len(db)} no_worse={no_worse}/{len(workloads)} "
             f"better={better}\"")

        # ---- leg 2: tuned replay from a fresh cache (= new process) -------
        n_before = len(db)
        cache2 = api.CompilationCache(disk_dir=d)
        t0 = time.perf_counter()
        tuned_recs = {}
        for w in workloads:
            c = api.stripe_jit(w.build(), hw, backend="pallas",
                               interpret=True, cache=cache2, tune=db)
            tuned_recs[w.name] = c
        dt_replay = (time.perf_counter() - t0) * 1e6 / len(workloads)
        n_tuned = sum(1 for c in tuned_recs.values()
                      if c.record.decision_source == "tuned")
        assert n_tuned == len(workloads), {
            n: c.record.decision_source for n, c in tuned_recs.items()}
        assert cache2.stats.tuned_hits == len(workloads)
        assert len(db) == n_before, "tuned replay must not re-measure"
        best = {n: wl["best_candidate"] for n, wl in ms["workloads"].items()}
        assert all(c.record.tuned["candidate_id"] == best[n]
                   for n, c in tuned_recs.items())
        # the replayed winner stays correct: int8 fig4 conv is bit-exact
        fig4 = next(w for w in workloads if w.name == "fig4_conv")
        src = fig4.build()
        ins = rand_inputs(src)
        got = np.asarray(tuned_recs["fig4_conv"](ins)["O"])
        assert (got == api.execute_reference(src, ins)["O"]).all()
        emit("autotune_tuned_replay_compile", dt_replay,
             f"\"{n_tuned}/{len(workloads)} tuned "
             f"(hits={cache2.stats.tuned_hits})\"")

        # DB round-trip: a fresh handle sees identical entries
        db2 = api.TuningDB(dir=d)
        assert len(db2) == n_before
        for w in workloads:
            rec = tuned_recs[w.name].record
            e = db2.lookup(rec.ir_fingerprint, rec.hw_fingerprint,
                           "pallas", True)
            assert e is not None and e.candidate_id == best[w.name]
        emit("autotune_db_roundtrip", 0.0, n_before)
        shutil.copyfile(db.path, "tuning_db.json")

        # ---- leg 3: online cost-model calibration -------------------------
        clear_calibrations()
        try:
            cache3 = api.CompilationCache(disk_dir=d)
            for _pass in range(2):
                for w in workloads:
                    prog = w.build()
                    c = api.stripe_jit(prog, hw, backend="jnp",
                                       profile=True, cache=cache3)
                    c(rand_inputs(prog))
                if _pass == 0:
                    rows = obs.read_residuals(obs.residual_log_path(cache3))
                    fit = api.fit_calibration(rows, hw.fingerprint(), "jnp")
                    assert fit is not None, "calibration fit needs term rows"
                    api.set_calibration(fit)
                    save_calibrations(d, cals=[fit])  # persist next to the DB
            rows = obs.read_residuals(obs.residual_log_path(cache3))

            def gmean(rs):
                logs = [math.log(r["measured_s"] / r["predicted_s"])
                        for r in rs if r.get("predicted_s")
                        and r.get("measured_s")]
                return math.exp(sum(logs) / len(logs)) if logs else None

            g_before = gmean([r for r in rows if not r.get("calibrated")])
            g_after = gmean([r for r in rows if r.get("calibrated")])
            assert g_before is not None and g_after is not None
            bias_b, bias_a = abs(math.log(g_before)), abs(math.log(g_after))
            assert bias_a <= bias_b, (g_before, g_after)
            with open("calibration_report.json", "w") as f:
                json.dump({"hw": hw.name, "backend": "jnp",
                           "rows": len(rows),
                           "gmean_before": g_before, "gmean_after": g_after,
                           "bias_before": bias_b, "bias_after": bias_a,
                           "calibration": fit.to_json()}, f, indent=2)
            emit("autotune_calibration_gmean_before", 0.0, f"{g_before:.3f}")
            emit("autotune_calibration_gmean_after", 0.0, f"{g_after:.3f}")
            emit("autotune_calibration_bias_shrink", 0.0,
                 f"{bias_b / max(bias_a, 1e-9):.1f}x")
        finally:
            clear_calibrations()


def bench_distributed() -> None:
    """Multi-device smoke on 8 emulated host devices (subprocess — this
    process's jax is already initialized single-device): the acceptance
    FFN through ``stripe_jit(mesh=8)`` vs the *replicated* placement on
    the same mesh (every device computes the full program — the
    no-partitioning baseline; emulated devices share the host cores, so
    the wall-clock ratio measures the partition's per-device work
    reduction, not physical parallelism), plus the predicted-vs-emitted
    collective loop on a reduction-split matmul (psum count and modelled
    bytes asserted in the child).  A plain single-device row is emitted
    as the absolute reference."""
    import os
    import subprocess
    import sys
    import textwrap

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    script = textwrap.dedent("""
        import json, time
        import jax
        import numpy as np
        if jax.device_count() < 8:
            print(json.dumps({"skip": f"only {jax.device_count()} device(s)"}))
            raise SystemExit(0)
        from repro import api
        from repro.core import mesh_lower
        from repro.core.cost import collective_seconds
        from repro.core.frontend import TileProgram
        from repro.core.hwconfig import CPU_TEST

        def ffn(m, k, n):
            tp = TileProgram("ffn")
            tp.input("X", (m, k), "float32")
            tp.input("W", (k, n), "float32")
            tp.input("B", (n,), "float32")
            tp.output("O", (m, n), "float32")
            tp.temp("T", (m, n), "float32")
            tp.temp("U", (m, n), "float32")
            tp.op("T[i, j] += X[i, c] * W[c, j]", name="mm")
            tp.op("U[i, j] = T[i, j] + B[j]", name="bias")
            tp.op("O[i, j] = gelu(U[i, j])", name="act")
            return tp.build()

        m, k, n = 2048, 512, 512
        rng = np.random.default_rng(0)
        arrays = {"X": rng.normal(size=(m, k)).astype("float32"),
                  "W": rng.normal(size=(k, n)).astype("float32"),
                  "B": rng.normal(size=(n,)).astype("float32")}
        single = api.jit(ffn(m, k, n), CPU_TEST, backend="jnp")
        sh = api.jit(ffn(m, k, n), CPU_TEST, backend="jnp", mesh=8)

        # replicated placement on the same mesh: every device runs the
        # full single-device program (in_specs/out_specs all P())
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        jmesh = Mesh(np.array(jax.devices()[:8]), ("x",))
        inner = api.jit(ffn(m, k, n), CPU_TEST, backend="jnp", jit=False)
        in_order = ["X", "W", "B"]
        rep_body = shard_map(
            lambda X, W, B: inner({"X": X, "W": W, "B": B})["O"],
            mesh=jmesh, in_specs=(P(), P(), P()), out_specs=P(),
            check_rep=False)
        rep_jit = jax.jit(rep_body)
        rep = lambda a: {"O": rep_jit(*[a[k] for k in in_order])}

        r0, s0, g0 = rep(arrays), sh(arrays), single(arrays)
        np.testing.assert_allclose(np.asarray(s0["O"]), np.asarray(g0["O"]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(r0["O"]), np.asarray(g0["O"]),
                                   rtol=1e-4, atol=1e-4)

        def best_us(fn, rounds=5):
            best = float("inf")
            for _ in range(rounds):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(arrays)["O"])
                best = min(best, time.perf_counter() - t0)
            return best * 1e6

        t_single, t_rep, t_sh = best_us(single), best_us(rep), best_us(sh)

        # predicted-vs-emitted collective loop: reduction-split matmul
        tp = TileProgram("kred")
        tp.input("X", (12, 4096), "float32")
        tp.input("W", (4096, 20), "float32")
        tp.output("O", (12, 20), "float32")
        tp.op("O[i, j] += X[i, c] * W[c, j]", name="mm")
        kr = api.jit(tp.build(), CPU_TEST, backend="jnp", mesh=8)
        karr = {"X": rng.normal(size=(12, 4096)).astype("float32"),
                "W": rng.normal(size=(4096, 20)).astype("float32")}
        counts = mesh_lower.count_collectives(kr._fn, karr)
        assert counts.get("psum") == 1, counts
        pred = kr.record.mesh["collective_bytes"]
        want = collective_seconds("psum", 12 * 20 * 4, 8, 1.0)
        assert abs(pred - want) < 1e-6, (pred, want)
        np.testing.assert_allclose(
            np.asarray(kr(karr)["O"]),
            np.asarray(karr["X"] @ karr["W"]), rtol=1e-3, atol=1e-3)

        print(json.dumps({
            "devices": jax.device_count(),
            "single_us": t_single,
            "replicated_us": t_rep, "sharded_us": t_sh,
            "speedup": t_rep / t_sh,
            "ffn_collective_bytes": sh.record.mesh["collective_bytes"],
            "kred_psum_count": counts["psum"],
            "kred_collective_bytes": pred,
        }))
    """)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"distributed bench failed:\n{out.stdout}\n{out.stderr}"
    res = json.loads(out.stdout.strip().splitlines()[-1])
    if "skip" in res:
        emit("distributed_skipped", 0.0, f"\"{res['skip']}\"")
        return
    emit("distributed_devices", 0.0, res["devices"])
    emit("distributed_ffn_single_device", res["single_us"], "")
    emit("distributed_ffn_replicated_mesh8", res["replicated_us"], "")
    emit("distributed_ffn_sharded_mesh8", res["sharded_us"],
         f"{res['speedup']:.2f}x")
    assert res["speedup"] > 1.0, \
        f"sharded must beat the replicated placement ({res['speedup']:.2f}x)"
    emit("distributed_ffn_collective_bytes", 0.0,
         int(res["ffn_collective_bytes"]))
    emit("distributed_kred_psum_emitted_vs_predicted", 0.0,
         f"\"psum={res['kred_psum_count']} bytes={int(res['kred_collective_bytes'])}\"")


BENCHES = {
    "fig1": bench_fig1_engineering_effort,
    "fig4": bench_fig4_autotile,
    "fig5": bench_fig5_rewrite,
    "cache": bench_stripe_jit_cache,
    "fusion": bench_fusion,
    "memplan": bench_memplan,
    "conv": bench_conv,
    "explore": bench_explore,
    "distributed": bench_distributed,
    "autotune": bench_autotune,
    "serving": bench_serving,
    "chaos": bench_chaos,
    "matmul": bench_stripe_matmul,
    "flash": bench_flash_attention_blocks,
    "hillclimb": bench_hillclimb,
    "arch": bench_arch_steps,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="OUT.json", default=None,
                    help="also write records as JSON to this path")
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of {','.join(BENCHES)}")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="enable span tracing for the whole run and write a "
                         "Chrome/Perfetto trace at the end")
    ap.add_argument("--metrics", metavar="OUT.json", default=None,
                    help="write the process-wide metrics-registry snapshot "
                         "at the end")
    ap.add_argument("--profile", action="store_true",
                    help="use profiled Stripe compiles (measured per-unit "
                         "latencies + residual log) in the cache/serving "
                         "benches")
    args = ap.parse_args(argv)
    global PROFILE
    PROFILE = args.profile
    if args.trace:
        obs.enable_tracing()

    selected = list(BENCHES)
    if args.only:
        selected = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in selected if s not in BENCHES]
        if unknown:
            ap.error(f"unknown bench(es) {unknown}; choose from {list(BENCHES)}")
    if args.json:
        # fail on an unwritable path now, not after minutes of benching
        try:
            open(args.json, "a").close()
        except OSError as e:
            ap.error(f"cannot write --json path: {e}")
    for name in selected:
        BENCHES[name]()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(RESULTS, f, indent=2)
        print(f"# wrote {len(RESULTS)} records to {args.json}")
    if args.trace:
        obs.export_chrome_trace(args.trace)
        print(f"# wrote {args.trace} ({len(obs.spans())} spans)")
    if args.metrics:
        with open(args.metrics, "w") as f:
            json.dump(obs.metrics_snapshot(), f, indent=2)
        print(f"# wrote {args.metrics}")


if __name__ == "__main__":
    main()
