"""Benchmark harness — one function per paper table/figure plus framework
benches.  Prints ``name,us_per_call,derived`` CSV rows.

Paper artifacts (Stripe has no numeric tables; its quantitative artifacts
are the Fig. 1 engineering-effort comparison and the Fig. 4/5 autotiling
example, both reproduced exactly):

* fig1: engineering-effort counts (kernel-library vs schedule-space vs
  Stripe) computed from this repo's actual artifact counts.
* fig4: the cache-line cost model on the 3x3 conv — cost of the Fig.5b
  tiling (54 lines / tile pair) and the autotiler's pick.
* fig5: the tiling rewrite — wall-clock of the XLA-compiled lowering
  before/after the pass pipeline (semantics asserted equal).

Framework benches: Stripe-matmul kernel vs plain einsum (CPU wall time),
per-arch reduced train step, flash-attention block-size choice, and the
§Perf hillclimb (see stripe_hillclimb.py).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, n=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def bench_fig1_engineering_effort() -> None:
    """Fig 1: artifacts needed per approach for our 10 archs x 3 hw
    configs x K ops.  Stripe: ops + hw-configs; kernel library:
    ops x hw x versions."""
    from repro import configs
    from repro.core.hwconfig import REGISTRY

    n_ops = 4          # matmul, attention-score, gla-chunk, conv (frontend ops)
    n_hw = len(REGISTRY)
    n_arch = len(configs.names())
    kernel_lib = n_ops * n_hw * n_arch          # per-op-per-hw-per-shape family
    schedule_space = n_ops * n_hw + n_ops       # spaces + algorithms
    stripe = n_ops + n_hw                       # algorithms + configs
    print(f"fig1_artifacts_kernel_library,{0.0:.2f},{kernel_lib}")
    print(f"fig1_artifacts_schedule_space,{0.0:.2f},{schedule_space}")
    print(f"fig1_artifacts_stripe,{0.0:.2f},{stripe}")


def bench_fig4_autotile() -> None:
    from repro.core.cost import evaluate_tiling
    from repro.core.frontend import single_op_program
    from repro.core.hwconfig import PAPER_FIG4
    from repro.core.passes.autotile import choose_tiling

    prog = single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), "int8"), "F": ((3, 3, 8, 16), "int8"),
         "O": ((12, 16, 16), "int32")},
        out="O",
    )
    blk = prog.entry.stmts[0]
    params = dict(PAPER_FIG4.passes[0][1])
    ref = evaluate_tiling(blk, {"x": 3, "y": 4}, PAPER_FIG4, params)
    t0 = time.perf_counter()
    tiles, best = choose_tiling(blk, PAPER_FIG4, params)
    dt = (time.perf_counter() - t0) * 1e6
    print(f"fig4_cost_fig5b_tiling,0.00,{ref.cost:.6f}")
    print(f"fig4_lines_per_tilepair,0.00,{ref.lines / ref.n_tiles:.0f}")
    print(f"fig4_autotile_best_cost,{dt:.2f},{best.cost:.6f}")
    print(f"fig4_autotile_tiles,0.00,\"{tiles}\"")


def bench_fig5_rewrite() -> None:
    """Tiling-rewrite overhead + executable equivalence (reduced shape)."""
    import copy

    from repro.core import execute_reference, single_op_program
    from repro.core.hwconfig import CPU_TEST
    from repro.core.lower_jnp import lower_program_jnp
    from repro.core.passes import compile_program

    prog = single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), "float32"), "F": ((3, 3, 8, 16), "float32"),
         "O": ((12, 16, 16), "float32")},
        out="O",
    )
    src = copy.deepcopy(prog)
    t0 = time.perf_counter()
    opt = compile_program(prog, CPU_TEST)
    dt_compile = (time.perf_counter() - t0) * 1e6
    rng = np.random.RandomState(0)
    arrays = {"I": rng.randn(12, 16, 8).astype(np.float32),
              "F": rng.randn(3, 3, 8, 16).astype(np.float32)}
    a = execute_reference(src, arrays)["O"]
    b = execute_reference(opt, arrays)["O"]
    equal = bool(np.allclose(a, b, rtol=1e-4, atol=1e-5))
    fn = jax.jit(lambda d: lower_program_jnp(opt.source)(d)["O"])
    dt_exec = _timeit(fn, {k: jnp.asarray(v) for k, v in arrays.items()})
    print(f"fig5_pass_pipeline_compile,{dt_compile:.2f},1")
    print(f"fig5_semantics_preserved,0.00,{int(equal)}")
    print(f"fig5_conv_exec_jnp,{dt_exec:.2f},1")


def bench_stripe_matmul() -> None:
    from repro.kernels.stripe_matmul.ops import matmul, matmul_ref

    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(256, 512), jnp.float32)
    w = jnp.asarray(rng.randn(512, 384), jnp.float32)
    t_ref = _timeit(jax.jit(lambda a, b: matmul_ref(a, b)), x, w)
    got = matmul(x, w, interpret=True)
    err = float(jnp.max(jnp.abs(got - matmul_ref(x, w))))
    print(f"stripe_matmul_ref_xla,{t_ref:.2f},1")
    print(f"stripe_matmul_pallas_interpret_maxerr,0.00,{err:.2e}")


def bench_flash_attention_blocks() -> None:
    from repro.kernels.flash_attention.ops import choose_block_sizes

    for s in (4096, 32768):
        t0 = time.perf_counter()
        bq, bk = choose_block_sizes(s, s, 128)
        dt = (time.perf_counter() - t0) * 1e6
        print(f"flash_attn_autotile_s{s},{dt:.2f},\"bq={bq} bk={bk}\"")


def bench_arch_steps() -> None:
    from repro import configs
    from repro.models.build import build_model, make_batch

    for name in configs.names():
        cfg = configs.get(name).scaled()
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg, "train", 2, 32)
        fn = jax.jit(lambda p, b: m.loss(p, b, remat=False)[0])
        dt = _timeit(fn, params, batch, n=3, warmup=1)
        print(f"arch_train_step_reduced/{name},{dt:.2f},1")


def bench_hillclimb() -> None:
    from . import stripe_hillclimb

    stripe_hillclimb.main()


def main() -> None:
    bench_fig1_engineering_effort()
    bench_fig4_autotile()
    bench_fig5_rewrite()
    bench_stripe_matmul()
    bench_flash_attention_blocks()
    bench_hillclimb()
    bench_arch_steps()


if __name__ == "__main__":
    main()
