"""Traffic benchmark: continuous-batching vs wave serving under load.

An open-loop driver replays a Poisson arrival process (a feeder thread
submits each request at its arrival time while the engine serves) of
``--requests`` mixed-length prompts against both engines at equal slot
count, then reports throughput (tokens/s), request-latency percentiles
(p50/p99, measured submit -> finish per request, so queueing delay under
load is included), and slot utilization.

Both engines are warmed on a throwaway request set before the timed run,
so the comparison is steady-state serving; cold-boot cost is the
compile-cache warm-start story (``ServingEngine.compile_log()``).

    PYTHONPATH=src python benchmarks/serve_traffic.py --requests 1000
    PYTHONPATH=src python benchmarks/serve_traffic.py --json OUT.json
"""
import argparse
import json
import threading
import time
from typing import Any, Dict, List

import jax
import numpy as np

from repro import api


def make_requests(cfg, n: int, seed: int, rate: float, base_uid: int = 0):
    """(arrival_offsets, requests): Poisson arrivals at ``rate`` req/s,
    prompt lengths mixed over [4, 48], generation lengths over [4, 24]."""
    r = np.random.RandomState(seed)
    arrivals = np.cumsum(r.exponential(1.0 / rate, size=n)) if rate > 0 \
        else np.zeros(n)
    reqs = []
    for i in range(n):
        plen = int(r.choice([4, 8, 16, 24, 32, 48]))
        new = int(r.randint(4, 25))
        reqs.append(api.Request(
            uid=base_uid + i,
            prompt=r.randint(1, cfg.vocab, size=plen).astype(np.int32),
            sampling=api.SamplingParams(max_new_tokens=new)))
    return arrivals, reqs


def drive(eng, params, arrivals, reqs) -> Dict[str, Any]:
    """Open-loop run: feeder thread submits on the arrival clock; the
    serve loop drains until every request finished."""
    n = len(reqs)
    done: List[Any] = []
    t0 = time.perf_counter()

    def feeder():
        for arr, r in zip(arrivals, reqs):
            lag = arr - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            eng.submit(r)

    th = threading.Thread(target=feeder, daemon=True)
    th.start()
    while len(done) < n:
        done.extend(eng.run(params, max_steps=1_000_000))
        if len(done) < n:
            time.sleep(0.0005)
    wall = time.perf_counter() - t0
    th.join()
    toks = sum(len(r.out_tokens) for r in done)
    lats = np.sort([r.finish_time - r.submit_time for r in done])
    return {
        "finished": len(done),
        "tokens": toks,
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1),
        "p50_s": round(float(lats[int(0.50 * n)]), 4),
        "p99_s": round(float(lats[int(0.99 * n)]), 4),
        "slot_utilization": (round(eng.metrics()["slot_utilization"], 3)
                             if isinstance(eng, api.ServingEngine) else None),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=1000)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--rate", type=float, default=250.0,
                    help="Poisson arrival rate, req/s (0 = all queued at t=0)")
    ap.add_argument("--json", default=None)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the continuous-beats-wave assertions")
    args = ap.parse_args(argv)

    cfg = api.configs.get("llama3-8b").scaled(
        d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=97, dtype="float32")
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    results: Dict[str, Any] = {"config": vars(args)}
    engines = (
        ("continuous", api.ServingEngine(model, api.EngineConfig(
            slots=args.slots, max_len=args.max_len, page_size=args.page_size))),
        ("wave", api.WaveEngine(model, args.slots, args.max_len)),
    )
    for label, eng in engines:
        # warm-up: compile every prompt bucket off the clock
        _, warm = make_requests(cfg, 50, seed=1, rate=0.0, base_uid=1_000_000)
        for r in warm:
            eng.submit(r)
        eng.run(params, max_steps=1_000_000)

        arrivals, reqs = make_requests(cfg, args.requests, seed=7, rate=args.rate)
        res = drive(eng, params, arrivals, reqs)
        results[label] = res
        print(f"{label:11s}: {res['tok_per_s']:8.0f} tok/s  "
              f"p50 {res['p50_s']*1e3:7.1f} ms  p99 {res['p99_s']*1e3:7.1f} ms  "
              f"util {res['slot_utilization']}")

    c, w = results["continuous"], results["wave"]
    results["speedup_tok_per_s"] = round(c["tok_per_s"] / w["tok_per_s"], 2)
    results["p99_improvement"] = round(w["p99_s"] / c["p99_s"], 2)
    print(f"continuous vs wave: {results['speedup_tok_per_s']}x throughput, "
          f"{results['p99_improvement']}x better p99")
    if not args.no_check:
        assert c["tok_per_s"] > w["tok_per_s"], "continuous must beat wave on throughput"
        assert c["p99_s"] < w["p99_s"], "continuous must beat wave on p99"
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
