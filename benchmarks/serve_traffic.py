"""Traffic benchmark: continuous-batching vs wave serving under load.

An open-loop driver replays a Poisson arrival process (a feeder thread
submits each request at its arrival time while the engine serves) of
``--requests`` mixed-length prompts against both engines at equal slot
count, then reports throughput (tokens/s), request-latency percentiles
(p50/p99, measured submit -> finish per request, so queueing delay under
load is included), and slot utilization.

Both engines are warmed on a throwaway request set before the timed run,
so the comparison is steady-state serving; cold-boot cost is the
compile-cache warm-start story (``ServingEngine.compile_log()``).

``--faults`` runs the chaos leg instead: the same Poisson trace is
replayed through two identical continuous engines — one clean, one under
an injected fault plan spanning five fault classes (prefill-compile
crash, torn disk-cache writes, device-step errors, prep-thread death,
page-allocation failure) — asserting that every request still completes
with *exactly-once, token-identical* output, that every injected fault is
matched by a recovery/degradation event in ``engine.events()``, and that
faulted throughput stays within 70% of fault-free.

``--trace OUT.json`` records the run as spans (request lifecycle, decode
steps, prep work) and writes a Chrome/Perfetto trace; with tracing on,
the continuous leg also reports the mean per-request latency breakdown
(queue wait vs prefill vs decode) computed from those spans.
``--profile`` compiles the engine's Stripe decode programs with
``profile=True`` (per-unit measured latencies + cost-model residual rows).

    PYTHONPATH=src python benchmarks/serve_traffic.py --requests 1000
    PYTHONPATH=src python benchmarks/serve_traffic.py --json OUT.json
    PYTHONPATH=src python benchmarks/serve_traffic.py --faults --json OUT.json
    PYTHONPATH=src python benchmarks/serve_traffic.py --trace trace.json
"""
import argparse
import json
import tempfile
import threading
import time
from typing import Any, Dict, List

import jax
import numpy as np

from repro import api, obs
from repro.core.cache import CompilationCache
from repro.reliability import faults


def make_requests(cfg, n: int, seed: int, rate: float, base_uid: int = 0):
    """(arrival_offsets, requests): Poisson arrivals at ``rate`` req/s,
    prompt lengths mixed over [4, 48], generation lengths over [4, 24]."""
    r = np.random.RandomState(seed)
    arrivals = np.cumsum(r.exponential(1.0 / rate, size=n)) if rate > 0 \
        else np.zeros(n)
    reqs = []
    for i in range(n):
        plen = int(r.choice([4, 8, 16, 24, 32, 48]))
        new = int(r.randint(4, 25))
        reqs.append(api.Request(
            uid=base_uid + i,
            prompt=r.randint(1, cfg.vocab, size=plen).astype(np.int32),
            sampling=api.SamplingParams(max_new_tokens=new)))
    return arrivals, reqs


def drive(eng, params, arrivals, reqs) -> Dict[str, Any]:
    """Open-loop run: feeder thread submits on the arrival clock; the
    serve loop drains until every request finished."""
    n = len(reqs)
    done: List[Any] = []
    t0 = time.perf_counter()

    def feeder():
        for arr, r in zip(arrivals, reqs):
            lag = arr - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            eng.submit(r)

    th = threading.Thread(target=feeder, daemon=True)
    th.start()
    while len(done) < n:
        done.extend(eng.run(params, max_steps=1_000_000))
        if len(done) < n:
            time.sleep(0.0005)
    wall = time.perf_counter() - t0
    th.join()
    toks = sum(len(r.out_tokens) for r in done)
    lats = np.sort([r.finish_time - r.submit_time for r in done])
    return {
        "finished": len(done),
        "tokens": toks,
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1),
        "p50_s": round(float(lats[int(0.50 * n)]), 4),
        "p99_s": round(float(lats[int(0.99 * n)]), 4),
        "slot_utilization": (round(eng.metrics()["slot_utilization"], 3)
                             if isinstance(eng, api.ServingEngine) else None),
    }


def span_breakdown() -> Dict[str, Any]:
    """Mean per-request latency breakdown (queue/prefill/decode seconds)
    from the serving spans currently in the default tracer."""
    events = obs.get_tracer().chrome_trace()["traceEvents"]
    per = obs.trace.request_breakdown(events)
    if not per:
        return {}

    def mean(k):
        return sum(r[k] for r in per.values()) / len(per)

    return {"requests": len(per),
            "queue_s": round(mean("queue_s"), 5),
            "prefill_s": round(mean("prefill_s"), 5),
            "decode_s": round(mean("decode_s"), 5),
            "total_s": round(mean("total_s"), 5)}


def _fault_plan(args) -> faults.FaultPlan:
    """Five fault classes against the timed trace.  Decode-step hits are
    spread through the run; the compile/cache classes land on the buckets
    that (deliberately) were not warmed."""
    return faults.FaultPlan([
        faults.fail_nth("serve.prefill_compile", 1),          # compile crash
        faults.fail_nth("cache.disk_write_torn", 2),          # cache corruption
        faults.fail_nth("cache.disk_write_torn", 5),
        faults.fail_nth("serve.decode_step", 80),             # device errors
        faults.fail_nth("serve.decode_step", 400),
        faults.fail_nth("serve.decode_step", 900),
        faults.fail_nth("serve.prep_thread", args.requests // 2),  # thread death
        faults.fail_nth("paged.alloc", 40),                   # alloc failure
    ])


def bench_faults(args, cfg, model, params) -> Dict[str, Any]:
    """The chaos leg: identical trace through a clean and a faulted
    engine; asserts completion, exactly-once token parity, fault->event
    matching, and >= 70% of fault-free throughput."""
    def mk_engine():
        return api.ServingEngine(
            model, api.EngineConfig(slots=args.slots, max_len=args.max_len,
                                    page_size=args.page_size,
                                    quarantine_backoff_s=0.25),
            compile_cache=CompilationCache(disk_dir=tempfile.mkdtemp(
                prefix="stripe-chaos-")))

    def warm(eng):
        # warm only the short buckets: the long ones compile during the
        # timed run (identically in both legs), giving the compile/cache
        # fault classes real work to corrupt
        r = np.random.RandomState(1)
        for i, plen in enumerate([4, 8, 16] * 2):
            eng.submit(api.Request(
                uid=1_000_000 + i,
                prompt=r.randint(1, cfg.vocab, size=plen).astype(np.int32),
                sampling=api.SamplingParams(max_new_tokens=4)))
        eng.run(params, max_steps=1_000_000)

    results: Dict[str, Any] = {}
    tokens: Dict[str, Dict[int, List[int]]] = {}
    statuses: Dict[str, Dict[int, str]] = {}
    plan = _fault_plan(args)
    for label in ("nofault", "faulted"):
        eng = mk_engine()
        warm(eng)
        arrivals, reqs = make_requests(cfg, args.requests, seed=7, rate=args.rate)
        if label == "faulted":
            with faults.inject(plan):
                res = drive(eng, params, arrivals, reqs)
        else:
            res = drive(eng, params, arrivals, reqs)
        tokens[label] = {r.uid: list(r.out_tokens) for r in reqs}
        statuses[label] = {r.uid: r.status for r in reqs}
        if label == "faulted":
            ev_counts: Dict[str, int] = {}
            for e in eng.events():
                ev_counts[e["event"]] = ev_counts.get(e["event"], 0) + 1
            qs = eng.cache_stats()
            res["faults_injected"] = plan.fired_counts()
            res["recovery_events"] = {
                k: v for k, v in ev_counts.items()
                if k in ("quarantine", "quarantine_expired", "quarantine_clear",
                         "device_step_failed", "requeue", "prep_thread_restart",
                         "alloc_failed", "cache_corruption_recovered",
                         "retry_exhausted", "prep_failed")}
            res["quarantine_stats"] = {
                "quarantined": qs.quarantined, "hits": qs.quarantine_hits,
                "expiries": qs.quarantine_expiries, "clears": qs.quarantine_clears}
            res["retries"] = eng.metrics()["retries"]

            # ---- every injected fault matches a recovery/degradation event
            fired = plan.fired_counts()
            ev = res["recovery_events"]
            assert fired.get("serve.prefill_compile", 0) == ev.get("quarantine", 0)
            assert fired.get("serve.decode_step", 0) == ev.get("device_step_failed", 0)
            assert fired.get("serve.prep_thread", 0) == ev.get("prep_thread_restart", 0)
            assert fired.get("paged.alloc", 0) == ev.get("alloc_failed", 0)
            torn = fired.get("cache.disk_write_torn", 0)
            recovered = sum(e.get("count", 0) for e in eng.events()
                            if e["event"] == "cache_corruption_recovered")
            assert torn == recovered, f"{torn} torn writes, {recovered} recovered"
            assert len(fired) >= 4, f"need >=4 distinct fault classes, got {fired}"
            # quarantine entry + backoff expiry visible via cache_stats()
            assert qs.quarantined >= 1 and qs.quarantine_expiries >= 1
        results[label] = res
        print(f"{label:11s}: {res['tok_per_s']:8.0f} tok/s  "
              f"p50 {res['p50_s']*1e3:7.1f} ms  p99 {res['p99_s']*1e3:7.1f} ms  "
              f"util {res['slot_utilization']}")

    # ---- exactly-once, token-identical completion under faults
    assert statuses["faulted"] == statuses["nofault"], \
        "fault recovery must not change any request's outcome"
    assert all(s == "ok" for s in statuses["faulted"].values())
    diverged = [u for u in tokens["nofault"]
                if tokens["faulted"][u] != tokens["nofault"][u]]
    assert not diverged, f"{len(diverged)} requests diverged under faults: {diverged[:5]}"
    ratio = results["faulted"]["tok_per_s"] / results["nofault"]["tok_per_s"]
    results["faulted_throughput_ratio"] = round(ratio, 3)
    print(f"faulted vs fault-free: {ratio:.2f}x throughput "
          f"({len(results['faulted']['faults_injected'])} fault classes, "
          f"all {args.requests} requests exactly-once)")
    assert ratio >= 0.70, f"faulted throughput {ratio:.2f}x < 0.70x fault-free"
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=1000)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--rate", type=float, default=250.0,
                    help="Poisson arrival rate, req/s (0 = all queued at t=0)")
    ap.add_argument("--json", default=None)
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="record spans and write a Chrome/Perfetto trace; "
                         "also reports the span-derived per-request latency "
                         "breakdown for the continuous engine")
    ap.add_argument("--profile", action="store_true",
                    help="compile the engine's Stripe decode programs with "
                         "profile=True (measured per-unit latencies + "
                         "cost-model residual rows)")
    ap.add_argument("--faults", action="store_true",
                    help="run the chaos leg (fault injection) instead of "
                         "the continuous-vs-wave comparison")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the continuous-beats-wave assertions")
    args = ap.parse_args(argv)
    if args.trace:
        obs.enable_tracing()

    cfg = api.configs.get("llama3-8b").scaled(
        d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=97, dtype="float32")
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    results: Dict[str, Any] = {"config": vars(args)}
    if args.faults:
        results.update(bench_faults(args, cfg, model, params))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(results, f, indent=2)
            print(f"# wrote {args.json}")
        if args.trace:
            obs.export_chrome_trace(args.trace)
            print(f"# wrote {args.trace} ({len(obs.spans())} spans)")
        return

    engines = (
        ("continuous", api.ServingEngine(model, api.EngineConfig(
            slots=args.slots, max_len=args.max_len, page_size=args.page_size,
            profile=args.profile))),
        ("wave", api.WaveEngine(model, args.slots, args.max_len)),
    )
    for label, eng in engines:
        # warm-up: compile every prompt bucket off the clock
        _, warm = make_requests(cfg, 50, seed=1, rate=0.0, base_uid=1_000_000)
        for r in warm:
            eng.submit(r)
        eng.run(params, max_steps=1_000_000)

        if args.trace and label == "continuous":
            obs.clear_trace()  # keep warm-up spans out of the breakdown
        arrivals, reqs = make_requests(cfg, args.requests, seed=7, rate=args.rate)
        res = drive(eng, params, arrivals, reqs)
        if args.trace and label == "continuous":
            bd = res["latency_breakdown"] = span_breakdown()
            if bd:
                print(f"continuous latency breakdown (mean over "
                      f"{bd['requests']} requests): "
                      f"queue {bd['queue_s']*1e3:.1f} ms, "
                      f"prefill {bd['prefill_s']*1e3:.1f} ms, "
                      f"decode {bd['decode_s']*1e3:.1f} ms")
        results[label] = res
        print(f"{label:11s}: {res['tok_per_s']:8.0f} tok/s  "
              f"p50 {res['p50_s']*1e3:7.1f} ms  p99 {res['p99_s']*1e3:7.1f} ms  "
              f"util {res['slot_utilization']}")

    c, w = results["continuous"], results["wave"]
    results["speedup_tok_per_s"] = round(c["tok_per_s"] / w["tok_per_s"], 2)
    results["p99_improvement"] = round(w["p99_s"] / c["p99_s"], 2)
    print(f"continuous vs wave: {results['speedup_tok_per_s']}x throughput, "
          f"{results['p99_improvement']}x better p99")
    if not args.no_check:
        assert c["tok_per_s"] > w["tok_per_s"], "continuous must beat wave on throughput"
        assert c["p99_s"] < w["p99_s"], "continuous must beat wave on p99"
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {args.json}")
    if args.trace:
        obs.export_chrome_trace(args.trace)
        print(f"# wrote {args.trace} ({len(obs.spans())} spans)")


if __name__ == "__main__":
    main()
