"""Deep-dive example: watch each Stripe pass transform the IR, reproduce
the paper's Fig. 5 rewrite, and run the generated Pallas kernel in
interpret mode.

    PYTHONPATH=src python examples/compile_op_with_stripe.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import single_op_program
from repro.core.hwconfig import PAPER_FIG4, TPU_V5E
from repro.core.passes import get_pass
from repro.core.tiling import split_block


def fig5_rewrite():
    print("=" * 70)
    print("Paper Fig. 5: conv tiling rewrite (3x4x16 output tile)")
    prog = single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), "int8"), "F": ((3, 3, 8, 16), "int8"),
         "O": ((12, 16, 16), "int32")},
        out="O",
    )
    blk = prog.entry.stmts[0]
    print("--- before (Fig. 5a) ---")
    print(blk.pretty())
    tiled = split_block(blk, {"x": 3, "y": 4})
    print("--- after (Fig. 5b): note I view 5x6x8 at [3x-1, 4y-1, 0] ---")
    print(tiled.pretty())


def pass_by_pass():
    print("=" * 70)
    print("TPU pipeline, pass by pass, on a 512^3 matmul")
    prog = single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((512, 512), "float32"), "B": ((512, 512), "float32"),
         "O": ((512, 512), "float32")},
        out="O",
    )
    for name, params in TPU_V5E.passes:
        prog = get_pass(name)(prog, TPU_V5E, params)
        blocks = [s for s in prog.entry.stmts if hasattr(s, "tags")]
        tags = [sorted(t for t in b.tags if not t.startswith("sched")) for b in blocks]
        print(f"after {name:10s}: {len(blocks)} block(s), tags={tags}")
    print(prog.pretty()[:1200], "...")


def run_generated_kernel():
    print("=" * 70)
    print("Stripe-generated Pallas kernel (interpret mode)")
    from repro.kernels.stripe_matmul.ops import matmul, matmul_ref

    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(256, 512), jnp.float32)
    w = jnp.asarray(rng.randn(512, 384), jnp.float32)
    b = jnp.asarray(rng.randn(384), jnp.float32)
    got = matmul(x, w, b, act="relu", interpret=True)
    want = matmul_ref(x, w, b, act="relu")
    print("max |err| vs oracle:", float(jnp.max(jnp.abs(got - want))))


if __name__ == "__main__":
    fig5_rewrite()
    pass_by_pass()
    run_generated_kernel()
