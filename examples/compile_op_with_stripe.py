"""Deep-dive example: watch each Stripe pass transform the IR, reproduce
the paper's Fig. 5 rewrite, and run the generated Pallas kernel in
interpret mode.

    PYTHONPATH=src python examples/compile_op_with_stripe.py
"""
import numpy as np
import jax.numpy as jnp

from repro import api


def fig5_rewrite():
    print("=" * 70)
    print("Paper Fig. 5: conv tiling rewrite (3x4x16 output tile)")
    prog = api.single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), "int8"), "F": ((3, 3, 8, 16), "int8"),
         "O": ((12, 16, 16), "int32")},
        out="O",
    )
    blk = prog.entry.stmts[0]
    print("--- before (Fig. 5a) ---")
    print(blk.pretty())
    tiled = api.split_block(blk, {"x": 3, "y": 4})
    print("--- after (Fig. 5b): note I view 5x6x8 at [3x-1, 4y-1, 0] ---")
    print(tiled.pretty())


def pass_by_pass():
    print("=" * 70)
    print("TPU pipeline, pass by pass, on a 512^3 matmul")
    prog = api.single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((512, 512), "float32"), "B": ((512, 512), "float32"),
         "O": ((512, 512), "float32")},
        out="O",
    )
    hw = api.get_config("tpu_v5e")
    for name, params in hw.passes:
        prog = api.get_pass(name)(prog, hw, params)
        blocks = [s for s in prog.entry.stmts if hasattr(s, "tags")]
        tags = [sorted(t for t in b.tags if not t.startswith("sched")) for b in blocks]
        print(f"after {name:10s}: {len(blocks)} block(s), tags={tags}")
    print(prog.pretty()[:1200], "...")


def run_generated_kernel():
    print("=" * 70)
    print("Stripe-generated Pallas kernel (interpret mode)")
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(256, 512), jnp.float32)
    w = jnp.asarray(rng.randn(512, 384), jnp.float32)
    b = jnp.asarray(rng.randn(384), jnp.float32)
    got = api.matmul(x, w, b, act="relu", interpret=True)
    want = api.matmul_ref(x, w, b, act="relu")
    print("max |err| vs oracle:", float(jnp.max(jnp.abs(got - want))))


def jit_with_cache():
    """The unified driver: one call runs frontend -> passes -> lowering
    behind the two-level compilation cache; the second compile is a cache
    hit and skips the autotile search entirely."""
    import time

    print("=" * 70)
    print("stripe_jit: compile driver + persistent compilation cache")
    cache = api.CompilationCache()  # disk at $STRIPE_CACHE_DIR or ~/.cache/stripe-repro
    text = "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]"
    tensors = {"I": ((12, 16, 8), "float32"), "F": ((3, 3, 8, 16), "float32"),
               "O": ((12, 16, 16), "float32")}
    t0 = time.perf_counter()
    compiled = api.jit(text, api.get_config("cpu_test"), tensors=tensors, out="O", cache=cache)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    api.jit(text, api.get_config("cpu_test"), tensors=tensors, out="O", cache=cache)
    warm = time.perf_counter() - t0
    rng = np.random.RandomState(0)
    out = compiled({"I": rng.randn(12, 16, 8).astype(np.float32),
                    "F": rng.randn(3, 3, 8, 16).astype(np.float32)})["O"]
    print(f"cold compile {cold*1e3:.1f} ms  (tilings={compiled.record.tilings})")
    print(f"warm compile {warm*1e6:.0f} us  ({cold/warm:.0f}x faster)")
    print(f"output shape {out.shape}; cache stats {cache.stats.as_dict()}")


if __name__ == "__main__":
    fig5_rewrite()
    pass_by_pass()
    run_generated_kernel()
    jit_with_cache()
