"""End-to-end training driver: data pipeline -> model -> AdamW -> fault-
tolerant loop with checkpointing.

Default preset trains a reduced llama-family model for 200 steps on CPU
(a few minutes).  ``--arch xlstm-125m --full`` trains the real 125M-param
xLSTM config (TPU-scale; on CPU it is slow but correct).

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 200
"""
import argparse

import jax

from repro import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true", help="use the full config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = api.configs.get(args.arch)
    if not args.full:
        cfg = cfg.scaled(n_layers=4, d_model=128, d_ff=256 if cfg.d_ff else 0,
                         vocab=512, vocab_pad_multiple=64)
    model = api.build_model(cfg)
    data = api.DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    opt = api.adamw.AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    tc = api.TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)

    trainer = api.Trainer(model, opt, data, tc, rng=jax.random.PRNGKey(0))
    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) for {args.steps} steps")
    out = trainer.run()
    for h in out["history"]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  {h['dt']*1e3:.0f} ms")
    print("final loss:", out["final_loss"], "| stragglers flagged:", len(out["stragglers"]))


if __name__ == "__main__":
    main()
