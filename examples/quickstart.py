"""Quickstart: express a tensor op in the Tile frontend, compile it with
the Stripe pass pipeline for TPU, inspect the optimized IR, and execute
both backends.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro import api


def main():
    # 1. A fused linear layer in the Tile language (paper §3.4).
    tp = api.TileProgram("fused_linear")
    tp.input("X", (256, 512))
    tp.input("W", (512, 384))
    tp.input("B", (384,))
    tp.temp("T", (256, 384))
    tp.output("O", (256, 384))
    tp.op("T[i, j] += X[i, c] * W[c, j]")
    tp.op("O[i, j] = relu(T[i, j] + B[j])")
    prog = tp.build()
    assert api.validate_program(prog) == []          # Def. 2 holds

    # 2. Compile with the TPU v5e hardware config: fuse -> autotile ->
    #    stencil -> boundary -> localize -> schedule.
    optimized = api.compile_program(prog, api.get_config("tpu_v5e"))
    print("=== optimized Stripe IR ===")
    print(optimized.pretty())

    # 3. Execute: jnp reference backend (and, on TPU, the Pallas backend —
    #    see repro.kernels.stripe_matmul for the generated kernel).
    rng = np.random.RandomState(0)
    arrays = {
        "X": jnp.asarray(rng.randn(256, 512), jnp.float32),
        "W": jnp.asarray(rng.randn(512, 384), jnp.float32),
        "B": jnp.asarray(rng.randn(384), jnp.float32),
    }
    out = api.lower_program_jnp(optimized.source)(arrays)["O"]
    want = np.maximum(np.asarray(arrays["X"]) @ np.asarray(arrays["W"]) + np.asarray(arrays["B"]), 0)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4, atol=1e-4)
    print("\njnp backend matches numpy: OK", out.shape)


if __name__ == "__main__":
    main()
