"""Batched serving: submit a set of prompts to the wave-batched engine
(prefill once per wave, lockstep decode, greedy sampling).

    PYTHONPATH=src python examples/serve_batched.py --arch qwen3-4b
"""
import argparse

import jax
import numpy as np

from repro import configs
from repro.models.build import build_model
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get(args.arch).scaled()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, batch_slots=4, max_len=64)

    rng = np.random.RandomState(0)
    for i in range(args.requests):
        prompt = rng.randint(0, cfg.vocab, size=rng.randint(3, 9)).astype(np.int32)
        engine.submit(Request(uid=i, prompt=prompt, max_new_tokens=args.new_tokens))

    done = engine.run(params, max_steps=256)
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: prompt={list(r.prompt)} -> out={r.out_tokens}")
    print(f"{len(done)}/{args.requests} requests completed")


if __name__ == "__main__":
    main()
