"""Continuous-batching serving: submit a set of prompts to the paged-KV
engine (per-slot admission/eviction, decode compiled through stripe_jit,
greedy sampling), then stream a couple of requests token-by-token.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen3-4b
"""
import argparse

import jax
import numpy as np

from repro import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = api.configs.get(args.arch).scaled()
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = api.ServingEngine(
        model, api.EngineConfig(slots=4, max_len=64, page_size=8))

    rng = np.random.RandomState(0)
    for i in range(args.requests):
        prompt = rng.randint(0, cfg.vocab, size=rng.randint(3, 9)).astype(np.int32)
        engine.submit(api.Request(
            uid=i, prompt=prompt,
            sampling=api.SamplingParams(max_new_tokens=args.new_tokens)))

    done = engine.run(params, max_steps=256)
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: prompt={list(r.prompt)} -> out={r.out_tokens}")
    m = engine.metrics()
    print(f"{len(done)}/{args.requests} requests completed | "
          f"{m['decode_steps']} decode steps, "
          f"slot utilization {m['slot_utilization']:.0%}")
    rec = engine.compile_records()["decode/mlp"]
    print(f"decode MLP via stripe_jit: {rec.n_kernels} kernels, groups={rec.groups}")

    # streaming API: tokens arrive as they are produced
    print("--- streaming ---")
    stream = engine.generate(
        [rng.randint(0, cfg.vocab, size=5).astype(np.int32) for _ in range(2)],
        params=params, sampling=api.SamplingParams(max_new_tokens=4))
    for uid, tok in stream:
        print(f"  uid={uid} token={tok}")


if __name__ == "__main__":
    main()
