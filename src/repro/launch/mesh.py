"""Production mesh builders.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization and only then calls these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int = 8):
    """Small host-device mesh for multi-device unit tests (2 x n/2)."""
    return jax.make_mesh((2, n_devices // 2), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n
