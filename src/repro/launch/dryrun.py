"""Multi-pod dry-run: ``.lower().compile()`` every (architecture x input
shape) cell on the production meshes and record memory/cost/collective
statistics.

MUST be run as a script/module so the XLA_FLAGS below take effect before
jax initializes:

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_results.json
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import sys
import time
import traceback
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..configs.base import SHAPES, applicable_shapes
from ..models.build import build_model, input_specs
from ..optim import adamw
from ..parallel import sharding as shd
from .hlo_stats import collective_stats, total_collective_bytes
from .mesh import dp_axes, dp_size, make_production_mesh


def _eval_param_shapes(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def _opt_shapes(param_shapes):
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, param_shapes),
        "v": jax.tree.map(zeros, param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def build_step(model, cfg, shape, mesh):
    """Returns (fn, arg_shapes, in_shardings, out_shardings)."""
    dpx = dp_axes(mesh)
    dps = dp_size(mesh)
    sizes = dict(mesh.shape)
    opt_cfg = adamw.AdamWConfig()
    pshapes = _eval_param_shapes(model)
    pspecs = shd.param_specs(pshapes, sizes)
    specs_in = input_specs(cfg, shape)

    if shape.kind == "train":
        def train_step(params, opt_state, batch):
            (loss, _metrics), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, remat=True), has_aux=True)(params)
            new_p, new_o, info = adamw.apply_updates(params, grads, opt_state, opt_cfg)
            return loss, new_p, new_o

        oshapes = _opt_shapes(pshapes)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        bspecs = shd.batch_specs(specs_in, dpx, sizes)
        in_sh = (pspecs, ospecs, bspecs)
        out_sh = (P(), pspecs, ospecs)
        args = (pshapes, oshapes, specs_in)
        return train_step, args, in_sh, out_sh

    # vlm caches hold the prepended patch positions too
    cache_len = shape.seq_len + (cfg.frontend_len if cfg.frontend == "patches" else 0)

    if shape.kind == "prefill":
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, cache_len))
        cspecs = shd.cache_specs(cache_shapes, shape.global_batch, dps, dpx, sizes)

        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)

        bspecs = shd.batch_specs(specs_in, dpx, sizes)
        in_sh = (pspecs, bspecs, cspecs)
        out_sh = (P(), cspecs)
        args = (pshapes, specs_in, cache_shapes)
        return prefill_step, args, in_sh, out_sh

    # decode: one new token against a cache of seq_len
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, cache_len))
    cspecs = shd.cache_specs(cache_shapes, shape.global_batch, dps, dpx, sizes)
    tok_spec = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}

    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch["tokens"])

    bspecs = shd.batch_specs(tok_spec, dpx, sizes) if shape.global_batch >= dps else jax.tree.map(lambda l: P(), tok_spec)
    in_sh = (pspecs, cspecs, bspecs)
    out_sh = (P(), cspecs)
    args = (pshapes, cache_shapes, tok_spec)
    return serve_step, args, in_sh, out_sh


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             keep_hlo: bool = False) -> Dict[str, Any]:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    t0 = time.time()
    fn, args, in_sh, out_sh = build_step(model, cfg, shape, mesh)

    with mesh:
        to_sharding = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P))
        jitted = jax.jit(fn, in_shardings=tuple(to_sharding(s) for s in in_sh),
                         out_shardings=to_sharding(out_sh))
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k in ("flops", "bytes accessed", "optimal_seconds"):
            if k in ca:
                cost[k] = float(ca[k])
    except Exception as e:  # pragma: no cover
        cost["error"] = str(e)

    hlo = compiled.as_text()
    # dominant scan trip count: layer stack (groups for zamba)
    trip = cfg.n_layers + cfg.n_enc_layers
    if cfg.hybrid:
        trip = (cfg.n_layers + cfg.hybrid.shared_attn_every - 1) // cfg.hybrid.shared_attn_every
    colls = collective_stats(hlo, body_multiplier=trip)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem,
        "cost": cost,
        "collectives": colls,
        "collective_bytes": sum(d["operand_bytes"] for d in colls.values()),
        "scan_trip_count": trip,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if keep_hlo:
        result["hlo"] = hlo
    return result


def all_cells():
    for arch in configs.names():
        cfg = configs.get(arch)
        for shape in applicable_shapes(cfg):
            yield arch, shape.name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} [{'2x16x16' if mp else '16x16'}]"
            try:
                r = run_cell(arch, shape, multi_pod=mp)
                mm = r["memory"].get("argument_size_in_bytes", 0) / (1 << 30)
                print(f"OK   {tag}: compile={r['compile_s']}s args={mm:.1f}GiB "
                      f"flops={r['cost'].get('flops', 0):.3e} coll={r['collective_bytes']:.3e}B",
                      flush=True)
                results.append(r)
            except Exception as e:
                n_fail += 1
                print(f"FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "ok": False, "error": str(e)})
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"done: {len(results) - n_fail}/{len(results)} cells passed", flush=True)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
