"""Collective-traffic statistics from compiled HLO text.

``compiled.cost_analysis()`` has FLOPs and bytes but no collective bytes;
we parse the post-GSPMD optimized HLO and sum the operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# e.g.:  %foo.12 = bf16[8,128,256]{2,1,0} all-gather(%bar.3), ...
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],\s{}]+?)\s+([\w\-]+)\(([^)]*)\)"
)
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_START = re.compile(r"^%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$|^ENTRY\s")


def collective_stats(hlo_text: str, body_multiplier: int = 1) -> Dict[str, Dict[str, float]]:
    """Returns {collective_kind: {count, operand_bytes, result_bytes}}.

    XLA's textual HLO lists each while-loop *body* computation once, so a
    collective inside a layer scan appears once even though it executes
    n_layers times.  ``body_multiplier`` scales collectives found inside
    non-entry computations whose name marks them as loop bodies (jax scan
    lowers to ``while`` with ``body``/``region`` computations); pass the
    dominant scan trip count (n_layers).
    """
    shapes: Dict[str, int] = {}
    rows = []
    in_entry = True
    cur_comp = ""
    # computations that are actual while-loop bodies/conditions: collect the
    # names referenced by `while(...), condition=%c, body=%b` instructions
    loop_comps = set()
    for m in re.finditer(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", hlo_text):
        loop_comps.update(m.groups())
    for ln in hlo_text.splitlines():
        stripped = ln.strip()
        if stripped.startswith("ENTRY"):
            in_entry = True
            cur_comp = "entry"
        elif stripped.startswith("%") and stripped.endswith("{") and "=" not in stripped.split("(")[0]:
            cur_comp = stripped.split()[0].lstrip("%")
            in_entry = False
        m = _INSTR.match(ln)
        if not m:
            continue
        name, type_str, op, operands = m.groups()
        shapes[name] = _shape_bytes(type_str)
        rows.append((name, type_str, op, operands, cur_comp, in_entry))

    out: Dict[str, Dict[str, float]] = {}
    for name, type_str, op, operands, comp, in_entry in rows:
        kind = None
        for c in COLLECTIVES:
            if op == c or op.startswith(c + "-start"):
                kind = c
                break
        if kind is None:
            continue
        mult = 1
        if not in_entry and comp in loop_comps:
            mult = body_multiplier
        opnd_bytes = 0
        for token in operands.split(","):
            token = token.strip().lstrip("%")
            if token in shapes:
                opnd_bytes += shapes[token]
        d = out.setdefault(kind, {"count": 0, "operand_bytes": 0.0, "result_bytes": 0.0})
        d["count"] += mult
        d["operand_bytes"] += opnd_bytes * mult
        d["result_bytes"] += shapes.get(name, 0) * mult
    return out


def total_collective_bytes(hlo_text: str, body_multiplier: int = 1) -> float:
    stats = collective_stats(hlo_text, body_multiplier)
    return sum(d["operand_bytes"] for d in stats.values())
