"""Roofline analysis over dry-run results.

Per (arch x shape x mesh) cell, derives the three roofline terms from the
compiled artifact recorded by ``dryrun.py``:

    compute term    = HLO_FLOPs / peak_FLOPs            (per-chip seconds)
    memory term     = HLO_bytes / HBM_bw
    collective term = collective_bytes / link_bw

``cost_analysis`` of the GSPMD-partitioned module reports *per-chip*
FLOPs/bytes, so the prompt's /chips is already applied.  Two caveats,
both reported side-by-side:

* XLA costs a ``while`` body once regardless of trip count, so raw
  FLOPs/bytes undercount layer-scanned models; the ANALYTIC columns use
  MODEL_FLOPS (6·N·D train / 2·N_active·D inference) and a parameter+
  cache traffic model as the sound lower bound per step.
* collective bytes are parsed from the partitioned HLO with loop bodies
  scaled by the scan trip count (see hlo_stats).

Usage:
    PYTHONPATH=src python -m repro.launch.roofline dryrun_results.json
"""
import json
import sys
from typing import Dict, List

PEAK_FLOPS = 197e12   # bf16 per chip
HBM_BW = 819e9        # bytes/s per chip
LINK_BW = 50e9        # bytes/s per ICI link

from .. import configs
from ..configs.base import SHAPES


def model_flops_per_chip(r: Dict) -> float:
    cfg = configs.get(r["arch"])
    shape = SHAPES[r["shape"]]
    chips = r["n_devices"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * cfg.param_count() * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * cfg.active_param_count() * tokens / chips
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * cfg.active_param_count() * tokens / chips


def analytic_bytes_per_chip(r: Dict) -> float:
    """Per-step HBM traffic lower bound: every resident param shard is
    read (weights stream from HBM once per use: fwd+bwd+remat for train),
    plus optimizer state r/w for train, plus the full KV/state cache for
    decode."""
    cfg = configs.get(r["arch"])
    shape = SHAPES[r["shape"]]
    chips = r["n_devices"]
    p = cfg.param_count()
    if shape.kind == "train":
        # params bf16 x (fwd + bwd-read + remat) + grads f32 + adam m,v r/w
        return (3 * 2 * p + 4 * p + 4 * 4 * p) / chips
    if shape.kind == "prefill":
        return 2 * cfg.active_param_count() / chips
    cache = r["memory"].get("argument_size_in_bytes", 0)  # incl. cache shard
    return 2 * cfg.active_param_count() / chips + cache * 0.5


def analyze(results: List[Dict]) -> List[Dict]:
    rows = []
    for r in results:
        if not r.get("ok"):
            continue
        flops = r["cost"].get("flops", 0.0)
        byts = r["cost"].get("bytes accessed", 0.0)
        coll = r.get("collective_bytes", 0.0)
        mf = model_flops_per_chip(r)
        ab = analytic_bytes_per_chip(r)

        t_c_raw = flops / PEAK_FLOPS
        t_m_raw = byts / HBM_BW
        t_x = coll / LINK_BW
        t_c = max(t_c_raw, mf / PEAK_FLOPS)       # scan-corrected compute
        t_m = max(t_m_raw, ab / HBM_BW)           # scan-corrected memory
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        dom = max(terms, key=terms.get)
        intrinsic = max(t_c, t_m)                 # hardware-imposed floor
        frac = intrinsic / max(max(terms.values()), 1e-30)
        rows.append({
            **{k: r[k] for k in ("arch", "shape", "mesh", "n_devices")},
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "t_compute_raw_s": t_c_raw, "t_memory_raw_s": t_m_raw,
            "dominant": dom,
            "model_flops_per_chip": mf,
            "hlo_flops_per_chip": flops,
            "useful_ratio": (mf / flops) if flops else float("inf"),
            "roofline_fraction": frac,
        })
    return rows


NOTES = {
    "compute": "already MXU-bound: gains come from stenciling/fusion keeping the MXU fed",
    "memory": "HBM-bound: increase arithmetic intensity (larger tiles, multiquery batching, quantized weights/cache)",
    "collective": "network-bound: fix sharding so activations/grads stay local; overlap with compute (ring collective-matmul); compress inter-pod grads",
}


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS/chip | HLO_FLOPs/chip | useful | roofline frac |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['model_flops_per_chip']:.2e} "
            f"| {r['hlo_flops_per_chip']:.2e} | {min(r['useful_ratio'], 99.0):.2f} "
            f"| {r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    results = json.load(open(path))
    rows = analyze(results)
    print(markdown_table(rows))
    out = path.replace(".json", "_roofline.json")
    json.dump(rows, open(out, "w"), indent=1)
    # summary: worst cells per category
    single = [r for r in rows if r["mesh"] == "16x16"]
    worst = sorted(single, key=lambda r: r["roofline_fraction"])[:5]
    print("\nworst roofline fractions (single-pod):")
    for r in worst:
        print(f"  {r['arch']} x {r['shape']}: {r['roofline_fraction']:.3f} ({r['dominant']}) -> {NOTES[r['dominant']]}")


if __name__ == "__main__":
    main()
