"""Decode-time attention/MLP blocks as Stripe programs.

The serving engine's decode step is not one opaque ``jax.jit`` over the
model: its dense blocks are expressed in the Tile frontend and compiled
through ``stripe_jit`` — frontend → fusion groups → memory planning →
backend — so decode traffic exercises the whole compiler, and every
compile leaves a :class:`~repro.core.driver.CompileRecord` (fusion
groups, kernel counts, per-block backend choices and fallback reasons)
that the engine surfaces via ``compile_records()``.

Four programs cover one transformer layer at decode time (``m`` = rows
flowing through the block: the slot count for decode, the padded bucket
length for prefill):

* ``qkv``    — the three attention input projections sharing one operand;
* ``scores`` — the GQA score contraction ``S[b,k,g,t] += Q·K`` over the
  gathered paged KV (decode only; softmax stays outside — it is not a
  contraction);
* ``values`` — the GQA value contraction ``O[b,k,g,d] += P·V``;
* ``attn_out`` — output projection fused with the residual add;
* ``mlp``    — the FFN with its activation chain fused between the
  matmuls when the activation is exactly representable as Stripe
  intrinsics (``silu``/``relu``/``relu2`` and their GLU forms); for
  activations whose framework semantics differ from the intrinsic
  (tanh-approximated ``gelu``), the matmuls compile through Stripe and
  the activation runs outside, recorded in ``act_outside``.

Programs compute in float32 (matching the reference attention path,
which upcasts for scores/values); callers cast in and out.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from ..core import cache as _cache
from ..core.driver import CompiledProgram, CompileRecord, stripe_jit
from ..core.frontend import TileProgram
from ..core.hwconfig import HardwareConfig

# activations whose Stripe intrinsic chain is semantically identical to
# the framework's nn.core._ACT implementation (see module docstring)
_FUSABLE_ACT = {
    "silu": "silu({x})",
    "relu": "relu({x})",
    "relu2": "square(relu({x}))",
}


def _jit_opts(cfg: "EngineLikeConfig") -> Dict:
    return dict(backend=cfg.backend, interpret=cfg.interpret,
                use_disk=cfg.use_disk, cache=cfg.cache, profile=cfg.profile,
                tune=cfg.tune)


@dataclasses.dataclass
class EngineLikeConfig:
    """The compile-relevant knobs, decoupled from EngineConfig."""

    hw: HardwareConfig
    backend: str = "jnp"
    interpret: bool = True
    use_disk: bool = True
    cache: Optional[_cache.CompilationCache] = None
    profile: bool = False
    tune: Any = None  # a repro.tune.TuningDB, or None


@dataclasses.dataclass
class DecodePrograms:
    """Stripe-compiled callables for one row-count ``m`` plus records."""

    m: int
    qkv: Callable
    attn_out: Callable
    mlp: Callable
    act_outside: Optional[str]  # activation applied outside the program, if any
    records: Dict[str, CompileRecord]
    scores: Optional[Callable] = None  # decode only (needs the KV window T)
    values: Optional[Callable] = None


def build_qkv_program(cfg, m: int, jc: EngineLikeConfig) -> CompiledProgram:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    tp = TileProgram(f"serve_qkv_m{m}")
    tp.input("X", (m, d))
    tp.input("WQ", (d, h * hd))
    tp.input("WK", (d, kv * hd))
    tp.input("WV", (d, kv * hd))
    tp.output("Q", (m, h * hd))
    tp.output("K", (m, kv * hd))
    tp.output("V", (m, kv * hd))
    tp.op("Q[b, e] += X[b, d] * WQ[d, e]", name="proj_q")
    tp.op("K[b, e] += X[b, d] * WK[d, e]", name="proj_k")
    tp.op("V[b, e] += X[b, d] * WV[d, e]", name="proj_v")
    return stripe_jit(tp.build(), jc.hw, **_jit_opts(jc))


def build_attn_out_program(cfg, m: int, jc: EngineLikeConfig) -> CompiledProgram:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    tp = TileProgram(f"serve_attn_out_m{m}")
    tp.input("A", (m, h * hd))
    tp.input("R", (m, d))
    tp.input("WO", (h * hd, d))
    tp.temp("T", (m, d))
    tp.output("Y", (m, d))
    tp.op("T[b, d2] += A[b, e] * WO[e, d2]", name="proj_o")
    tp.op("Y[b, d2] = T[b, d2] + R[b, d2]", name="resid")
    return stripe_jit(tp.build(), jc.hw, **_jit_opts(jc))


def build_mlp_program(cfg, m: int, jc: EngineLikeConfig):
    """Returns (compiled, act_outside).  The activation chain is fused
    into the program when exactly representable; otherwise the program
    carries the matmuls and the caller applies the activation between
    ``H`` (and ``G`` for GLU) and the down-projection."""
    d, f = cfg.d_model, cfg.d_ff
    act = cfg.act
    glu = act.endswith("_glu")
    base = act.split("_")[0] if glu else act
    fused = base in _FUSABLE_ACT
    tp = TileProgram(f"serve_mlp_m{m}")
    tp.input("X", (m, d))
    tp.input("R", (m, d))
    tp.input("Wd", (f, d))
    if glu:
        tp.input("Wg", (d, f))
        tp.input("Wu", (d, f))
        if fused:
            tp.temp("G", (m, f))
            tp.temp("U", (m, f))
            tp.temp("A", (m, f))
            tp.op("G[b, f] += X[b, d] * Wg[d, f]", name="mm_gate")
            tp.op("U[b, f] += X[b, d] * Wu[d, f]", name="mm_up")
            gexpr = _FUSABLE_ACT[base].format(x="G[b, f]")
            tp.op(f"A[b, f] = {gexpr} * U[b, f]", name="glu")
            inner = "A"
        else:
            # matmuls through Stripe, activation outside: split programs
            return _split_glu_programs(cfg, m, jc), base
    else:
        tp.input("Wu", (d, f))
        if fused:
            tp.temp("H", (m, f))
            tp.temp("A", (m, f))
            tp.op("H[b, f] += X[b, d] * Wu[d, f]", name="mm_up")
            tp.op(f"A[b, f] = {_FUSABLE_ACT[base].format(x='H[b, f]')}", name="act")
            inner = "A"
        else:
            return _split_plain_programs(cfg, m, jc), base
    tp.temp("O", (m, d))
    tp.output("Y", (m, d))
    tp.op(f"O[b, d2] += {inner}[b, f] * Wd[f, d2]", name="mm_down")
    tp.op("Y[b, d2] = O[b, d2] + R[b, d2]", name="resid")
    return stripe_jit(tp.build(), jc.hw, **_jit_opts(jc)), None


def _split_glu_programs(cfg, m: int, jc: EngineLikeConfig):
    """GLU MLP with the activation outside: an up program producing G and
    U, and a down program applying Wd + residual."""
    d, f = cfg.d_model, cfg.d_ff
    up = TileProgram(f"serve_mlp_up_m{m}")
    up.input("X", (m, d)); up.input("Wg", (d, f)); up.input("Wu", (d, f))
    up.output("G", (m, f)); up.output("U", (m, f))
    up.op("G[b, f] += X[b, d] * Wg[d, f]", name="mm_gate")
    up.op("U[b, f] += X[b, d] * Wu[d, f]", name="mm_up")
    down = _down_program(cfg, m, jc)
    cup = stripe_jit(up.build(), jc.hw, **_jit_opts(jc))
    return _SplitMLP(cup, down, glu=True)


def _split_plain_programs(cfg, m: int, jc: EngineLikeConfig):
    d, f = cfg.d_model, cfg.d_ff
    up = TileProgram(f"serve_mlp_up_m{m}")
    up.input("X", (m, d)); up.input("Wu", (d, f))
    up.output("H", (m, f))
    up.op("H[b, f] += X[b, d] * Wu[d, f]", name="mm_up")
    cup = stripe_jit(up.build(), jc.hw, **_jit_opts(jc))
    return _SplitMLP(cup, _down_program(cfg, m, jc), glu=False)


def _down_program(cfg, m: int, jc: EngineLikeConfig) -> CompiledProgram:
    d, f = cfg.d_model, cfg.d_ff
    tp = TileProgram(f"serve_mlp_down_m{m}")
    tp.input("A", (m, f)); tp.input("R", (m, d)); tp.input("Wd", (f, d))
    tp.temp("O", (m, d))
    tp.output("Y", (m, d))
    tp.op("O[b, d2] += A[b, f] * Wd[f, d2]", name="mm_down")
    tp.op("Y[b, d2] = O[b, d2] + R[b, d2]", name="resid")
    return stripe_jit(tp.build(), jc.hw, **_jit_opts(jc))


@dataclasses.dataclass
class _SplitMLP:
    """Two stripe programs with the activation applied by the caller."""

    up: CompiledProgram
    down: CompiledProgram
    glu: bool

    @property
    def records(self):
        return {"mlp_up": self.up.record, "mlp_down": self.down.record}


def build_scores_program(cfg, m: int, t: int, jc: EngineLikeConfig) -> CompiledProgram:
    kv, hd = cfg.n_kv_heads, cfg.hd
    g = cfg.n_heads // kv
    tp = TileProgram(f"serve_scores_m{m}_t{t}")
    tp.input("Q", (m, kv, g, hd))
    tp.input("K", (m, t, kv, hd))
    tp.output("S", (m, kv, g, t))
    tp.op("S[b, k, g, t] += Q[b, k, g, d] * K[b, t, k, d]", name="scores")
    return stripe_jit(tp.build(), jc.hw, **_jit_opts(jc))


def build_values_program(cfg, m: int, t: int, jc: EngineLikeConfig) -> CompiledProgram:
    kv, hd = cfg.n_kv_heads, cfg.hd
    g = cfg.n_heads // kv
    tp = TileProgram(f"serve_values_m{m}_t{t}")
    tp.input("P", (m, kv, g, t))
    tp.input("V", (m, t, kv, hd))
    tp.output("O", (m, kv, g, hd))
    tp.op("O[b, k, g, d] += P[b, k, g, t] * V[b, t, k, d]", name="values")
    return stripe_jit(tp.build(), jc.hw, **_jit_opts(jc))


def build_programs(cfg, m: int, jc: EngineLikeConfig,
                   kv_window: Optional[int] = None) -> DecodePrograms:
    """Compile the serving block programs for row count ``m``.

    ``kv_window`` (the logical paged-KV length T) adds the decode-only
    score/value contractions; prefill callers leave it None (their
    attention is the causal full-sequence einsum).
    """
    qkv = build_qkv_program(cfg, m, jc)
    attn_out = build_attn_out_program(cfg, m, jc)
    mlp, act_outside = build_mlp_program(cfg, m, jc)
    records: Dict[str, CompileRecord] = {
        "qkv": qkv.record, "attn_out": attn_out.record,
    }
    if isinstance(mlp, _SplitMLP):
        records.update(mlp.records)
    else:
        records["mlp"] = mlp.record
    scores = values = None
    if kv_window is not None:
        scores = build_scores_program(cfg, m, kv_window, jc)
        values = build_values_program(cfg, m, kv_window, jc)
        records["attn_scores"] = scores.record
        records["attn_values"] = values.record
    return DecodePrograms(m=m, qkv=qkv, attn_out=attn_out, mlp=mlp,
                          act_outside=act_outside, records=records,
                          scores=scores, values=values)


# ------------------------------------------------------------------ apply
def run_qkv(progs: DecodePrograms, x2d: jnp.ndarray, wq, wk, wv):
    out = progs.qkv({"X": x2d.astype(jnp.float32), "WQ": wq.astype(jnp.float32),
                     "WK": wk.astype(jnp.float32), "WV": wv.astype(jnp.float32)})
    return out["Q"], out["K"], out["V"]


def run_attn_out(progs: DecodePrograms, attn2d: jnp.ndarray, resid2d: jnp.ndarray, wo):
    out = progs.attn_out({"A": attn2d.astype(jnp.float32),
                          "R": resid2d.astype(jnp.float32),
                          "WO": wo.astype(jnp.float32)})
    return out["Y"]


def run_mlp(progs: DecodePrograms, x2d: jnp.ndarray, resid2d: jnp.ndarray, mlp_params, act: str):
    """Apply the (possibly split) MLP program, matching nn.core.mlp_apply."""
    from ..nn.core import _ACT

    x2d = x2d.astype(jnp.float32)
    resid2d = resid2d.astype(jnp.float32)
    mlp = progs.mlp
    glu = act.endswith("_glu")
    if isinstance(mlp, _SplitMLP):
        if glu:
            got = mlp.up({"X": x2d, "Wg": mlp_params["w_gate"].astype(jnp.float32),
                          "Wu": mlp_params["w_up"].astype(jnp.float32)})
            a = _ACT[progs.act_outside](got["G"]) * got["U"]
        else:
            got = mlp.up({"X": x2d, "Wu": mlp_params["w_up"].astype(jnp.float32)})
            a = _ACT[progs.act_outside](got["H"])
        return mlp.down({"A": a, "R": resid2d,
                         "Wd": mlp_params["w_down"].astype(jnp.float32)})["Y"]
    arrays = {"X": x2d, "R": resid2d, "Wd": mlp_params["w_down"].astype(jnp.float32)}
    if glu:
        arrays["Wg"] = mlp_params["w_gate"].astype(jnp.float32)
        arrays["Wu"] = mlp_params["w_up"].astype(jnp.float32)
    else:
        arrays["Wu"] = mlp_params["w_up"].astype(jnp.float32)
    return mlp(arrays)["Y"]
