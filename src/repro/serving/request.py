"""Serving request/config schema — the stable public contract.

``SamplingParams`` describes *how* to decode one request, ``EngineConfig``
describes the engine (slot count, paged-KV geometry, admission policy,
stripe backend), and ``Request`` carries one sequence through the engine.

``Request`` still accepts the pre-redesign flat fields
(``max_new_tokens=``, ``eos_id=``) as a thin deprecation shim — they are
folded into ``sampling`` at construction, so old call sites keep working
unchanged while new code passes ``SamplingParams`` explicitly.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class SamplingParams:
    """Per-request decode parameters.

    * ``max_new_tokens`` — tokens to generate, *including* the token
      emitted by the prefill step (the engine stops a sequence as soon as
      ``len(out_tokens) == max_new_tokens``).
    * ``eos_id`` — stop token; ``-1`` disables early stop.
    * ``temperature`` — placeholder for future stochastic sampling; only
      ``0.0`` (greedy argmax) is implemented, and the engine raises on
      anything else rather than silently ignoring it.
    * ``ttl_s`` — per-request deadline: seconds after submit by which the
      request must *finish*.  An expired request is evicted (or never
      admitted) with ``status == "deadline_exceeded"`` and whatever tokens
      it produced; ``None`` falls back to ``EngineConfig.default_ttl_s``
      (no deadline when that is also ``None``).
    """

    max_new_tokens: int = 16
    eos_id: int = -1
    temperature: float = 0.0
    ttl_s: Optional[float] = None

    def validate(self) -> None:
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        if self.temperature != 0.0:
            raise NotImplementedError(
                "only greedy decoding (temperature=0.0) is implemented")
        if self.ttl_s is not None and self.ttl_s <= 0:
            raise ValueError(f"ttl_s must be > 0, got {self.ttl_s}")


@dataclasses.dataclass
class EngineConfig:
    """Continuous-batching engine configuration.

    * ``slots`` — decode batch width; every decode step runs all slots.
    * ``max_len`` — maximum total sequence length (prompt + generated).
    * ``page_size`` — tokens per KV page; the logical KV window of one
      slot is ``ceil(max_len / page_size)`` pages.
    * ``pages`` — size of the shared physical page pool.  ``None`` sizes
      it at ``slots * ceil(max_len / page_size)`` (admission never blocks
      on pages); smaller pools create real paging pressure and may delay
      admission until evictions recycle pages.
    * ``admission`` — queue policy: ``"fcfs"`` (strict arrival order;
      head-of-line blocks when it doesn't fit) or ``"sjf"`` (shortest
      remaining job first among the prepared requests).
    * ``backend`` / ``hw`` / ``interpret`` — the ``stripe_jit`` backend,
      hardware config name, and Pallas interpret flag used to compile the
      decode-time attention/MLP blocks.
    * ``use_stripe_decode`` — route decode blocks through ``stripe_jit``
      (the default); ``False`` uses plain jnp ops (same math, no compile
      records) for A/B measurement.
    * ``use_disk_cache`` — let the engine's compilation cache persist
      tilings + the bucket manifest to disk so the next boot warm-starts.
    * ``max_queue`` — bounded admission queue: when more than this many
      requests are pending (submitted but not yet admitted), ``submit()``
      sheds the request (returns ``False``, ``status == "shed"``, a
      ``shed`` event) instead of growing the queue without bound.
      ``None`` keeps the queue unbounded.
    * ``default_ttl_s`` — engine-wide deadline applied to requests whose
      ``SamplingParams.ttl_s`` is ``None``.
    * ``max_retries`` — how many times a request evicted by a device-step
      failure is requeued before it is failed (``retry_exhausted``).
    * ``quarantine_backoff_s`` — base backoff of the compile-failure
      quarantine (doubles per consecutive failure).
    * ``event_log_size`` — ring-buffer capacity of the engine event log;
      beyond it the oldest events drop (counted in the
      ``serve.dropped_events`` metric).  ``0`` keeps the log unbounded.
    * ``profile`` — compile the decode-time Stripe programs with
      ``stripe_jit(..., profile=True)``: per-unit measured latencies
      attach to each ``CompileRecord`` and (predicted, measured) rows
      land in the cost-model residual log.
    * ``tune`` — consult (and, with ``profile``, populate) the measured
      tuning DB next to the engine's compilation cache: bucket compiles
      go through ``stripe_jit(..., tune=...)``, so a workload measured
      by the explore sweep or a previous profiled run replays its
      measured-best tiling (a ``tuned_replay`` engine event; hit/miss
      counts in ``cache_stats()``).
    """

    slots: int = 8
    max_len: int = 256
    page_size: int = 16
    pages: Optional[int] = None
    admission: str = "fcfs"
    backend: str = "jnp"
    hw: str = "tpu_v5e"
    interpret: bool = True
    use_stripe_decode: bool = True
    use_disk_cache: bool = False
    max_queue: Optional[int] = None
    default_ttl_s: Optional[float] = None
    max_retries: int = 2
    quarantine_backoff_s: float = 0.25
    event_log_size: int = 10_000
    profile: bool = False
    tune: bool = False

    def validate(self) -> None:
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.max_len < 2:
            raise ValueError(f"max_len must be >= 2, got {self.max_len}")
        if self.admission not in ("fcfs", "sjf"):
            raise ValueError(f"unknown admission policy {self.admission!r}; "
                             "expected 'fcfs' or 'sjf'")
        if self.pages is not None and self.pages < self.pages_per_slot:
            raise ValueError(
                f"pages={self.pages} cannot hold even one full sequence "
                f"({self.pages_per_slot} pages)")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.default_ttl_s is not None and self.default_ttl_s <= 0:
            raise ValueError(f"default_ttl_s must be > 0, got {self.default_ttl_s}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.quarantine_backoff_s <= 0:
            raise ValueError(
                f"quarantine_backoff_s must be > 0, got {self.quarantine_backoff_s}")
        if self.event_log_size < 0:
            raise ValueError(
                f"event_log_size must be >= 0, got {self.event_log_size}")

    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_len // self.page_size)

    @property
    def pool_pages(self) -> int:
        return (self.pages if self.pages is not None
                else self.slots * self.pages_per_slot)


@dataclasses.dataclass
class Request:
    """One sequence moving through the engine.

    Preferred construction is ``Request(uid, prompt, sampling=SamplingParams(...))``.
    The flat ``max_new_tokens`` / ``eos_id`` fields are a deprecation shim
    for the pre-``SamplingParams`` API; when ``sampling`` is not given they
    are folded into one.  ``out_tokens`` includes the token produced by the
    prefill step.
    """

    uid: int
    prompt: np.ndarray  # (plen,) int32
    max_new_tokens: int = 16       # deprecated: use sampling=
    eos_id: int = -1               # deprecated: use sampling=
    sampling: Optional[SamplingParams] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # terminal outcome: "ok" (finished normally), "shed" (rejected by the
    # bounded queue), "deadline_exceeded" (TTL expired queued or mid-
    # decode), "failed" (prep error / retries exhausted)
    status: str = "ok"
    retries: int = 0
    error: str = ""
    # engine-filled timing/placement (seconds on time.perf_counter's clock)
    submit_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0
    deadline: float = 0.0  # absolute finish-by time; 0.0 = no deadline
    slot: int = -1
    # crash-safe retry bookkeeping: tokens already emitted to the caller
    # before the failure; the retried incarnation regenerates and verifies
    # them (greedy decode is deterministic) without re-emitting
    replay_len: int = 0

    def __post_init__(self) -> None:
        if self.sampling is None:
            self.sampling = SamplingParams(max_new_tokens=self.max_new_tokens,
                                           eos_id=self.eos_id)
        else:
            # keep the legacy mirror fields consistent for old readers
            self.max_new_tokens = self.sampling.max_new_tokens
            self.eos_id = self.sampling.eos_id
        self.sampling.validate()
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.uid}: empty prompt")

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time
