"""Block-paged KV cache with static shapes, plus the paged decode/prefill
steps for dense-attention LMs.

Layout: the physical KV store is ``(n_layers, n_pages, page_size, kv_heads,
head_dim)``.  A slot's logical KV window is ``pages_per_slot =
ceil(max_len / page_size)`` pages, mapped through a ``page_table`` row of
physical page ids; the logical window length ``T = pages_per_slot *
page_size`` is what attention sees, with positions ``>= pos`` masked.
Every shape is static — slots grow and shrink purely by rewriting the
(tiny, host-side) page table and per-slot ``pos``.

Physical pages ``[0, pool_pages)`` form the shared allocation pool;
pages ``[pool_pages, pool_pages + slots)`` are per-slot *garbage pages*:
an idle slot's page-table row points at its own garbage page, so the
always-full-batch decode step's KV writes from dead slots land in
disjoint junk rows (never a scatter collision with a live slot, which
keeps runs deterministic) and are never read.

Because masked score entries are exact zeros after softmax (the
``NEG_INF`` shift underflows ``exp`` to 0.0), recycled pages need no
zeroing: stale values contribute exactly nothing.  Greedy decode through
the paged path therefore reproduces the dense-cache reference decode
token-for-token (asserted in tests/test_serving_engine.py).

The dense blocks inside these steps route through the Stripe-compiled
programs of :mod:`repro.serving.stripe_decode` when ``progs`` is given,
or through equivalent plain-jnp ops when it is None (A/B path).  Both
compute in float32, matching the reference attention path's upcast.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm
from ..nn.attention import NEG_INF, causal_mask, mha
from ..reliability import faults
from ..nn.core import apply_norm, apply_rope, embed_lookup, rms_head_norm
from .stripe_decode import DecodePrograms, run_attn_out, run_mlp, run_qkv


# --------------------------------------------------------------- page pool
class PagePool:
    """Host-side allocator over the shared physical page pool.

    Allocation and release are O(pages) list ops on python ints — the
    device never sees the free list, only the rewritten page tables.
    LIFO reuse keeps the hot pages hot and is deterministic.
    """

    def __init__(self, pool_pages: int, slots: int):
        self.pool_pages = int(pool_pages)
        self.slots = int(slots)
        self._free: List[int] = list(range(self.pool_pages))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def total_pages(self) -> int:
        """Physical pages including the per-slot garbage pages."""
        return self.pool_pages + self.slots

    def garbage_page(self, slot: int) -> int:
        return self.pool_pages + slot

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, n: int) -> Optional[List[int]]:
        if faults.fires("paged.alloc", n=n, free=len(self._free)):
            # injected transient allocation failure: report exhaustion;
            # the engine defers the admission instead of crashing
            return None
        if len(self._free) < n:
            return None
        got = self._free[-n:]
        del self._free[-n:]
        return got

    def release(self, pages: List[int]) -> None:
        for p in pages:
            if not (0 <= p < self.pool_pages):
                raise ValueError(f"released page {p} outside the pool")
        self._free.extend(pages)


def pages_needed(plen: int, new_tokens: int, page_size: int) -> int:
    """Pages a request occupies over its whole lifetime: KV rows are
    written for positions ``[0, plen + new_tokens - 1)`` (the last
    emitted token is never written back)."""
    rows = plen + max(new_tokens, 1) - 1
    return -(-rows // page_size)


def init_pages(cfg, total_pages: int, page_size: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    dtype = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, total_pages, page_size, cfg.n_kv_heads, cfg.hd)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


# ----------------------------------------------------------- jnp fallback
def _proj(x2d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bd,de->be", x2d.astype(jnp.float32), w.astype(jnp.float32))


def _mlp_jnp(x2d: jnp.ndarray, resid2d: jnp.ndarray, p, act: str) -> jnp.ndarray:
    from ..nn.core import _ACT

    x2d = x2d.astype(jnp.float32)
    if act.endswith("_glu"):
        a = _ACT[act.split("_")[0]](_proj(x2d, p["w_gate"])) * _proj(x2d, p["w_up"])
    else:
        a = _ACT[act](_proj(x2d, p["w_up"]))
    return _proj(a, p["w_down"]) + resid2d.astype(jnp.float32)


# ------------------------------------------------------------ decode step
def make_decode_step(cfg, progs: Optional[DecodePrograms], page_size: int):
    """Build the (jit-friendly) continuous decode step.

    Signature: ``fn(params, pages_k, pages_v, page_table, pos, tok) ->
    (next_tok, pages_k, pages_v)`` with ``page_table (S, PPS) int32``,
    ``pos (S,) int32`` (per-slot lengths), ``tok (S,) int32``.
    """
    ps = int(page_size)
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kv
    sm_scale = 1.0 / np.sqrt(hd)

    def step(params, pages_k, pages_v, page_table, pos, tok):
        s = page_table.shape[0]
        pps = page_table.shape[1]
        t_total = pps * ps
        n_phys = pages_k.shape[1]
        x = embed_lookup(params["embed"], tok[:, None])  # (S, 1, D)

        # flat-row addressing over (n_phys * ps) KV rows
        gather_rows = (page_table[:, :, None] * ps
                       + jnp.arange(ps, dtype=jnp.int32)[None, None, :]
                       ).reshape(s, t_total)
        cur_page = jnp.take_along_axis(page_table, (pos // ps)[:, None], axis=1)[:, 0]
        write_rows = cur_page * ps + pos % ps  # (S,) — disjoint by construction
        kpos = jnp.arange(t_total, dtype=jnp.int32)
        valid = kpos[None, :] < (pos + 1)[:, None]  # (S, T)
        mask = valid[:, None, None, :]  # (S, 1|KV, 1|G, T)

        def layer(x, scanned):
            p_i, pk, pv = scanned
            ap = p_i["attn"]
            xn = apply_norm(p_i["ln1"], x, cfg.norm)
            if progs is not None:
                q2, k2, v2 = run_qkv(progs, xn[:, 0], ap["wq"], ap["wk"], ap["wv"])
            else:
                q2 = _proj(xn[:, 0], ap["wq"])
                k2 = _proj(xn[:, 0], ap["wk"])
                v2 = _proj(xn[:, 0], ap["wv"])
            q = q2.reshape(s, 1, h, hd)
            k = k2.reshape(s, 1, kv, hd)
            v = v2.reshape(s, 1, kv, hd)
            if cfg.qk_norm:
                q = rms_head_norm(q, ap["q_norm"])
                k = rms_head_norm(k, ap["k_norm"])
            q = apply_rope(q, pos[:, None], cfg.rope, cfg.rope_theta)
            k = apply_rope(k, pos[:, None], cfg.rope, cfg.rope_theta)

            flat_k = pk.reshape(n_phys * ps, kv, hd).at[write_rows].set(
                k[:, 0].astype(pk.dtype))
            flat_v = pv.reshape(n_phys * ps, kv, hd).at[write_rows].set(
                v[:, 0].astype(pv.dtype))
            ck = flat_k[gather_rows].astype(jnp.float32)  # (S, T, KV, hd)
            cv = flat_v[gather_rows].astype(jnp.float32)

            qg = q[:, 0].reshape(s, kv, g, hd).astype(jnp.float32)
            if progs is not None and progs.scores is not None:
                scores = progs.scores({"Q": qg, "K": ck})["S"]
            else:
                scores = jnp.einsum("bkgd,btkd->bkgt", qg, ck)
            scores = scores * sm_scale
            scores = jnp.where(mask, scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1)
            if progs is not None and progs.values is not None:
                o = progs.values({"P": probs, "V": cv})["O"]
            else:
                o = jnp.einsum("bkgt,btkd->bkgd", probs, cv)
            a2 = o.reshape(s, h * hd)
            if progs is not None:
                x1 = run_attn_out(progs, a2, x[:, 0], ap["wo"])
            else:
                x1 = _proj(a2, ap["wo"]) + x[:, 0].astype(jnp.float32)
            x1 = x1.astype(x.dtype)

            xn2 = apply_norm(p_i["ln2"], x1[:, None], cfg.norm)
            if progs is not None:
                y = run_mlp(progs, xn2[:, 0], x1, p_i["mlp"], cfg.act)
            else:
                y = _mlp_jnp(xn2[:, 0], x1, p_i["mlp"], cfg.act)
            return (y.astype(x.dtype)[:, None],
                    (flat_k.reshape(pk.shape), flat_v.reshape(pv.shape)))

        x, (pages_k, pages_v) = jax.lax.scan(
            layer, x, (params["blocks"], pages_k, pages_v))
        logits = lm._logits(params, cfg, x)  # (S, 1, V)
        nxt = jnp.argmax(logits[:, -1, : cfg.vocab], axis=-1).astype(jnp.int32)
        return nxt, pages_k, pages_v

    return step


# ----------------------------------------------------------------- prefill
def make_prefill_step(cfg, progs: Optional[DecodePrograms], page_size: int,
                      bucket_len: int):
    """Build the batch-1 paged prefill for one compile bucket.

    Signature: ``fn(params, tokens (1, Lb), length (int32 scalar),
    page_row (PPS,) int32, pages_k, pages_v) -> (first_tok scalar,
    pages_k, pages_v)``.  Tokens are right-padded to the bucket; rows at
    positions ``>= length`` scatter junk into the slot's own allocated /
    garbage pages, which attention masks, and which decode overwrites
    in-place before each position ever becomes visible.
    """
    ps = int(page_size)
    lb = int(bucket_len)
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    sm_scale = 1.0 / np.sqrt(hd)

    def step(params, tokens, length, page_row, pages_k, pages_v):
        n_phys = pages_k.shape[1]
        x = embed_lookup(params["embed"], tokens)  # (1, Lb, D)
        t = jnp.arange(lb, dtype=jnp.int32)
        write_rows = page_row[t // ps] * ps + t % ps  # (Lb,)
        positions = t[None]  # (1, Lb)
        cmask = causal_mask(lb)

        def layer(x, scanned):
            p_i, pk, pv = scanned
            ap = p_i["attn"]
            xn = apply_norm(p_i["ln1"], x, cfg.norm)
            if progs is not None:
                q2, k2, v2 = run_qkv(progs, xn[0], ap["wq"], ap["wk"], ap["wv"])
            else:
                q2 = _proj(xn[0], ap["wq"])
                k2 = _proj(xn[0], ap["wk"])
                v2 = _proj(xn[0], ap["wv"])
            q = q2.reshape(1, lb, h, hd)
            k = k2.reshape(1, lb, kv, hd)
            v = v2.reshape(1, lb, kv, hd)
            if cfg.qk_norm:
                q = rms_head_norm(q, ap["q_norm"])
                k = rms_head_norm(k, ap["k_norm"])
            q = apply_rope(q, positions, cfg.rope, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope, cfg.rope_theta)

            flat_k = pk.reshape(n_phys * ps, kv, hd).at[write_rows].set(
                k[0].astype(pk.dtype))
            flat_v = pv.reshape(n_phys * ps, kv, hd).at[write_rows].set(
                v[0].astype(pv.dtype))

            out = mha(q, k, v, cmask, sm_scale)  # causal full-sequence
            if progs is not None:
                x1 = run_attn_out(progs, out.reshape(lb, h * hd), x[0], ap["wo"])
            else:
                x1 = _proj(out.reshape(lb, h * hd), ap["wo"]) + x[0].astype(jnp.float32)
            x1 = x1.astype(x.dtype)
            xn2 = apply_norm(p_i["ln2"], x1[None], cfg.norm)
            if progs is not None:
                y = run_mlp(progs, xn2[0], x1, p_i["mlp"], cfg.act)
            else:
                y = _mlp_jnp(xn2[0], x1, p_i["mlp"], cfg.act)
            return (y.astype(x.dtype)[None],
                    (flat_k.reshape(pk.shape), flat_v.reshape(pv.shape)))

        x, (pages_k, pages_v) = jax.lax.scan(
            layer, x, (params["blocks"], pages_k, pages_v))
        x_last = jax.lax.dynamic_slice(x, (0, length - 1, 0), (1, 1, x.shape[-1]))
        logits = lm._logits(params, cfg, x_last)  # (1, 1, V)
        tok = jnp.argmax(logits[0, 0, : cfg.vocab]).astype(jnp.int32)
        return tok, pages_k, pages_v

    return step
