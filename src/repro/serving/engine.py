"""Continuous-batching serving engine over a paged KV cache, with decode
compiled through ``stripe_jit``.

Architecture (one PR-sized tour; DESIGN.md §9 has the long form):

* **Slots, not waves.**  The decode step always runs ``slots`` sequences;
  a finished sequence is evicted *that step* and the freed slot is
  refilled from the queue in the same admission phase, so the batch never
  drains to let stragglers finish (the failure mode of
  :class:`~repro.serving.wave.WaveEngine`).
* **Paged KV** (:mod:`repro.serving.paged`): fixed-size pages in one
  static physical store, a per-slot page table, pages recycled on
  eviction.  Admission blocks only when the *pool* (not a dense
  per-slot allocation) is exhausted.
* **Stripe-compiled decode** (:mod:`repro.serving.stripe_decode`): the
  dense blocks of both prefill and decode are Tile programs compiled
  via ``stripe_jit`` — fusion grouping, memory planning, per-block
  hybrid backend fallback — with every :class:`CompileRecord` surfaced
  through :meth:`ServingEngine.compile_records`.
* **Genuine compile buckets.**  Prefill compiles per power-of-two prompt
  bucket; each bucket's compiled step is a *real entry* in the
  :class:`~repro.core.cache.CompilationCache` keyed by a content hash,
  so ``cache_stats()`` counts true bucket hit/miss traffic (the old
  engine only logged buckets).  With a disk-backed cache the engine
  writes a bucket *manifest* and warm-starts every previously seen
  bucket at boot, while the stripe tilings replay from the on-disk
  store.
* **Async host prep.**  ``submit()`` hands the raw request to a
  background thread that pads and buckets it while the device is busy
  decoding; admission drains the prepared queue (deterministically —
  single FIFO worker) at each step boundary.
* **Resilience** (:mod:`repro.reliability.faults` names the injection
  sites; DESIGN.md §10 has the long form).  The engine survives every
  registered serve-time fault site:

  - *prep-thread supervision* — a request whose prep raises becomes a
    failed request (``status == "failed"``); a dying worker hands its
    exception back under the condition variable (no 10s stall) and is
    restarted, its in-flight request requeued (prep is side-effect-free;
    bounded by ``max_retries``);
  - *compile quarantine* — a prompt bucket whose compiled-step build
    raises serves through the plain-jnp prefill instead (same tokens),
    and the bucket is negative-cached with exponential backoff
    (``quarantine``/``quarantine_expired``/``quarantine_clear`` events);
  - *deadlines* — ``SamplingParams.ttl_s`` / ``EngineConfig.default_ttl_s``
    bound each request's life; expired requests are evicted (queued ones
    never occupy a slot) with ``status == "deadline_exceeded"``;
  - *load shedding* — with ``EngineConfig.max_queue`` set, ``submit()``
    rejects excess requests (``status == "shed"``, a ``shed`` event)
    instead of growing the queue without bound;
  - *crash-safe decode* — a device-step failure evicts only the affected
    slots and requeues their requests (bounded by ``max_retries``); the
    retried incarnation regenerates the already-emitted prefix and
    *verifies* it token-for-token without re-emitting (exactly-once
    output), while healthy slots keep decoding;
  - *page-allocation failures* — a failed allocation defers the
    admission (``alloc_failed`` event) instead of crashing the engine.

Public contract
---------------
``ServingEngine(model, EngineConfig(...))`` (or the legacy
``ServingEngine(model, batch_slots=4, max_len=64)`` shim), then either

* batch: ``engine.submit(Request(...)); finished = engine.run(params)``;
* streaming: ``for uid, tok in engine.generate(prompts, params=params)``.

``submit()`` returns ``False`` when the bounded queue sheds the request.
``run()`` returns every request that reached a terminal state during the
call — check ``Request.status`` (``ok`` / ``deadline_exceeded`` /
``failed``); shed requests never enter the engine and are listed by
:meth:`ServingEngine.shed`.

Greedy decoding only (``SamplingParams.temperature == 0.0``); a request's
``out_tokens`` includes the token emitted by its prefill step.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque
from typing import Any, Deque, Dict, Iterable, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import cache as stripe_cache
from ..core.driver import CompileRecord
from ..core.hwconfig import get_config as _get_hw
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..reliability import faults
from .paged import PagePool, init_pages, make_decode_step, make_prefill_step, pages_needed
from .request import EngineConfig, Request, SamplingParams
from .stripe_decode import EngineLikeConfig, build_programs
from .wave import WaveEngine  # re-exported: the legacy engine lives on as the baseline

__all__ = ["ServingEngine", "WaveEngine", "Request", "SamplingParams", "EngineConfig"]

_STOP = object()


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass
class _Prepared:
    """A request after host-side prep (padding + bucketing), ready to admit."""

    req: Request
    order: int
    plen: int
    bucket: int
    tokens: np.ndarray  # (1, bucket) int32, right-padded
    n_pages: int
    eff_new: int        # max_new_tokens clipped to what max_len can hold


class ServingEngine:
    """Continuous-batching engine; see module docstring for the contract."""

    def __init__(self, model, config: Optional[EngineConfig] = None,
                 max_len: Optional[int] = None, *,
                 batch_slots: Optional[int] = None,
                 compile_cache: Optional[stripe_cache.CompilationCache] = None,
                 params: Any = None):
        # Legacy shim: ServingEngine(model, 4, 64) and
        # ServingEngine(model, batch_slots=4, max_len=64) both still work.
        if isinstance(config, int):
            batch_slots, config = config, None
        if config is None:
            config = EngineConfig(
                slots=batch_slots if batch_slots is not None else 8,
                max_len=max_len if max_len is not None else 256)
        config.validate()
        self.model = model
        self.cfg = model.cfg
        if getattr(self.cfg, "family", "dense") != "dense" or \
                getattr(self.cfg, "frontend", "none") != "none":
            raise ValueError(
                f"ServingEngine serves dense-attention LMs (family='dense', "
                f"frontend='none'); got family={self.cfg.family!r} "
                f"frontend={self.cfg.frontend!r}. Use WaveEngine for other families.")
        self.config = config
        self.slots = config.slots
        self.max_len = config.max_len
        self._params = params

        self._compile_cache = (compile_cache if compile_cache is not None
                               else stripe_cache.CompilationCache(
                                   capacity=256, use_disk=config.use_disk_cache))
        self._tune_db = None
        if config.tune:
            # the tuning DB lives next to the disk compilation cache (or
            # the process default dir): bucket compiles consult it, and
            # profiled dispatches feed measurements back into it
            from ..tune.db import TuningDB

            self._tune_db = TuningDB(dir=self._compile_cache.disk_dir)
        self._jc = EngineLikeConfig(
            hw=_get_hw(config.hw), backend=config.backend,
            interpret=config.interpret,
            use_disk=self._compile_cache.disk_dir is not None,
            cache=self._compile_cache, profile=config.profile,
            tune=self._tune_db)

        # ---- paged KV state (static shapes; see paged.py for the layout)
        self._ps = config.page_size
        self._pps = config.pages_per_slot
        self._kv_window = self._pps * self._ps
        self._pool = PagePool(config.pool_pages, self.slots)
        self._pk, self._pv = init_pages(self.cfg, self._pool.total_pages, self._ps)
        self._garbage = np.array(
            [self._pool.garbage_page(s) for s in range(self.slots)], np.int32)
        self._page_table = np.tile(self._garbage[:, None], (1, self._pps)).astype(np.int32)
        self._pos = np.zeros(self.slots, np.int32)
        self._last = np.zeros(self.slots, np.int32)
        self._slot_req: List[Optional[Request]] = [None] * self.slots
        self._slot_pages: List[List[int]] = [[] for _ in range(self.slots)]
        self._slot_eff = np.zeros(self.slots, np.int64)
        self._free_slots = list(range(self.slots))

        # ---- compile identity: content keys shared across engine instances
        self._model_fp = stripe_cache.stable_hash(dataclasses.asdict(self.cfg))
        self._manifest_key = stripe_cache.content_key(
            "serve_manifest", self._model_fp, self._ps, self._pps,
            config.backend, config.use_stripe_decode)
        self._records: Dict[str, CompileRecord] = {}
        self._compile_log: List[Dict[str, Any]] = []
        self._pending_tuned: List[Dict[str, Any]] = []
        self._build_decode()

        # ---- async prep: submit() -> raw queue -> FIFO worker -> ready deque
        self._raw: "queue.Queue" = queue.Queue()
        self._ready: Deque[_Prepared] = deque()
        self._cond = threading.Condition()
        self._n_submitted = 0
        self._n_prepared = 0
        self._order = 0
        self._prep_thread: Optional[threading.Thread] = None
        # a dying prep worker leaves (in-flight request, exception) here and
        # notifies the condition variable so _drain_prep reacts immediately
        self._prep_exc: Optional[Tuple[Optional[Request], BaseException]] = None
        self._prep_restarts = 0

        # ---- resilience: bucket compile quarantine + retry/replay state
        self._quarantine = stripe_cache.QuarantineStore(
            base_backoff_s=config.quarantine_backoff_s,
            stats=self._compile_cache.stats)
        # per-slot exactly-once bookkeeping: tokens emitted by this
        # incarnation, and how many of them are replays of pre-failure output
        self._slot_emitted = np.zeros(self.slots, np.int64)
        self._slot_replay = np.zeros(self.slots, np.int64)
        # hot-path read: _surface_cache_errors runs every serve iteration,
        # so hold the registry counter itself rather than going through the
        # CacheStats attribute shim (and never copy a stats dict per step)
        self._disk_err_ctr = self._compile_cache.stats.registry.counter(
            "cache.disk_errors")
        self._disk_errors_seen = int(self._disk_err_ctr.value)

        # ---- bookkeeping + observability
        # the event log is a bounded ring buffer: long-running traffic
        # cannot grow it without bound; drops are counted and surfaced as
        # the serve.dropped_events metric
        self._next_uid = 0
        self._event_cap = config.event_log_size or None
        self._events: Deque[Dict[str, Any]] = deque(maxlen=self._event_cap)
        self._dropped_events = 0
        self._obs = obs_metrics.Registry()
        self._m_events = {}  # per-event-label counter cache (hot-path refs)
        self._finished: List[Request] = []
        self._shed_reqs: List[Request] = []
        self._steps = 0
        self._live_steps = 0
        self._tokens_out = 0
        self._retries_total = 0
        self._warmed = False
        self._decode_warm = False
        self._h_decode = self._obs.histogram("serve.decode_step_s")
        self._h_prefill = self._obs.histogram("serve.prefill_s")
        self._h_queue = self._obs.histogram("serve.queue_wait_s")
        self._h_request = self._obs.histogram("serve.request_s")
        for fields in self._pending_tuned:  # decode compiles pre-date the log
            self._event("tuned_replay", **fields)
        self._pending_tuned.clear()

    # -------------------------------------------------------------- events
    def _event(self, event: str, **fields) -> None:
        """Append one structured event to the bounded log, count it in the
        metrics registry, and (when tracing) mark it on the trace."""
        if self._event_cap is not None and len(self._events) == self._event_cap:
            self._dropped_events += 1
        self._events.append({"step": self._steps, "event": event, **fields})
        ctr = self._m_events.get(event)
        if ctr is None:
            ctr = self._m_events[event] = self._obs.counter(
                "serve.events", event=event)
        ctr.inc()
        obs_trace.instant(f"serve.{event}", **fields)

    def _finish_obs(self, r: Request) -> None:
        """Request-lifecycle observability at terminal time: total-latency
        histogram plus a retroactive ``serve.request`` span covering the
        request's whole life (submit -> terminal)."""
        if r.submit_time and r.finish_time:
            self._h_request.observe(r.finish_time - r.submit_time)
            obs_trace.span_at("serve.request", r.submit_time, r.finish_time,
                              uid=r.uid, status=r.status,
                              tokens=len(r.out_tokens))

    # ------------------------------------------------------------- compile
    def _build_decode(self) -> None:
        """Compile (or fetch) the decode-step programs + jitted step.

        The entry is a genuine compilation-cache record keyed by model
        fingerprint and engine geometry, so a second engine over the same
        model reuses the live compiled step (a memory hit in
        ``cache_stats()``)."""
        key = stripe_cache.content_key(
            "serve_decode", self._model_fp, self.slots, self._ps, self._pps,
            self.config.backend, self.config.interpret,
            self.config.use_stripe_decode,
            # tuned replays lower different tilings, so a tuned bucket
            # never aliases an untuned one in a shared live cache
            self.config.tune)
        hit = self._compile_cache.get_memory(key)
        if hit is None:
            t0 = time.perf_counter()
            progs = (build_programs(self.cfg, self.slots, self._jc,
                                    kv_window=self._kv_window)
                     if self.config.use_stripe_decode else None)
            fn = jax.jit(make_decode_step(self.cfg, progs, self._ps))
            hit = (fn, progs)
            self._compile_cache.put_memory(key, hit)
            self._compile_log.append({
                "kind": "decode_programs", "slots": self.slots,
                "kv_window": self._kv_window,
                "first_call_s": time.perf_counter() - t0})
            if progs is not None:
                self._note_tuned("decode", progs.records)
        self._decode_fn, self._decode_progs = hit
        if self._decode_progs is not None:
            self._records.update(
                {f"decode/{k}": v for k, v in self._decode_progs.records.items()})

    def _note_tuned(self, kind: str, records) -> None:
        """Emit one ``tuned_replay`` event per freshly-compiled program
        whose tilings came from the tuning DB (decision provenance for
        the event log; replayed cache hits stay silent).  Decode compiles
        happen before the event log exists, so early events buffer in
        ``_pending_tuned`` and flush at the end of ``__init__``."""
        for name, rec in records.items():
            if (getattr(rec, "decision_source", "") == "tuned"
                    and not rec.cache_hit):
                tuned = getattr(rec, "tuned", None) or {}
                fields = dict(kind=kind, program=name,
                              candidate=str(tuned.get("candidate_id", "")),
                              measured_s=tuned.get("measured_s"),
                              source=str(tuned.get("source", "")))
                if getattr(self, "_obs", None) is None:
                    self._pending_tuned.append(fields)
                else:
                    self._event("tuned_replay", **fields)

    def _prefill_key(self, bucket: int) -> str:
        return stripe_cache.content_key(
            "serve_prefill", self._model_fp, self._ps, self._pps, bucket,
            self.config.backend, self.config.interpret,
            self.config.use_stripe_decode, self.config.tune)

    def _get_prefill(self, bucket: int, params, warm: bool = False):
        """Fetch-or-compile the prefill step for one prompt bucket.

        Every admission routes through this lookup, so bucket traffic is
        counted by the compilation cache for real (``cache_stats()``), and
        every new bucket is added to the on-disk manifest for the next
        boot's warm start.

        A bucket whose compile *crashes* is quarantined (negative-cached
        with exponential backoff) and served through the plain-jnp prefill
        fallback — same math, same tokens — on the very step the compile
        failed; when the embargo lapses the next admission re-attempts the
        real compile."""
        key = self._prefill_key(bucket)
        entry = self._quarantine.get(key)
        was_expired = entry.expired if entry is not None else None
        if self._quarantine.active(key):
            return self._prefill_fallback(bucket, params)
        if entry is not None and was_expired is False:
            # embargo just lapsed: one retry is permitted below
            self._event("quarantine_expired", bucket=bucket,
                        fail_count=entry.fail_count)
        fn = self._compile_cache.get_memory(key)
        if fn is not None:
            return fn
        t0 = time.perf_counter()
        try:
            faults.check("serve.prefill_compile", bucket=bucket)
            progs = (build_programs(self.cfg, bucket, self._jc)
                     if self.config.use_stripe_decode else None)
            fn = jax.jit(make_prefill_step(self.cfg, progs, self._ps, bucket))
            # trace + compile now (dummy call into the slot-0 garbage page,
            # result discarded) so the admission that triggered this pays the
            # whole cost here, visibly, and later admissions are warm.
            row = np.full(self._pps, self._garbage[0], np.int32)
            out = fn(params, jnp.zeros((1, bucket), jnp.int32), jnp.int32(1),
                     jnp.asarray(row), self._pk, self._pv)
            jax.block_until_ready(out)
        except Exception as e:  # noqa: BLE001 — any compile crash quarantines
            qe = self._quarantine.record_failure(key, repr(e))
            self._event("quarantine", bucket=bucket, reason=repr(e)[:200],
                        fail_count=qe.fail_count,
                        backoff_s=round(qe.backoff_s, 4))
            return self._prefill_fallback(bucket, params)
        if progs is not None:
            self._records.update(
                {f"prefill_L{bucket}/{k}": v for k, v in progs.records.items()})
            self._note_tuned(f"prefill_L{bucket}", progs.records)
        if entry is not None:
            # post-embargo retry succeeded: the bucket is healthy again
            self._quarantine.clear(key)
            self._event("quarantine_clear", bucket=bucket)
        self._compile_cache.put_memory(key, fn)
        self._compile_log.append({
            "kind": "prefill", "bucket": bucket, "slots": 1, "plen": bucket,
            "first_call_s": time.perf_counter() - t0, "warm_start": warm})
        self._touch_manifest(bucket)
        return fn

    def _prefill_fallback(self, bucket: int, params):
        """Degraded prefill for a quarantined bucket: plain jnp, no stripe
        programs, cached under its own key.  Produces the same tokens as
        the stripe path (both are bit-exact vs the dense reference), so a
        quarantined bucket degrades in *throughput*, never in output."""
        fkey = stripe_cache.content_key(
            "serve_prefill_fallback", self._model_fp, self._ps, self._pps, bucket)
        fn = self._compile_cache.get_memory(fkey)
        if fn is not None:
            return fn
        t0 = time.perf_counter()
        fn = jax.jit(make_prefill_step(self.cfg, None, self._ps, bucket))
        row = np.full(self._pps, self._garbage[0], np.int32)
        out = fn(params, jnp.zeros((1, bucket), jnp.int32), jnp.int32(1),
                 jnp.asarray(row), self._pk, self._pv)
        jax.block_until_ready(out)
        self._compile_cache.put_memory(fkey, fn)
        self._compile_log.append({
            "kind": "prefill_fallback", "bucket": bucket,
            "first_call_s": time.perf_counter() - t0})
        return fn

    def _touch_manifest(self, bucket: int) -> None:
        if self._compile_cache.disk_dir is None:
            return
        payload = self._compile_cache.get_disk(self._manifest_key) or {}
        buckets = sorted(set(payload.get("buckets", [])) | {int(bucket)})
        self._compile_cache.put_disk(self._manifest_key, {"buckets": buckets})

    def _warm_start(self, params) -> None:
        """At boot (first serve), replay the on-disk bucket manifest:
        every previously seen prefill bucket compiles now — with stripe
        tilings replayed from the disk cache — instead of stalling the
        first admission that needs it."""
        if self._warmed:
            return
        self._warmed = True
        if self._compile_cache.disk_dir is None:
            return
        payload = self._compile_cache.get_disk(self._manifest_key)
        if not payload:
            return
        buckets = [int(b) for b in payload.get("buckets", [])]
        for b in buckets:
            if b <= self.max_len:
                self._get_prefill(b, params, warm=True)
        self._event("warm_start", buckets=buckets)

    # ----------------------------------------------------------- admission
    def submit(self, req: Request) -> bool:
        """Enqueue a request.  Validation is synchronous (raises here);
        padding/bucketing happens on the prep thread.

        Returns ``False`` when the bounded queue (``EngineConfig.max_queue``)
        sheds the request instead of admitting it — the request is marked
        ``status == "shed"`` and never enters the engine."""
        req.submit_time = time.perf_counter()
        ttl = (req.sampling.ttl_s if req.sampling.ttl_s is not None
               else self.config.default_ttl_s)
        if ttl is not None:
            req.deadline = req.submit_time + ttl
        plen = int(req.prompt.size)
        if plen > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt length {plen} > max_len {self.max_len}")
        eff = min(req.sampling.max_new_tokens, self.max_len - plen + 1)
        if pages_needed(plen, eff, self._ps) > self._pool.pool_pages:
            raise ValueError(
                f"request {req.uid}: needs more pages than the whole pool "
                f"({self._pool.pool_pages}); raise EngineConfig.pages")
        if self.config.max_queue is not None:
            with self._cond:
                depth = (self._n_submitted - self._n_prepared) + len(self._ready)
            if depth >= self.config.max_queue:
                req.status = "shed"
                req.done = True
                req.finish_time = req.submit_time
                self._shed_reqs.append(req)
                self._event("shed", uid=req.uid, queue_depth=depth)
                return False
        self._next_uid = max(self._next_uid, req.uid + 1)
        self._ensure_prep_thread()
        with self._cond:
            self._n_submitted += 1
        self._event("enqueue", uid=req.uid)
        self._raw.put(req)
        return True

    def _ensure_prep_thread(self) -> None:
        if self._prep_thread is None or not self._prep_thread.is_alive():
            self._prep_thread = threading.Thread(
                target=self._prep_loop, daemon=True, name="serve-prep")
            self._prep_thread.start()

    def _prep_loop(self) -> None:
        item: Any = None
        try:
            while True:
                item = self._raw.get()
                if item is _STOP:
                    return
                try:
                    faults.check("serve.prep", uid=item.uid)
                    with obs_trace.span("serve.prep", uid=item.uid):
                        prep = self._prepare(item)
                except Exception as e:  # noqa: BLE001 — per-item failure:
                    # the request fails, the worker survives
                    with self._cond:
                        self._n_prepared += 1
                        self._fail_prep(item, e)
                        self._cond.notify_all()
                    continue
                # thread-level fault site: simulates the worker dying with
                # a prepared-but-unhanded item in flight
                faults.check("serve.prep_thread", uid=item.uid)
                with self._cond:
                    self._ready.append(prep)
                    self._n_prepared += 1
                    self._cond.notify_all()
        except BaseException as e:
            # dying: hand the exception (and the in-flight request) back to
            # the serving thread under the condition variable so _drain_prep
            # wakes immediately instead of stalling on its timeout; the
            # handoff is the report, so don't also re-raise into the void
            with self._cond:
                self._prep_exc = (item if isinstance(item, Request) else None, e)
                self._cond.notify_all()

    def _fail_prep(self, req: Request, exc: BaseException) -> None:
        """Terminal-fail a request that never made it past prep."""
        req.status = "failed"
        req.error = f"prep failed: {exc!r}"[:300]
        req.done = True
        req.finish_time = time.perf_counter()
        self._finished.append(req)
        self._event("prep_failed", uid=req.uid, error=req.error)

    def _prepare(self, req: Request) -> _Prepared:
        plen = int(req.prompt.size)
        bucket = max(plen, min(_next_pow2(plen), self.max_len))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        eff = min(req.sampling.max_new_tokens, self.max_len - plen + 1)
        with self._cond:
            order, self._order = self._order, self._order + 1
        return _Prepared(req=req, order=order, plen=plen, bucket=bucket,
                         tokens=toks, n_pages=pages_needed(plen, eff, self._ps),
                         eff_new=eff)

    def _drain_prep(self) -> None:
        """Barrier: wait until everything submitted so far is prepared.
        Keeps admission deterministic (pure arrival order) while the
        actual padding work overlapped with the previous device steps.

        Supervision: a dying worker notifies the condition variable with
        its exception attached (``self._prep_exc``), so thread death is
        detected immediately — not after a multi-second stall.  The worker
        is restarted and its in-flight request (if any) requeued — prep is
        side-effect-free, so the retry is safe — bounded by
        ``max_retries`` (exhaustion fails the request).  A worker found
        dead *without* a handoff is a fail-fast error."""
        with self._cond:
            while self._n_prepared < self._n_submitted:
                if self._prep_exc is not None:
                    item, exc = self._prep_exc
                    self._prep_exc = None
                    self._prep_restarts += 1
                    ev = {"restarts": self._prep_restarts,
                          "error": repr(exc)[:200]}
                    if item is not None:
                        item.retries += 1
                        self._retries_total += 1
                        if item.retries > self.config.max_retries:
                            self._n_prepared += 1
                            self._fail_prep(item, exc)
                            ev["failed_uid"] = item.uid
                        else:
                            # nothing happened to the request yet: retry it
                            # through the restarted worker
                            self._raw.put(item)
                            ev["requeued_uid"] = item.uid
                    self._event("prep_thread_restart", **ev)
                    self._prep_thread = None
                    self._ensure_prep_thread()
                    continue
                if not self._cond.wait(timeout=0.25):
                    if self._prep_exc is not None:
                        continue
                    if self._prep_thread is None or not self._prep_thread.is_alive():
                        raise RuntimeError(
                            "serving prep thread died without handing back its "
                            f"work ({self._n_submitted - self._n_prepared} "
                            "request(s) pending)")

    def close(self) -> None:
        """Stop the prep thread (idempotent; the engine stays usable —
        a later submit() restarts it)."""
        if self._prep_thread is not None and self._prep_thread.is_alive():
            self._raw.put(_STOP)
            self._prep_thread.join(timeout=5.0)
        self._prep_thread = None

    def _pick_candidate(self) -> Optional[int]:
        """Index into ``self._ready`` of the next request to admit, or
        None if nothing admissible (fcfs: strict head-of-line; sjf:
        shortest total job among prepared requests that fits)."""
        if not self._ready:
            return None
        if self.config.admission == "fcfs":
            return 0 if self._pool.can_alloc(self._ready[0].n_pages) else None
        best: Optional[Tuple[Tuple[int, int], int]] = None
        for i, p in enumerate(self._ready):
            if not self._pool.can_alloc(p.n_pages):
                continue
            k = (p.plen + p.eff_new, p.order)
            if best is None or k < best[0]:
                best = (k, i)
        return None if best is None else best[1]

    def _expire_queued(self) -> None:
        """Drop queued requests whose deadline passed — they never occupy
        a slot; whatever tokens they have (none, pre-admission) stand."""
        now = time.perf_counter()
        with self._cond:
            expired = [p for p in self._ready
                       if p.req.deadline and now > p.req.deadline]
            for p in expired:
                self._ready.remove(p)
        for p in expired:
            self._finish_terminal(p.req, "deadline_exceeded", where="queued")

    def _expire_slots(self) -> None:
        """Evict live requests whose deadline passed mid-decode; their
        partial output stands, the slot and pages recycle immediately."""
        now = time.perf_counter()
        for s in range(self.slots):
            r = self._slot_req[s]
            if r is not None and r.deadline and now > r.deadline:
                self._release_slot(s)
                self._finish_terminal(r, "deadline_exceeded", where="slot")

    def _finish_terminal(self, r: Request, status: str, *, where: str = "",
                         error: str = "") -> None:
        """Move a request to a non-ok terminal state."""
        r.status = status
        if error:
            r.error = error
        r.done = True
        r.finish_time = time.perf_counter()
        self._finished.append(r)
        self._finish_obs(r)
        ev = {"uid": r.uid, "tokens": len(r.out_tokens)}
        if where:
            ev["where"] = where
        if error:
            ev["error"] = error[:200]
        self._event(status, **ev)

    def _surface_cache_errors(self) -> None:
        """Turn disk-cache corruption the CompilationCache absorbed (torn
        or unreadable entries treated as misses) into engine events so
        every injected cache fault has a visible recovery record."""
        errs = int(self._disk_err_ctr.value)
        if errs > self._disk_errors_seen:
            self._event("cache_corruption_recovered",
                        count=errs - self._disk_errors_seen)
            self._disk_errors_seen = errs

    def _admit(self, params) -> List[Tuple[int, int]]:
        """Fill free slots from the prepared queue; returns the
        (uid, first_token) pairs emitted by the prefills (a retried
        request's replayed first token is verified, not re-emitted)."""
        emitted: List[Tuple[int, int]] = []
        self._drain_prep()
        self._expire_queued()
        self._surface_cache_errors()
        while self._free_slots:
            with self._cond:
                idx = self._pick_candidate()
                if idx is None:
                    break
                prep = self._ready[idx]
                del self._ready[idx]
            pages = self._pool.alloc(prep.n_pages)
            if pages is None:
                # allocation failed after can_alloc said yes (injected fault
                # or a raced pool): defer, don't crash — the request goes
                # back to the queue head and retries next admission phase
                with self._cond:
                    self._ready.appendleft(prep)
                self._event("alloc_failed", uid=prep.req.uid,
                            pages=prep.n_pages,
                            free_pages=self._pool.free_pages)
                break
            slot = self._free_slots.pop(0)
            r = prep.req
            r.slot = slot
            # queue wait closes at admission: stamped retroactively from
            # the submit-side timestamp (submit and admission run on
            # different threads, so this cannot be a ``with`` block)
            now = time.perf_counter()
            self._h_queue.observe(now - r.submit_time)
            obs_trace.span_at("serve.queue", r.submit_time, now, uid=r.uid)
            row = np.full(self._pps, self._garbage[slot], np.int32)
            row[: len(pages)] = pages
            self._page_table[slot] = row
            self._slot_pages[slot] = pages
            self._slot_req[slot] = r
            self._slot_eff[slot] = prep.eff_new
            with obs_trace.span("serve.prefill", uid=r.uid,
                                bucket=prep.bucket, slot=slot):
                t_pf = time.perf_counter()
                fn = self._get_prefill(prep.bucket, params)
                tok, self._pk, self._pv = fn(
                    params, jnp.asarray(prep.tokens), jnp.int32(prep.plen),
                    jnp.asarray(row), self._pk, self._pv)
                first = int(tok)
            self._h_prefill.observe(time.perf_counter() - t_pf)
            self._pos[slot] = prep.plen
            self._last[slot] = first
            replay = r.replay_len
            if replay > 0:
                # retried incarnation: the prefill token was already emitted
                # before the failure — verify, don't re-emit (exactly-once)
                if first != r.out_tokens[0]:
                    raise RuntimeError(
                        f"exactly-once violated on retry of request {r.uid}: "
                        f"replayed prefill token {first} != recorded "
                        f"{r.out_tokens[0]}")
                self._slot_emitted[slot] = 1
                self._slot_replay[slot] = replay
                self._event("admit", uid=r.uid, slot=slot, bucket=prep.bucket,
                            retry=r.retries, replay=replay,
                            queue_depth=len(self._ready))
            else:
                r.first_token_time = time.perf_counter()
                r.out_tokens.append(first)
                self._tokens_out += 1
                self._slot_emitted[slot] = 1
                self._slot_replay[slot] = 0
                self._event("admit", uid=r.uid, slot=slot, bucket=prep.bucket,
                            queue_depth=len(self._ready))
                emitted.append((r.uid, first))
                if first == r.sampling.eos_id or len(r.out_tokens) >= prep.eff_new:
                    self._evict(slot)
        return emitted

    def _release_slot(self, slot: int) -> None:
        """Return a slot's pages to the pool and reset its decode state;
        says nothing about the request's fate (callers finish or requeue)."""
        self._pool.release(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self._slot_req[slot] = None
        self._page_table[slot] = self._garbage[slot]
        self._pos[slot] = 0
        self._last[slot] = 0
        self._slot_eff[slot] = 0
        self._slot_emitted[slot] = 0
        self._slot_replay[slot] = 0
        self._free_slots.append(slot)
        self._free_slots.sort()

    def _evict(self, slot: int) -> None:
        r = self._slot_req[slot]
        r.done = True
        r.finish_time = time.perf_counter()
        self._release_slot(slot)
        self._finished.append(r)
        self._finish_obs(r)
        self._event("finish", uid=r.uid, slot=slot,
                    queue_depth=len(self._ready),
                    free_pages=self._pool.free_pages)

    def _on_step_failure(self, live: List[int], exc: BaseException) -> None:
        """Crash-safe decode recovery: release only the affected slots and
        requeue their requests (front of queue, bounded by ``max_retries``);
        healthy slots are untouched and simply redo the step.  Nothing was
        committed for the failed step — KV pages, positions and output all
        update only after a successful step — so the retried incarnation
        replays deterministically from its prefill."""
        payload = getattr(exc, "payload", None) or {}
        affected = payload.get("slots")
        affected = [s for s in (live if affected is None else affected)
                    if 0 <= s < self.slots and self._slot_req[s] is not None]
        self._event("device_step_failed", slots=list(affected),
                    error=repr(exc)[:200])
        for s in affected:
            r = self._slot_req[s]
            self._release_slot(s)
            r.retries += 1
            self._retries_total += 1
            if r.retries > self.config.max_retries:
                self._finish_terminal(
                    r, "failed",
                    error=f"retries exhausted after device-step failure: {exc!r}")
                self._event("retry_exhausted", uid=r.uid, retries=r.retries)
                continue
            r.replay_len = len(r.out_tokens)
            r.slot = -1
            prep = self._prepare(r)
            with self._cond:
                self._ready.appendleft(prep)
            self._event("requeue", uid=r.uid, retries=r.retries,
                        replay=r.replay_len)

    # ----------------------------------------------------------- the loop
    def _serve(self, params, max_steps: int) -> Iterator[Tuple[int, int]]:
        """The core loop, as a generator of (uid, token).  ``max_steps``
        bounds *decode steps* (legacy semantics)."""
        if params is None:
            raise ValueError("no params: pass params= to run()/generate() "
                             "or construct the engine with params=")
        self._warm_start(params)
        steps = 0
        stall = 0
        while steps < max_steps:
            for out in self._admit(params):
                yield out
            self._expire_slots()
            live = [s for s in range(self.slots) if self._slot_req[s] is not None]
            if not live:
                with self._cond:
                    pending = bool(self._ready) or self._n_prepared < self._n_submitted
                if not pending:
                    break
                # nothing live but work queued: admission normally succeeds
                # next pass (submit() guarantees every request fits an empty
                # pool), but injected allocation faults can starve it — spin
                # with a tiny sleep and fail fast rather than hang forever
                stall += 1
                if stall > 20_000:
                    raise RuntimeError(
                        "admission stalled: queued work cannot be admitted "
                        f"(free_pages={self._pool.free_pages})")
                if stall > 1:
                    time.sleep(0.0002)
                continue
            stall = 0
            t0 = time.perf_counter()
            try:
                faults.check("serve.decode_step",
                             step=self._steps, n_live=len(live))
                with obs_trace.span("serve.decode_step", step=self._steps,
                                    n_live=len(live)):
                    nxt, pk, pv = self._decode_fn(
                        params, self._pk, self._pv,
                        jnp.asarray(self._page_table), jnp.asarray(self._pos),
                        jnp.asarray(self._last))
                    nxt = np.asarray(nxt)
            except Exception as e:  # noqa: BLE001 — device-step crash:
                # nothing was committed (pages/pos/output update below, only
                # on success); recover the affected slots and carry on
                self._on_step_failure(live, e)
                continue
            self._pk, self._pv = pk, pv
            self._h_decode.observe(time.perf_counter() - t0)
            steps += 1
            self._steps += 1
            self._live_steps += len(live)
            if not self._decode_warm:
                self._decode_warm = True
                self._compile_log.append({
                    "kind": "decode", "slots": self.slots,
                    "kv_window": self._kv_window,
                    "first_call_s": time.perf_counter() - t0})
            for s in live:
                r = self._slot_req[s]
                tok = int(nxt[s])
                self._pos[s] += 1
                self._last[s] = tok
                idx = int(self._slot_emitted[s])
                self._slot_emitted[s] = idx + 1
                if idx < self._slot_replay[s]:
                    # replaying pre-failure output on a retried request:
                    # greedy decode is deterministic, so the regenerated
                    # token must equal the recorded one — verify, suppress
                    if tok != r.out_tokens[idx]:
                        raise RuntimeError(
                            f"exactly-once violated on retry of request "
                            f"{r.uid}: replayed token {tok} at index {idx} "
                            f"!= recorded {r.out_tokens[idx]}")
                    continue
                r.out_tokens.append(tok)
                self._tokens_out += 1
                yield (r.uid, tok)
                if tok == r.sampling.eos_id or len(r.out_tokens) >= self._slot_eff[s]:
                    self._evict(s)

    def run(self, params=None, max_steps: int = 256) -> List[Request]:
        """Serve until the queue drains (or ``max_steps`` decode steps);
        returns the requests that finished during this call."""
        params = params if params is not None else self._params
        start = len(self._finished)
        for _ in self._serve(params, max_steps):
            pass
        return self._finished[start:]

    def generate(self, prompts: Iterable[Any], *, params=None,
                 sampling: Optional[SamplingParams] = None,
                 max_steps: int = 100_000) -> Iterator[Tuple[int, int]]:
        """Streaming API: submit ``prompts`` (token-id sequences) and
        return an iterator of (uid, token) pairs in emission order.
        Uids are assigned in prompt order starting from the engine's
        running counter; tokens include each request's prefill token."""
        params = params if params is not None else self._params
        for pr in prompts:
            sp = (dataclasses.replace(sampling) if sampling is not None
                  else SamplingParams())
            uid = self._next_uid
            self.submit(Request(uid=uid, prompt=np.asarray(pr, np.int32),
                                sampling=sp))
        return self._serve(params, max_steps)

    # ------------------------------------------------------- introspection
    def cache_stats(self) -> stripe_cache.CacheStats:
        """True hit/miss traffic over compile-bucket and stripe-program
        lookups (every admission does a real keyed cache lookup)."""
        return self._compile_cache.stats

    def compile_log(self) -> List[Dict[str, Any]]:
        """One record per cold compile: prefill buckets, decode program
        build, first decode call."""
        return list(self._compile_log)

    def compile_records(self) -> Dict[str, CompileRecord]:
        """Stripe ``CompileRecord`` per compiled block program (fusion
        groups, kernel counts, per-block backends and fallbacks), keyed
        ``decode/<block>`` and ``prefill_L<bucket>/<block>``."""
        return dict(self._records)

    def events(self) -> List[Dict[str, Any]]:
        """Admission/eviction/fault-recovery event log (used by tests and
        benches for slot-reuse, utilization and resilience accounting)."""
        return list(self._events)

    def shed(self) -> List[Request]:
        """Requests rejected by the bounded queue (``status == "shed"``);
        they never entered the engine and are not in ``run()``'s result."""
        return list(self._shed_reqs)

    def quarantine_entries(self) -> Dict[str, Dict[str, Any]]:
        """Active + historical compile-quarantine entries keyed by the
        prefill cache key (see ``QuarantineStore``)."""
        return {k: e.as_dict() for k, e in self._quarantine.entries().items()}

    def metrics(self) -> Dict[str, Any]:
        """Engine health summary (legacy dict shape, plus
        ``dropped_events`` — events lost to the bounded ring buffer)."""
        self._sync_registry()
        steps = max(self._steps, 1)
        by_status: Dict[str, int] = {}
        for r in self._finished:
            by_status[r.status] = by_status.get(r.status, 0) + 1
        return {
            "decode_steps": self._steps,
            "tokens_out": self._tokens_out,
            "finished": len(self._finished),
            "finished_by_status": by_status,
            "shed": len(self._shed_reqs),
            "retries": self._retries_total,
            "prep_restarts": self._prep_restarts,
            "quarantined": sum(1 for e in self._quarantine.entries().values()
                               if not e.expired),
            "slot_utilization": self._live_steps / (steps * self.slots),
            "free_pages": self._pool.free_pages,
            "queue_depth": len(self._ready),
            "dropped_events": self._dropped_events,
        }

    def _sync_registry(self) -> None:
        """Fold the plain-int hot-path counters into the obs registry so a
        snapshot reflects current state.  Hot paths deliberately bump bare
        ints; this reconciles them lazily at observation time."""
        reg = self._obs
        steps = max(self._steps, 1)
        reg.counter("serve.decode_steps").set(self._steps)
        reg.counter("serve.tokens_out").set(self._tokens_out)
        reg.counter("serve.retries").set(self._retries_total)
        reg.counter("serve.prep_restarts").set(self._prep_restarts)
        reg.counter("serve.shed").set(len(self._shed_reqs))
        by_status: Dict[str, int] = {}
        for r in self._finished:
            by_status[r.status] = by_status.get(r.status, 0) + 1
        for status, n in by_status.items():
            reg.counter("serve.finished", status=status).set(n)
        reg.gauge("serve.slot_utilization").set(
            self._live_steps / (steps * self.slots))
        reg.gauge("serve.free_pages").set(self._pool.free_pages)
        reg.gauge("serve.queue_depth").set(len(self._ready))
        reg.gauge("serve.dropped_events").set(self._dropped_events)

    def metrics_registry(self) -> obs_metrics.Registry:
        """The engine's private metrics registry (counters per event type,
        latency histograms ``serve.{queue_wait,prefill,decode_step,request}_s``)."""
        self._sync_registry()
        return self._obs

    def metrics_snapshot(self) -> Dict[str, Any]:
        """Deterministic snapshot of the engine registry: event counters,
        gauges, and the four latency histograms."""
        self._sync_registry()
        return self._obs.snapshot()
