"""Batched serving engine: wave-batched prefill + lockstep decode.

Requests are grouped into fixed-size waves; each wave's prompts are
left-padded to a common length, prefilled in one jit'd call, then decoded
in lockstep (one token per engine step for every sequence).  Finished
sequences are masked out; the wave retires when all finish, and the next
wave is admitted.  All shapes are static, so the prefill and decode steps
compile exactly once per (batch, length) bucket.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import cache as stripe_cache


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (plen,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, batch_slots: int, max_len: int,
                 compile_cache: Optional[stripe_cache.CompilationCache] = None):
        self.model = model
        self.cfg = model.cfg
        self.slots = batch_slots
        self.max_len = max_len
        self._queue: List[Request] = []
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        # per-(slots, prompt-length) bucket compile log: jax.jit compiles
        # once per static shape; the compilation cache tracks which buckets
        # are warm and how long each cold bucket's first trace took, so the
        # serving path reports real hit/miss traffic.
        self._compile_cache = (compile_cache if compile_cache is not None
                               else stripe_cache.CompilationCache(capacity=64, use_disk=False))
        self._compile_log: List[Dict[str, Any]] = []

    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def cache_stats(self) -> stripe_cache.CacheStats:
        """Hit/miss stats over (batch, length) compile buckets."""
        return self._compile_cache.stats

    def compile_log(self) -> List[Dict[str, Any]]:
        """One record per cold bucket: shapes + first-call (compile) time."""
        return list(self._compile_log)

    def _bucket(self, plen: int) -> str:
        return stripe_cache.content_key(
            "serve_bucket", getattr(self.cfg, "name", ""), self.slots, plen)

    def _next_wave(self) -> List[Request]:
        wave = self._queue[: self.slots]
        self._queue = self._queue[self.slots :]
        return wave

    def run(self, params, max_steps: int = 256) -> List[Request]:
        finished: List[Request] = []
        steps = 0
        while self._queue and steps < max_steps:
            wave = self._next_wave()
            # pad the wave to full slots by repeating the last request's
            # prompt (masked out of results)
            prompts = [r.prompt for r in wave]
            while len(prompts) < self.slots:
                prompts.append(prompts[-1])
            plen = max(len(p) for p in prompts)
            toks = np.zeros((self.slots, plen), np.int32)
            for i, p in enumerate(prompts):
                toks[i, plen - len(p):] = p  # left-align end-of-prompt

            cache = self.model.init_cache(self.slots, self.max_len)
            batch = {"tokens": jnp.asarray(toks)}
            if self.cfg.frontend == "patches":
                batch["patches"] = jnp.zeros((self.slots, self.cfg.frontend_len, self.cfg.d_model), jnp.dtype(self.cfg.dtype))
            if self.cfg.frontend == "frames":
                batch["frames"] = jnp.zeros((self.slots, plen, self.cfg.d_model), jnp.dtype(self.cfg.dtype))
            bucket = self._bucket(plen)
            cold = self._compile_cache.get_memory(bucket) is None
            t0 = time.perf_counter()
            logits, cache = self._prefill(params, batch, cache)
            jax.block_until_ready(logits)
            if cold:
                rec = {"slots": self.slots, "plen": plen,
                       "first_call_s": time.perf_counter() - t0}
                self._compile_cache.put_memory(bucket, rec)
                self._compile_log.append(rec)
            last = np.asarray(jnp.argmax(logits[:, -1, : self.cfg.vocab], axis=-1))
            live = np.array([i < len(wave) for i in range(self.slots)])
            for i, r in enumerate(wave):
                r.out_tokens.append(int(last[i]))

            while any(live[: len(wave)]) and steps < max_steps:
                steps += 1
                logits, cache = self._decode(params, cache, jnp.asarray(last[:, None], jnp.int32))
                last = np.asarray(jnp.argmax(logits[:, -1, : self.cfg.vocab], axis=-1))
                for i, r in enumerate(wave):
                    if not live[i]:
                        continue
                    tok = int(last[i])
                    r.out_tokens.append(tok)
                    if tok == r.eos_id or len(r.out_tokens) >= r.max_new_tokens:
                        r.done = True
                        live[i] = False
                        finished.append(r)
        return finished
