"""Serving: continuous-batching engine on stripe_jit + the wave baseline."""
from .engine import ServingEngine
from .request import EngineConfig, Request, SamplingParams
from .wave import WaveEngine

__all__ = ["ServingEngine", "WaveEngine", "Request", "SamplingParams",
           "EngineConfig"]
