"""The legacy wave-batched engine, kept as the serving baseline.

Requests are grouped into fixed-size waves; each wave's prompts are
left-padded to a common length, prefilled in one jit'd call, then decoded
in lockstep (one token per engine step for every sequence).  Finished
sequences are masked out; **the wave retires only when all of its
sequences finish**, and only then is the next wave admitted — the slot
bubbles this creates under mixed generation lengths are exactly what the
continuous-batching :class:`~repro.serving.engine.ServingEngine` removes.
The traffic benches compare the two head-to-head.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import cache as stripe_cache
from .request import Request


class WaveEngine:
    """Lockstep wave engine (``jax.jit`` directly on the model)."""

    def __init__(self, model, batch_slots: int, max_len: int,
                 compile_cache: Optional[stripe_cache.CompilationCache] = None):
        self.model = model
        self.cfg = model.cfg
        self.slots = batch_slots
        self.max_len = max_len
        self._queue: List[Request] = []
        self._queue_lock = threading.Lock()  # open-loop drivers submit from a feeder thread
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        # (batch, length) compile buckets: jax.jit compiles once per static
        # shape; real entries (first-call records) are keyed in the
        # compilation cache so hit/miss stats reflect bucket traffic.
        self._compile_cache = (compile_cache if compile_cache is not None
                               else stripe_cache.CompilationCache(capacity=64, use_disk=False))
        self._compile_log: List[Dict[str, Any]] = []

    def submit(self, req: Request) -> None:
        req.submit_time = time.perf_counter()
        with self._queue_lock:
            self._queue.append(req)

    def cache_stats(self) -> stripe_cache.CacheStats:
        """Hit/miss stats over (batch, length) compile buckets."""
        return self._compile_cache.stats

    def compile_log(self) -> List[Dict[str, Any]]:
        """One record per cold bucket: shapes + first-call (compile) time."""
        return list(self._compile_log)

    def _bucket(self, plen: int) -> str:
        return stripe_cache.content_key(
            "serve_bucket", getattr(self.cfg, "name", ""), self.slots, plen)

    def _next_wave(self) -> List[Request]:
        with self._queue_lock:
            wave = self._queue[: self.slots]
            self._queue = self._queue[self.slots :]
        return wave

    def run(self, params, max_steps: int = 256) -> List[Request]:
        finished: List[Request] = []
        steps = 0
        while self._queue and steps < max_steps:
            wave = self._next_wave()
            # pad the wave to full slots by repeating the last request's
            # prompt (masked out of results)
            prompts = [r.prompt for r in wave]
            while len(prompts) < self.slots:
                prompts.append(prompts[-1])
            plen = max(len(p) for p in prompts)
            toks = np.zeros((self.slots, plen), np.int32)
            for i, p in enumerate(prompts):
                toks[i, plen - len(p):] = p  # left-align end-of-prompt
            cache = self.model.init_cache(self.slots, self.max_len)
            batch = {"tokens": jnp.asarray(toks)}
            if self.cfg.frontend == "patches":
                batch["patches"] = jnp.zeros((self.slots, self.cfg.frontend_len, self.cfg.d_model), jnp.dtype(self.cfg.dtype))
            if self.cfg.frontend == "frames":
                batch["frames"] = jnp.zeros((self.slots, plen, self.cfg.d_model), jnp.dtype(self.cfg.dtype))
            bucket = self._bucket(plen)
            cold = self._compile_cache.get_memory(bucket) is None
            t0 = time.perf_counter()
            logits, cache = self._prefill(params, batch, cache)
            jax.block_until_ready(logits)
            if cold:
                rec = {"slots": self.slots, "plen": plen,
                       "first_call_s": time.perf_counter() - t0}
                self._compile_cache.put_memory(bucket, rec)
                self._compile_log.append(rec)
            last = np.asarray(jnp.argmax(logits[:, -1, : self.cfg.vocab], axis=-1))
            live = np.array([i < len(wave) for i in range(self.slots)])
            now = time.perf_counter()
            for i, r in enumerate(wave):
                r.out_tokens.append(int(last[i]))
                r.first_token_time = now

            while any(live[: len(wave)]) and steps < max_steps:
                steps += 1
                logits, cache = self._decode(params, cache, jnp.asarray(last[:, None], jnp.int32))
                last = np.asarray(jnp.argmax(logits[:, -1, : self.cfg.vocab], axis=-1))
                now = time.perf_counter()
                for i, r in enumerate(wave):
                    if not live[i]:
                        continue
                    tok = int(last[i])
                    r.out_tokens.append(tok)
                    if tok == r.sampling.eos_id or len(r.out_tokens) >= r.sampling.max_new_tokens:
                        r.done = True
                        r.finish_time = now
                        live[i] = False
                        finished.append(r)
        return finished
