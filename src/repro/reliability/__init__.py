"""Reliability: deterministic fault injection + the serving resilience
vocabulary shared by the engine, the compile driver, the cache, and the
training loop."""
from . import faults
from .faults import (FaultPlan, FaultRule, InjectedFault, fail_every,
                     fail_nth, fail_prob, fail_when, inject)

__all__ = ["faults", "FaultPlan", "FaultRule", "InjectedFault", "inject",
           "fail_nth", "fail_every", "fail_prob", "fail_when"]
