"""Deterministic, seedable fault injection for the serving/compile stack.

Failures in this repo originate at a small number of places — the disk
cache, a ``stripe_jit`` compile, a per-bucket prefill compile, the decode
device step, the serving prep thread, page allocation, a train step.
Each of those places is a **named injection site**: production code calls
:func:`check` (or :func:`fires`) with the site name and a little context,
which is a no-op unless a :class:`FaultPlan` is installed.  Tests and
benchmarks script failure sequences by installing plans through the
:func:`inject` context manager:

    with faults.inject(faults.fail_nth("serve.decode_step", 3)) as plan:
        engine.run(params)
    assert plan.fired()          # what fired, in order, with context

Triggers compose (AND semantics within one rule): fail the Nth hit
(``nth=``), every K-th hit (``every=``), with probability ``p`` under a
seed (``prob=``/``seed=`` — the random stream is owned by the rule, so
the same plan over the same hit sequence fires identically every run),
under a context predicate (``when=``), and at most ``times`` total.

Two call styles at a site:

* :func:`check` **raises** :class:`InjectedFault` when a rule fires — for
  sites whose real failure mode is an exception (compile, device step).
* :func:`fires` **returns True** when a rule fires — for sites where the
  caller simulates a specific corruption instead of raising (e.g. the
  cache tearing a disk write).

Plans are process-global (a lock-guarded stack, *not* thread-local) so
that faults scripted by a test thread are observed by the engine's prep
thread and by pool workers in the same process.

``repro.train.loop.FaultInjector`` is a thin compat shim over
:class:`FaultPlan`; training and serving share this one vocabulary.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import random
import threading
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterator, List, Optional

__all__ = ["SITES", "InjectedFault", "FaultRule", "FaultPlan", "inject",
           "check", "fires", "active_plans", "fail_nth", "fail_every",
           "fail_prob", "fail_when"]

# Registered injection sites: where failures actually originate.  check()
# rejects unknown site names so a typo'd site can never silently never
# fire; rules may use fnmatch patterns (e.g. "serve.*") over these names.
SITES: Dict[str, str] = {
    "cache.disk_read": "CompilationCache.get_disk: the entry read raises (I/O error)",
    "cache.disk_write": "CompilationCache.put_disk: the write raises; entry is lost",
    "cache.disk_write_torn": "CompilationCache.put_disk: a torn (truncated) entry "
                             "lands on disk, as a non-atomic writer would leave",
    "compile.stripe_jit": "driver._lower: the Pallas lowering of a stripe_jit "
                          "compile raises (quarantined by the driver)",
    "serve.prefill_compile": "ServingEngine._get_prefill: building a prompt "
                             "bucket's compiled step raises (bucket quarantined)",
    "serve.decode_step": "ServingEngine._serve: the jitted decode step raises "
                         "(affected slots evicted + requeued)",
    "serve.prep": "ServingEngine._prep_loop: preparing one request raises "
                  "(that request fails; the thread survives)",
    "serve.prep_thread": "ServingEngine._prep_loop: the prep thread itself dies "
                         "(supervisor restarts it; in-flight request fails)",
    "paged.alloc": "PagePool.alloc: page allocation fails transiently "
                   "(admission retries later instead of crashing)",
    "train.step": "Trainer.run: a train step raises (simulated preemption)",
}


class InjectedFault(RuntimeError):
    """Raised by :func:`check` when a rule fires.  Subclasses
    ``RuntimeError`` so pre-framework handlers (``run_with_restarts``)
    keep working.  ``payload`` carries rule-scripted data the recovery
    path may consult (e.g. which slots a device fault affected)."""

    def __init__(self, site: str, ctx: Optional[Dict[str, Any]] = None,
                 payload: Optional[Dict[str, Any]] = None):
        super().__init__(f"injected fault at {site}")
        self.site = site
        self.ctx = dict(ctx or {})
        self.payload = dict(payload or {})


@dataclasses.dataclass
class FaultRule:
    """One scheduled trigger on one site (or fnmatch site pattern).

    All provided conditions must hold for a hit to fire; a rule with no
    conditions fires on every hit (bounded by ``times``).  ``nth`` is
    1-based over the rule's own hit count.
    """

    site: str
    nth: Optional[int] = None
    every: Optional[int] = None
    prob: Optional[float] = None
    seed: int = 0
    times: Optional[int] = 1
    when: Optional[Callable[[Dict[str, Any]], bool]] = None
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)
    hits: int = 0
    fired: int = 0

    def __post_init__(self) -> None:
        if not any(ch in self.site for ch in "*?[") and self.site not in SITES:
            raise KeyError(f"unknown injection site {self.site!r}; known sites: "
                           f"{sorted(SITES)}")
        if self.prob is not None and not (0.0 <= self.prob <= 1.0):
            raise ValueError(f"prob must be in [0, 1], got {self.prob}")
        # The rule owns its random stream: deterministic under (seed, site)
        # regardless of what other rules/sites consume.
        self._rng = random.Random(f"{self.seed}:{self.site}")

    def matches(self, site: str) -> bool:
        return fnmatch.fnmatchcase(site, self.site)

    def should_fire(self, ctx: Dict[str, Any]) -> bool:
        """Advance this rule's hit counter and decide.  Callers hold the
        plan lock; the rule itself is not separately synchronized."""
        self.hits += 1
        # the probability stream advances on every hit, fired or not, so
        # later conditions cannot perturb it
        draw = self._rng.random() if self.prob is not None else None
        if self.times is not None and self.fired >= self.times:
            return False
        if self.nth is not None and self.hits != self.nth:
            return False
        if self.every is not None and self.hits % self.every != 0:
            return False
        if draw is not None and draw >= self.prob:
            return False
        if self.when is not None and not self.when(ctx):
            return False
        self.fired += 1
        return True


def fail_nth(site: str, nth: int, **kw: Any) -> FaultRule:
    """Fire on exactly the ``nth`` (1-based) hit of ``site``."""
    return FaultRule(site, nth=nth, **kw)


def fail_every(site: str, every: int, times: Optional[int] = None, **kw: Any) -> FaultRule:
    """Fire on every ``every``-th hit (unbounded unless ``times`` given)."""
    return FaultRule(site, every=every, times=times, **kw)


def fail_prob(site: str, prob: float, seed: int = 0,
              times: Optional[int] = None, **kw: Any) -> FaultRule:
    """Fire each hit with probability ``prob``, deterministically under
    ``seed`` (same plan + same hit order = same firings)."""
    return FaultRule(site, prob=prob, seed=seed, times=times, **kw)


def fail_when(site: str, when: Callable[[Dict[str, Any]], bool], **kw: Any) -> FaultRule:
    """Fire when ``when(ctx)`` is true for the hit's context."""
    return FaultRule(site, when=when, **kw)


class FaultPlan:
    """A set of rules plus the log of everything that fired.

    Thread-safe: the engine hits sites from the serve thread, the prep
    thread, and (for cache sites) pool workers concurrently.
    """

    def __init__(self, rules: Optional[List[FaultRule]] = None):
        self.rules: List[FaultRule] = list(rules or [])
        self._log: List[Dict[str, Any]] = []
        self._lock = threading.Lock()

    def add(self, rule: FaultRule) -> "FaultPlan":
        with self._lock:
            self.rules.append(rule)
        return self

    def _decide(self, site: str, ctx: Dict[str, Any]) -> Optional[FaultRule]:
        with self._lock:
            for rule in self.rules:
                if rule.matches(site) and rule.should_fire(ctx):
                    self._log.append({
                        "seq": len(self._log), "site": site,
                        "ctx": {k: v for k, v in ctx.items()
                                if isinstance(v, (str, int, float, bool))},
                        "hit": rule.hits})
                    return rule
        return None

    def hit(self, site: str, **ctx: Any) -> None:
        """Raise :class:`InjectedFault` if any rule fires for this hit."""
        rule = self._decide(site, ctx)
        if rule is not None:
            raise InjectedFault(site, ctx, rule.payload)

    def query(self, site: str, **ctx: Any) -> bool:
        """Non-raising form of :meth:`hit` (for simulated-corruption sites)."""
        return self._decide(site, ctx) is not None

    def fired(self) -> List[Dict[str, Any]]:
        """Everything that fired, in order: {seq, site, ctx, hit}."""
        with self._lock:
            return [dict(e) for e in self._log]

    def fired_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for e in self.fired():
            counts[e["site"]] = counts.get(e["site"], 0) + 1
        return counts


# ------------------------------------------------------------- global stack
_ACTIVE: List[FaultPlan] = []
_STACK_LOCK = threading.Lock()


def active_plans() -> List[FaultPlan]:
    with _STACK_LOCK:
        return list(_ACTIVE)


@contextmanager
def inject(*rules_or_plan: Any) -> Iterator[FaultPlan]:
    """Install a plan (or build one from rules) for the dynamic extent of
    the ``with`` block.  Nested injections stack; every active plan sees
    every hit."""
    if len(rules_or_plan) == 1 and isinstance(rules_or_plan[0], FaultPlan):
        plan = rules_or_plan[0]
    else:
        plan = FaultPlan([r for r in rules_or_plan])
    with _STACK_LOCK:
        _ACTIVE.append(plan)
    try:
        yield plan
    finally:
        with _STACK_LOCK:
            _ACTIVE.remove(plan)


def check(site: str, **ctx: Any) -> None:
    """Injection-site hook (raising style).  No-op without active plans;
    with plans, unknown sites are rejected and each plan may raise."""
    plans = active_plans()
    if not plans:
        return
    if site not in SITES:
        raise KeyError(f"check() on unregistered site {site!r}")
    for plan in plans:
        plan.hit(site, **ctx)


def fires(site: str, **ctx: Any) -> bool:
    """Injection-site hook (querying style): True when any active plan's
    rule fires, without raising."""
    plans = active_plans()
    if not plans:
        return False
    if site not in SITES:
        raise KeyError(f"fires() on unregistered site {site!r}")
    return any(plan.query(site, **ctx) for plan in plans)
