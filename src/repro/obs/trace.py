"""Structured tracing: zero-dependency spans exporting to Chrome trace JSON.

A *span* is a named wall-clock interval with attributes, recorded into a
process-wide bounded ring buffer.  Spans nest per thread (the tracer
keeps a thread-local stack, so each record knows its parent and depth)
and are cheap enough for serving hot paths: when tracing is disabled
(the default), ``span()`` returns a shared no-op context manager and the
cost is one attribute read; when enabled, finishing a span is one lock
acquisition and a deque append.

The buffer exports to Chrome trace-event JSON (``ph: "X"`` complete
events on the ``traceEvents`` array) loadable in Perfetto / DevTools via
:func:`export_chrome_trace`, and ``python -m repro.obs summarize`` turns
a trace file into a per-phase wall-time table.

Usage::

    from repro import obs

    obs.enable_tracing()
    with obs.trace.span("pass.fuse", program="mlp"):
        ...
    obs.export_chrome_trace("trace.json")

Cross-thread intervals that cannot be expressed as a ``with`` block on
one thread (e.g. a request's queue wait, stamped at submit on the feeder
thread and closed at admission on the serving thread) are recorded
retroactively with :func:`span_at`, passing explicit
``time.perf_counter()`` endpoints.

Enable at import time with ``STRIPE_TRACE=1`` in the environment.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional

ENV_TRACE = "STRIPE_TRACE"

#: default ring-buffer capacity (finished spans retained); beyond it the
#: oldest spans are dropped and counted in ``Tracer.dropped``
DEFAULT_CAPACITY = 200_000


class SpanRecord:
    """One finished span: name, start time and duration (seconds on the
    ``time.perf_counter`` clock), recording thread, parent span name and
    nesting depth, plus free-form attributes."""

    __slots__ = ("name", "ts", "dur", "tid", "thread", "parent", "depth",
                 "attrs", "phase")

    def __init__(self, name: str, ts: float, dur: float, tid: int,
                 thread: str, parent: str = "", depth: int = 0,
                 attrs: Optional[Dict[str, Any]] = None, phase: str = "X"):
        self.name = name
        self.ts = ts
        self.dur = dur
        self.tid = tid
        self.thread = thread
        self.parent = parent
        self.depth = depth
        self.attrs = attrs or {}
        self.phase = phase  # "X" complete span | "i" instant

    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name, "ts": self.ts, "dur": self.dur,
                "tid": self.tid, "thread": self.thread, "parent": self.parent,
                "depth": self.depth, "attrs": dict(self.attrs),
                "phase": self.phase}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"SpanRecord({self.name!r}, dur={self.dur * 1e3:.3f}ms, "
                f"depth={self.depth}, attrs={self.attrs})")


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


_NULL = _NullSpan()


class _Span:
    """A live span (context manager).  ``set(**attrs)`` attaches
    attributes discovered mid-span (e.g. which cache level hit)."""

    __slots__ = ("_tracer", "name", "attrs", "_t0", "_parent", "_depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> "_Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        stack = self._tracer._stack()
        self._parent = stack[-1] if stack else ""
        self._depth = len(stack)
        stack.append(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur = time.perf_counter() - self._t0
        stack = self._tracer._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._tracer._record(SpanRecord(
            self.name, self._t0, dur, threading.get_ident(),
            threading.current_thread().name, self._parent, self._depth,
            self.attrs))
        return False


class Tracer:
    """Process-wide span recorder: a bounded ring buffer of finished
    spans, thread-safe, with Chrome trace-event export."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 enabled: Optional[bool] = None):
        self.enabled = (bool(os.environ.get(ENV_TRACE))
                        if enabled is None else enabled)
        self.capacity = int(capacity)
        self.dropped = 0
        self._lock = threading.Lock()
        self._spans: "deque[SpanRecord]" = deque(maxlen=self.capacity)
        self._local = threading.local()
        self.epoch = time.perf_counter()

    # ------------------------------------------------------------- control
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0
            self.epoch = time.perf_counter()

    # ----------------------------------------------------------- recording
    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self._spans) == self.capacity:
                self.dropped += 1
            self._spans.append(rec)

    def span(self, name: str, **attrs):
        """Context manager timing a block as one span.  No-op (and
        allocation-free) while tracing is disabled."""
        if not self.enabled:
            return _NULL
        return _Span(self, name, attrs)

    def span_at(self, name: str, start_s: float, end_s: float, **attrs) -> None:
        """Record a span with explicit ``time.perf_counter`` endpoints —
        for intervals that start and end on different threads (a
        request's queue wait) or are reconstructed after the fact."""
        if not self.enabled:
            return
        self._record(SpanRecord(
            name, start_s, max(0.0, end_s - start_s), threading.get_ident(),
            threading.current_thread().name, "", 0, attrs))

    def instant(self, name: str, **attrs) -> None:
        """Record a zero-duration marker event."""
        if not self.enabled:
            return
        stack = self._stack()
        self._record(SpanRecord(
            name, time.perf_counter(), 0.0, threading.get_ident(),
            threading.current_thread().name, stack[-1] if stack else "",
            len(stack), attrs, phase="i"))

    # -------------------------------------------------------------- export
    def spans(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._spans)

    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome trace-event representation (``traceEvents`` +
        metadata), timestamps in microseconds relative to the tracer
        epoch — loadable in Perfetto / ``chrome://tracing``."""
        spans = self.spans()
        # origin: the tracer epoch, or the earliest span when a retroactive
        # span_at() predates it — Perfetto rejects negative timestamps
        origin = self.epoch
        if spans:
            origin = min(origin, min(s.ts for s in spans))
        # stable small tids per thread, in first-seen order
        tid_map: Dict[int, int] = {}
        names: Dict[int, str] = {}
        events: List[Dict[str, Any]] = []
        for s in spans:
            tid = tid_map.setdefault(s.tid, len(tid_map) + 1)
            names.setdefault(tid, s.thread)
            ev = {
                "name": s.name,
                "cat": s.name.split(".", 1)[0],
                "ph": s.phase,
                "ts": round((s.ts - origin) * 1e6, 3),
                "pid": os.getpid(),
                "tid": tid,
                "args": _json_safe(s.attrs),
            }
            if s.phase == "X":
                ev["dur"] = round(s.dur * 1e6, 3)
            else:
                ev["s"] = "t"  # instant scoped to its thread
            events.append(ev)
        meta = [{"name": "thread_name", "ph": "M", "pid": os.getpid(),
                 "tid": tid, "args": {"name": name}}
                for tid, name in sorted(names.items())]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "otherData": {"tool": "repro.obs", "dropped_spans": self.dropped}}

    def export_chrome_trace(self, path) -> str:
        data = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(data, f)
        return str(path)


def _json_safe(attrs: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        else:
            out[k] = str(v)
    return out


# --------------------------------------------------------------------------
# Process-wide default tracer + module-level API
# --------------------------------------------------------------------------
_default = Tracer()


def get_tracer() -> Tracer:
    return _default


def set_tracer(tracer: Tracer) -> None:
    global _default
    _default = tracer


def span(name: str, **attrs):
    return _default.span(name, **attrs)


def span_at(name: str, start_s: float, end_s: float, **attrs) -> None:
    _default.span_at(name, start_s, end_s, **attrs)


def instant(name: str, **attrs) -> None:
    _default.instant(name, **attrs)


def enable() -> None:
    _default.enable()


def disable() -> None:
    _default.disable()


def enabled() -> bool:
    return _default.enabled


def clear() -> None:
    _default.clear()


def spans() -> List[SpanRecord]:
    return _default.spans()


def export_chrome_trace(path) -> str:
    return _default.export_chrome_trace(path)


# --------------------------------------------------------------------------
# Trace-file analysis (the `python -m repro.obs summarize` backend)
# --------------------------------------------------------------------------
def load_chrome_trace(path) -> List[Dict[str, Any]]:
    with open(path) as f:
        data = json.load(f)
    events = data["traceEvents"] if isinstance(data, dict) else data
    return [e for e in events if e.get("ph") in ("X", "i")]


def summarize_events(events: Iterable[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Aggregate complete events per span name: count, total/mean/max
    wall ms — sorted by total time descending."""
    agg: Dict[str, Dict[str, float]] = {}
    for e in events:
        if e.get("ph") != "X":
            continue
        a = agg.setdefault(e["name"], {"count": 0, "total_us": 0.0, "max_us": 0.0})
        a["count"] += 1
        a["total_us"] += float(e.get("dur", 0.0))
        a["max_us"] = max(a["max_us"], float(e.get("dur", 0.0)))
    rows = []
    for name, a in agg.items():
        rows.append({
            "name": name, "count": int(a["count"]),
            "total_ms": a["total_us"] / 1e3,
            "mean_ms": a["total_us"] / 1e3 / max(a["count"], 1),
            "max_ms": a["max_us"] / 1e3,
        })
    rows.sort(key=lambda r: -r["total_ms"])
    return rows


def request_breakdown(events: Iterable[Dict[str, Any]]) -> Dict[int, Dict[str, float]]:
    """Per-request serving phase breakdown from ``serve.*`` spans:
    ``{uid: {queue_s, prefill_s, decode_s, total_s}}``.  ``decode_s`` is
    the remainder of the request's lifetime after queueing and prefill
    (the batched decode steps are shared across slots, so per-request
    decode time is attributed by residual, not by step)."""
    per_uid: Dict[int, Dict[str, float]] = {}
    for e in events:
        uid = (e.get("args") or {}).get("uid")
        if uid is None or e.get("ph") != "X":
            continue
        rec = per_uid.setdefault(int(uid), {})
        dur_s = float(e.get("dur", 0.0)) / 1e6
        if e["name"] == "serve.queue":
            rec["queue_s"] = rec.get("queue_s", 0.0) + dur_s
        elif e["name"] == "serve.prefill":
            rec["prefill_s"] = rec.get("prefill_s", 0.0) + dur_s
        elif e["name"] == "serve.request":
            rec["total_s"] = dur_s
    for rec in per_uid.values():
        rec.setdefault("queue_s", 0.0)
        rec.setdefault("prefill_s", 0.0)
        rec.setdefault("total_s", rec["queue_s"] + rec["prefill_s"])
        rec["decode_s"] = max(
            0.0, rec["total_s"] - rec["queue_s"] - rec["prefill_s"])
    return per_uid
