"""Metrics registry: labeled counters, gauges and histograms.

One :class:`Registry` holds a set of named series; a series is identified
by its metric name plus a sorted label set (``counter("serve.events",
event="finish")``), Prometheus-style.  Metric objects are created on
first use and cached, so hot paths hold a reference and pay one lock +
integer add per update.

``snapshot()`` renders the whole registry as a deterministic (sorted,
JSON-able) dict — the shape the CI artifact and the back-compat shims
(``CacheStats``, ``ServingEngine.metrics()``) read.

A process-wide default registry backs the module-level helpers
(``metrics.counter(...)``); subsystems that need isolated series (one
serving engine, one compilation cache) instantiate their own Registry.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Dict, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def series_key(name: str, labels: Dict[str, Any]) -> str:
    """Printable series identity: ``name{k=v,...}`` with sorted labels."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic (by convention) cumulative count; ``set()`` exists for
    back-compat shims that assign totals directly."""

    __slots__ = ("_v", "_lock")

    def __init__(self, value: float = 0):
        self._v = value
        self._lock = threading.Lock()

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._v += n

    def set(self, v: float) -> None:
        with self._lock:
            self._v = v

    @property
    def value(self):
        return self._v


class Gauge:
    """A point-in-time value (queue depth, free pages)."""

    __slots__ = ("_v", "_lock")

    def __init__(self, value: float = 0):
        self._v = value
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = v

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self):
        return self._v


# log-scale histogram: bucket i covers (BASE**(i-1), BASE**i] seconds
# (or any unit), anchored so sub-microsecond observations land in bucket 0
_BASE = 2.0
_ANCHOR = 1e-6


class Histogram:
    """Log-scale histogram (base-2 buckets anchored at 1e-6): tracks
    count / sum / min / max exactly and percentiles to bucket resolution."""

    __slots__ = ("_lock", "count", "sum", "min", "max", "_buckets")

    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets: Dict[int, int] = {}

    @staticmethod
    def _bucket(v: float) -> int:
        if v <= _ANCHOR:
            return 0
        return max(0, int(math.ceil(math.log(v / _ANCHOR, _BASE))))

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            b = self._bucket(v)
            self._buckets[b] = self._buckets.get(b, 0) + 1

    @staticmethod
    def _quantile(buckets: Dict[int, int], count: int, hi: float, q: float) -> float:
        """Upper bound of the bucket holding the q-quantile (0..1)."""
        if count == 0:
            return 0.0
        target = q * count
        seen = 0
        for b in sorted(buckets):
            seen += buckets[b]
            if seen >= target:
                return _ANCHOR * _BASE ** b
        return hi

    def percentile(self, q: float) -> float:
        with self._lock:
            return self._quantile(self._buckets, self.count, self.max, q)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            if self.count == 0:
                return {"count": 0, "sum": 0.0, "mean": 0.0,
                        "min": 0.0, "max": 0.0, "p50": 0.0, "p99": 0.0}
            return {
                "count": self.count,
                "sum": self.sum,
                "mean": self.sum / self.count,
                "min": self.min,
                "max": self.max,
                "p50": self._quantile(self._buckets, self.count, self.max, 0.50),
                "p99": self._quantile(self._buckets, self.count, self.max, 0.99),
            }


class Registry:
    """A named set of metric series; see module docstring."""

    def __init__(self):
        self._lock = threading.Lock()
        self._series: Dict[Tuple[str, LabelKey], Any] = {}

    def _get(self, name: str, labels: Dict[str, Any], cls):
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        m = self._series.get(key)
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(
                    f"metric {series_key(name, labels)} already registered "
                    f"as {type(m).__name__}, not {cls.__name__}")
            return m
        with self._lock:
            m = self._series.get(key)
            if m is None:
                m = self._series[key] = cls()
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {series_key(name, labels)} already registered "
                    f"as {type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, labels, Gauge)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(name, labels, Histogram)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Deterministic JSON-able dump: ``{"counters": {series: value},
        "gauges": {...}, "histograms": {series: {count, sum, ...}}}``
        with series keys sorted."""
        with self._lock:
            items = list(self._series.items())
        out: Dict[str, Dict[str, Any]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        for (name, labels), m in items:
            key = series_key(name, dict(labels))
            if isinstance(m, Counter):
                out["counters"][key] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][key] = m.value
            else:
                out["histograms"][key] = m.snapshot()
        return {kind: dict(sorted(d.items())) for kind, d in out.items()}

    def reset(self) -> None:
        with self._lock:
            self._series.clear()


# --------------------------------------------------------------------------
# Process-wide default registry + module-level API
# --------------------------------------------------------------------------
_default = Registry()


def get_registry() -> Registry:
    return _default


def set_registry(reg: Optional[Registry]) -> None:
    global _default
    _default = reg if reg is not None else Registry()


def counter(name: str, **labels) -> Counter:
    return _default.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return _default.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return _default.histogram(name, **labels)


def snapshot() -> Dict[str, Dict[str, Any]]:
    return _default.snapshot()


def reset() -> None:
    _default.reset()
