"""repro.obs — unified observability: tracing, metrics, kernel profiling.

Three coordinated pieces, all zero-dependency:

- :mod:`repro.obs.trace` — structured spans recorded into a bounded ring
  buffer, exported as Chrome trace-event JSON (Perfetto-loadable).
- :mod:`repro.obs.metrics` — labeled counters / gauges / histograms with
  a deterministic snapshot; backs ``cache_stats()`` and the serving
  engine's ``metrics()`` via shims.
- :mod:`repro.obs.profile` — cost-model residual logging: profiled
  compiles append (predicted_s, measured_s) rows per lowered unit to a
  JSONL file under the cache dir.

``python -m repro.obs summarize trace.json`` renders a per-phase
wall-time table and per-request serving breakdown from a trace file.
"""
from __future__ import annotations

from . import metrics, profile, trace
from .metrics import Registry, get_registry, snapshot as metrics_snapshot
from .profile import (append_residuals, read_residuals, residual_log_path,
                      summarize_residuals)
from .trace import (Tracer, clear as clear_trace, disable as disable_tracing,
                    enable as enable_tracing, enabled as tracing_enabled,
                    export_chrome_trace, get_tracer, instant, span, span_at,
                    spans)

__all__ = [
    "trace", "metrics", "profile",
    "span", "span_at", "instant", "spans",
    "enable_tracing", "disable_tracing", "tracing_enabled", "clear_trace",
    "export_chrome_trace", "get_tracer", "Tracer",
    "Registry", "get_registry", "metrics_snapshot",
    "residual_log_path", "append_residuals", "read_residuals",
    "summarize_residuals",
]
