"""Kernel profiling support: cost-model residual logging.

``stripe_jit(..., profile=True)`` wall-times every lowered unit (a
fusion group's Pallas kernels, a jnp fallback group, or the whole
program for the reference interpreter) on dispatch and attaches the
measurements to the :class:`~repro.core.driver.CompileRecord` next to
the cost model's predicted per-unit latencies.  On the first profiled
dispatch the (predicted, measured) pairs are appended — one JSON object
per line — to a **residual log** under the compilation-cache directory:

    {"ir_fingerprint": ..., "hw_fingerprint": ..., "block": "a+b",
     "predicted_s": 1.2e-5, "measured_s": 3.4e-5, "backend": "pallas",
     "interpret": true, "hw": "tpu_v5e", "key": ..., "ts": ...}

This file is the feed for the measured-feedback tuning database
(ROADMAP item 2): rows are keyed by IR fingerprint x hardware
fingerprint, exactly the identity the compilation cache already uses, so
accumulated (predicted, measured) pairs can calibrate the roofline /
pipeline model coefficients per hardware config.

Helpers here are import-light (no jax, no core imports at module level)
so ``repro.obs`` stays dependency-free.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

RESIDUAL_LOG_NAME = "residuals.jsonl"

# Rotation: the log is compacted once it exceeds this many rows (the
# newest half is kept; older rows fold into the tuning DB's running
# residual summaries, so long-run bias statistics survive rotation).
ENV_RESIDUAL_CAP = "STRIPE_RESIDUAL_CAP"
DEFAULT_RESIDUAL_CAP = 20_000

_write_lock = threading.Lock()


def residual_cap() -> int:
    """The configured rotation cap (rows); <= 0 disables rotation."""
    try:
        return int(os.environ.get(ENV_RESIDUAL_CAP, DEFAULT_RESIDUAL_CAP))
    except ValueError:
        return DEFAULT_RESIDUAL_CAP


def residual_log_path(cache=None) -> Path:
    """Where profiled compiles append residual rows: the cache's disk
    directory when it has one, else the process default cache dir."""
    from ..core.cache import default_cache_dir

    disk_dir = getattr(cache, "disk_dir", None)
    base = Path(disk_dir) if disk_dir is not None else default_cache_dir()
    return base / RESIDUAL_LOG_NAME


def append_residuals(rows: List[Dict[str, Any]], path=None,
                     cap: Optional[int] = None, db=None) -> Optional[Path]:
    """Append rows to the residual JSONL (atomic at line granularity:
    one ``write`` of the whole batch under a process-wide lock).  I/O
    failures are swallowed — profiling must never fail the dispatch.

    Growth is bounded: past ``cap`` rows (``$STRIPE_RESIDUAL_CAP``,
    default 20k; <= 0 disables) the log rotates — the newest ``cap // 2``
    rows are kept and the older ones fold into the tuning DB next to the
    log (``db`` overrides which DB; None opens ``tuning_db.json`` in the
    log's directory), so ``python -m repro.obs residuals`` still reports
    the full history via the DB's running summaries."""
    if not rows:
        return None
    p = Path(path) if path is not None else residual_log_path()
    data = "".join(json.dumps(r, sort_keys=True) + "\n" for r in rows)
    limit = residual_cap() if cap is None else int(cap)
    try:
        with _write_lock:
            p.parent.mkdir(parents=True, exist_ok=True)
            with open(p, "a") as f:
                f.write(data)
            if limit > 0:
                _rotate_locked(p, limit, db)
    except OSError:
        return None
    return p


def _rotate_locked(p: Path, cap: int, db=None) -> None:
    """Compact the log in place once it exceeds ``cap`` rows (caller
    holds the write lock).  Never raises."""
    try:
        with open(p) as f:
            lines = [ln for ln in f if ln.strip()]
    except OSError:
        return
    if len(lines) <= cap:
        return
    keep = lines[-max(cap // 2, 1):]
    fold = lines[: len(lines) - len(keep)]
    try:
        if db is None:
            from ..tune.db import TuningDB

            db = TuningDB(dir=p.parent)
        folded_rows = []
        for ln in fold:
            try:
                folded_rows.append(json.loads(ln))
            except ValueError:
                continue
        db.fold_residuals(folded_rows)
    except Exception:
        # compaction must never fail profiling; the rows are still
        # dropped below so the log stays bounded either way
        pass
    try:
        import tempfile

        fd, tmp = tempfile.mkstemp(dir=str(p.parent), suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            f.writelines(keep)
        os.replace(tmp, p)
    except OSError:
        pass


def read_residuals(path=None) -> List[Dict[str, Any]]:
    """Load the residual log (skipping unparseable lines, e.g. a torn
    final line after a crash)."""
    p = Path(path) if path is not None else residual_log_path()
    rows: List[Dict[str, Any]] = []
    try:
        with open(p) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rows.append(json.loads(line))
                except ValueError:
                    continue
    except OSError:
        return []
    return rows


def summarize_residuals(rows: List[Dict[str, Any]],
                        folded: Optional[List[Dict[str, Any]]] = None) -> Dict[str, Any]:
    """Aggregate residual rows: count, per-backend counts, and the
    geometric-mean ratio measured/predicted where both are present (the
    cost model's systematic bias on this hardware).

    ``folded`` takes the tuning DB's running residual summaries (rows
    rotated out of the log by :func:`append_residuals`); their pair
    counts and summed log ratios merge into the live rows' statistics so
    the reported bias covers the full history, not just the log tail."""
    import math

    n = len(rows)
    backends: Dict[str, int] = {}
    log_sum = 0.0
    pairs = 0
    for r in rows:
        backends[str(r.get("backend"))] = backends.get(str(r.get("backend")), 0) + 1
        p, m = r.get("predicted_s"), r.get("measured_s")
        if p and m and p > 0 and m > 0:
            log_sum += math.log(m / p)
            pairs += 1
    folded_rows = 0
    folded_pairs = 0
    for s in folded or []:
        folded_rows += int(s.get("rows", 0))
        fp = int(s.get("pairs", 0))
        folded_pairs += fp
        log_sum += float(s.get("sum_log_ratio", 0.0))
        b = str(s.get("backend"))
        backends[b] = backends.get(b, 0) + int(s.get("rows", 0))
    total_pairs = pairs + folded_pairs
    gmean = math.exp(log_sum / total_pairs) if total_pairs else None
    return {
        "rows": n + folded_rows,
        "live_rows": n,
        "folded_rows": folded_rows,
        "by_backend": dict(sorted(backends.items())),
        "pairs_with_prediction": total_pairs,
        "measured_over_predicted_gmean": gmean,
    }


_TERM_KEYS = ("latency_s", "t_mem", "t_compute", "t_mem_raw", "t_compute_raw")


def predicted_unit_terms(opt_program, pass_trace) -> Dict[str, Dict[str, Any]]:
    """Per-lowering-unit predicted cost terms from the pass trace.

    The autotile pass reports one analytic record per optimized block
    (``latency_s`` = the pipelined roofline estimate, plus the raw and
    calibrated roofline terms).  Lowering units are keyed by their
    "+"-joined *semantic* member names (the hybrid composer's unit
    naming), so each autotile record is attributed to the unit whose
    member set covers the record's block; records that match no unit
    (e.g. blocks the later passes restructure) keep their own block
    name.  Terms are summed per unit; ``calibrated`` is true when any
    contributing record was scored with an active calibration."""
    from ..core.ir import Block
    from ..core.passes.fuse import members_of

    units: List[tuple] = []  # (unit_name, member set)
    seen = set()
    for s in opt_program.entry.stmts:
        if not isinstance(s, Block):
            continue
        members = members_of(s)
        key = tuple(members)
        if key not in seen:
            seen.add(key)
            units.append(("+".join(members), set(members)))

    entries: List[Dict[str, Any]] = []
    for entry in pass_trace:
        if entry and entry[0] == "autotile" and len(entry) > 2:
            entries = [e for e in entry[2] if isinstance(e, dict) and "block" in e]
            break

    terms: Dict[str, Dict[str, Any]] = {}
    for e in entries:
        bases = {p.split(".")[0] for p in str(e["block"]).split("+")}
        uname = str(e["block"])
        for name, members in units:
            if bases & members:
                uname = name
                break
        t = terms.setdefault(uname, {k: 0.0 for k in _TERM_KEYS})
        for k in _TERM_KEYS:
            t[k] += float(e.get(k, 0.0) or 0.0)
        t["calibrated"] = bool(t.get("calibrated")) or bool(e.get("calibrated"))
    return terms


def predicted_unit_latencies(opt_program, pass_trace) -> Dict[str, float]:
    """Per-lowering-unit predicted latency from the pass trace (the
    ``latency_s`` slice of :func:`predicted_unit_terms`)."""
    return {u: t["latency_s"]
            for u, t in predicted_unit_terms(opt_program, pass_trace).items()}


def residual_rows(record, interpret: bool) -> List[Dict[str, Any]]:
    """Build residual-log rows from a profiled CompileRecord's
    (predicted, measured) per-unit latencies."""
    rows = []
    terms = getattr(record, "predicted_terms", None) or {}
    for unit, measured in sorted(record.measured_latency_s.items()):
        t = terms.get(unit) or {}
        rows.append({
            "ir_fingerprint": record.ir_fingerprint,
            "hw_fingerprint": record.hw_fingerprint,
            "hw": record.hw_name,
            "key": record.key,
            "block": unit,
            "backend": record.block_backends.get(unit, record.backend),
            "interpret": bool(interpret),
            "predicted_s": record.predicted_latency_s.get(unit),
            "measured_s": measured,
            # raw roofline terms feed the calibration fit; the flag marks
            # rows whose prediction already had a calibration applied
            "t_mem_raw": t.get("t_mem_raw"),
            "t_compute_raw": t.get("t_compute_raw"),
            "calibrated": bool(t.get("calibrated")),
            "ts": time.time(),
            "pid": os.getpid(),
        })
    return rows
