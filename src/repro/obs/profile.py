"""Kernel profiling support: cost-model residual logging.

``stripe_jit(..., profile=True)`` wall-times every lowered unit (a
fusion group's Pallas kernels, a jnp fallback group, or the whole
program for the reference interpreter) on dispatch and attaches the
measurements to the :class:`~repro.core.driver.CompileRecord` next to
the cost model's predicted per-unit latencies.  On the first profiled
dispatch the (predicted, measured) pairs are appended — one JSON object
per line — to a **residual log** under the compilation-cache directory:

    {"ir_fingerprint": ..., "hw_fingerprint": ..., "block": "a+b",
     "predicted_s": 1.2e-5, "measured_s": 3.4e-5, "backend": "pallas",
     "interpret": true, "hw": "tpu_v5e", "key": ..., "ts": ...}

This file is the feed for the measured-feedback tuning database
(ROADMAP item 2): rows are keyed by IR fingerprint x hardware
fingerprint, exactly the identity the compilation cache already uses, so
accumulated (predicted, measured) pairs can calibrate the roofline /
pipeline model coefficients per hardware config.

Helpers here are import-light (no jax, no core imports at module level)
so ``repro.obs`` stays dependency-free.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

RESIDUAL_LOG_NAME = "residuals.jsonl"

_write_lock = threading.Lock()


def residual_log_path(cache=None) -> Path:
    """Where profiled compiles append residual rows: the cache's disk
    directory when it has one, else the process default cache dir."""
    from ..core.cache import default_cache_dir

    disk_dir = getattr(cache, "disk_dir", None)
    base = Path(disk_dir) if disk_dir is not None else default_cache_dir()
    return base / RESIDUAL_LOG_NAME


def append_residuals(rows: List[Dict[str, Any]], path=None) -> Optional[Path]:
    """Append rows to the residual JSONL (atomic at line granularity:
    one ``write`` of the whole batch under a process-wide lock).  I/O
    failures are swallowed — profiling must never fail the dispatch."""
    if not rows:
        return None
    p = Path(path) if path is not None else residual_log_path()
    data = "".join(json.dumps(r, sort_keys=True) + "\n" for r in rows)
    try:
        with _write_lock:
            p.parent.mkdir(parents=True, exist_ok=True)
            with open(p, "a") as f:
                f.write(data)
    except OSError:
        return None
    return p


def read_residuals(path=None) -> List[Dict[str, Any]]:
    """Load the residual log (skipping unparseable lines, e.g. a torn
    final line after a crash)."""
    p = Path(path) if path is not None else residual_log_path()
    rows: List[Dict[str, Any]] = []
    try:
        with open(p) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rows.append(json.loads(line))
                except ValueError:
                    continue
    except OSError:
        return []
    return rows


def summarize_residuals(rows: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Aggregate residual rows: count, per-backend counts, and the
    geometric-mean ratio measured/predicted where both are present (the
    cost model's systematic bias on this hardware)."""
    import math

    n = len(rows)
    backends: Dict[str, int] = {}
    log_ratios: List[float] = []
    for r in rows:
        backends[str(r.get("backend"))] = backends.get(str(r.get("backend")), 0) + 1
        p, m = r.get("predicted_s"), r.get("measured_s")
        if p and m and p > 0 and m > 0:
            log_ratios.append(math.log(m / p))
    gmean = math.exp(sum(log_ratios) / len(log_ratios)) if log_ratios else None
    return {
        "rows": n,
        "by_backend": dict(sorted(backends.items())),
        "pairs_with_prediction": len(log_ratios),
        "measured_over_predicted_gmean": gmean,
    }


def predicted_unit_latencies(opt_program, pass_trace) -> Dict[str, float]:
    """Per-lowering-unit predicted latency from the pass trace.

    The autotile pass reports one analytic record per optimized block
    (``latency_s`` = the pipelined roofline estimate).  Lowering units
    are keyed by their "+"-joined *semantic* member names (the hybrid
    composer's unit naming), so each autotile record is attributed to
    the unit whose member set covers the record's block; records that
    match no unit (e.g. blocks the later passes restructure) keep their
    own block name."""
    from ..core.ir import Block
    from ..core.passes.fuse import members_of

    units: List[tuple] = []  # (unit_name, member set)
    seen = set()
    for s in opt_program.entry.stmts:
        if not isinstance(s, Block):
            continue
        members = members_of(s)
        key = tuple(members)
        if key not in seen:
            seen.add(key)
            units.append(("+".join(members), set(members)))

    entries: List[Dict[str, Any]] = []
    for entry in pass_trace:
        if entry and entry[0] == "autotile" and len(entry) > 2:
            entries = [e for e in entry[2] if isinstance(e, dict) and "block" in e]
            break

    predicted: Dict[str, float] = {}
    for e in entries:
        lat = float(e.get("latency_s", 0.0) or 0.0)
        bases = {p.split(".")[0] for p in str(e["block"]).split("+")}
        for uname, members in units:
            if bases & members:
                predicted[uname] = predicted.get(uname, 0.0) + lat
                break
        else:
            predicted[str(e["block"])] = predicted.get(str(e["block"]), 0.0) + lat
    return predicted


def residual_rows(record, interpret: bool) -> List[Dict[str, Any]]:
    """Build residual-log rows from a profiled CompileRecord's
    (predicted, measured) per-unit latencies."""
    rows = []
    for unit, measured in sorted(record.measured_latency_s.items()):
        rows.append({
            "ir_fingerprint": record.ir_fingerprint,
            "hw_fingerprint": record.hw_fingerprint,
            "hw": record.hw_name,
            "key": record.key,
            "block": unit,
            "backend": record.block_backends.get(unit, record.backend),
            "interpret": bool(interpret),
            "predicted_s": record.predicted_latency_s.get(unit),
            "measured_s": measured,
            "ts": time.time(),
            "pid": os.getpid(),
        })
    return rows
