"""CLI: summarize observability artifacts.

    python -m repro.obs summarize trace.json   # per-phase wall time + serving breakdown
    python -m repro.obs residuals [path]       # cost-model residual log summary
"""
from __future__ import annotations

import argparse
import json
import sys

from . import profile, trace


def _fmt_table(rows, cols, headers):
    widths = [max(len(h), max((len(f"{r[c]}") for r in rows), default=0))
              for c, h in zip(cols, headers)]
    lines = ["  ".join(h.ljust(w) for h, w in zip(headers, widths))]
    lines.append("  ".join("-" * w for w in widths))
    for r in rows:
        lines.append("  ".join(f"{r[c]}".ljust(w) for c, w in zip(cols, widths)))
    return "\n".join(lines)


def cmd_summarize(args) -> int:
    events = trace.load_chrome_trace(args.path)
    rows = trace.summarize_events(events)
    if not rows:
        print(f"{args.path}: no complete span events")
        return 1
    total_ms = sum(r["total_ms"] for r in rows)
    print(f"# {args.path}: {sum(r['count'] for r in rows)} spans, "
          f"{len(rows)} phases, {total_ms:.1f} ms total span time\n")
    table = [{
        "phase": r["name"], "count": r["count"],
        "total_ms": f"{r['total_ms']:.3f}",
        "mean_ms": f"{r['mean_ms']:.3f}",
        "max_ms": f"{r['max_ms']:.3f}",
        "share": f"{100.0 * r['total_ms'] / total_ms:.1f}%",
    } for r in rows[: args.top]]
    print(_fmt_table(table, ["phase", "count", "total_ms", "mean_ms",
                             "max_ms", "share"],
                     ["phase", "count", "total ms", "mean ms", "max ms", "%"]))

    breakdown = trace.request_breakdown(events)
    if breakdown:
        n = len(breakdown)
        mean = lambda k: sum(b[k] for b in breakdown.values()) / n
        print(f"\n# serving: {n} requests "
              f"(mean queue {mean('queue_s') * 1e3:.2f} ms | "
              f"prefill {mean('prefill_s') * 1e3:.2f} ms | "
              f"decode {mean('decode_s') * 1e3:.2f} ms | "
              f"total {mean('total_s') * 1e3:.2f} ms)")
        if args.requests:
            req_rows = [{
                "uid": uid,
                "queue_ms": f"{b['queue_s'] * 1e3:.2f}",
                "prefill_ms": f"{b['prefill_s'] * 1e3:.2f}",
                "decode_ms": f"{b['decode_s'] * 1e3:.2f}",
                "total_ms": f"{b['total_s'] * 1e3:.2f}",
            } for uid, b in sorted(breakdown.items())[: args.top]]
            print(_fmt_table(req_rows,
                             ["uid", "queue_ms", "prefill_ms", "decode_ms",
                              "total_ms"],
                             ["uid", "queue ms", "prefill ms", "decode ms",
                              "total ms"]))
    return 0


def cmd_residuals(args) -> int:
    rows = profile.read_residuals(args.path)
    # rows rotated out of the log live on as running summaries in the
    # tuning DB next to it — merge them so the bias covers full history
    path = profile.residual_log_path() if args.path is None else profile.Path(args.path)
    folded = []
    try:
        from ..tune.db import TuningDB

        folded = TuningDB(dir=path.parent).residual_summaries()
    except Exception:
        pass
    summary = profile.summarize_residuals(rows, folded=folded)
    if args.json:
        print(json.dumps(summary, indent=2, sort_keys=True))
        return 0
    print(f"# {path}: {summary['rows']} residual rows "
          f"({summary['live_rows']} live, {summary['folded_rows']} folded "
          f"into the tuning DB; "
          f"{summary['pairs_with_prediction']} with predictions)")
    for backend, n in summary["by_backend"].items():
        print(f"  backend {backend}: {n}")
    g = summary["measured_over_predicted_gmean"]
    if g is not None:
        print(f"  measured/predicted geometric mean: {g:.3f}x")
    return 0 if rows or folded else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize", help="per-phase wall time from a Chrome trace")
    p.add_argument("path")
    p.add_argument("--top", type=int, default=30, help="max rows per table")
    p.add_argument("--requests", action="store_true",
                   help="also print the per-request breakdown table")
    p.set_defaults(fn=cmd_summarize)

    p = sub.add_parser("residuals", help="summarize the cost-model residual log")
    p.add_argument("path", nargs="?", default=None)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_residuals)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
