"""Declarative design-space specification (paper §4, "the biggest payoff
of the nested polyhedral model is design exploration").

A :class:`SearchSpace` names a base :class:`HardwareConfig` and a set of
:class:`Axis` knobs over it.  Because the hardware config is the *only*
hardware-specific artifact in the compiler, turning a knob never touches
an operation or a pass — a point is just ``space.apply(point)`` and the
standard pipeline compiles it.

Axis paths address the config structurally:

* ``mem.<UNIT>.<field>``     — a memory-unit field (``size_bytes``,
  ``bandwidth``, ``cache_line_elems``), e.g. ``mem.VMEM.size_bytes``;
* ``stencil.<NAME>.<field>`` — a compute-stencil field, e.g.
  ``stencil.mxu.dims``;
* ``peak_flops`` / ``ici_link_bw`` / ``pipeline_depth`` — top-level
  roofline/pipeline scalars;
* ``mesh``                   — a device-mesh shape tuple via
  ``with_mesh`` (``(1,)`` = single device); the partition pass annotates
  the shard plan, so sweeping this axis trades predicted latency against
  the new communication-bytes Pareto axis;
* ``pipeline``               — a named pass-pipeline variant
  (:data:`PIPELINE_VARIANTS`), e.g. dropping the fusion pass;
* ``<pass>.<param>``         — a pass parameter via ``with_params``,
  e.g. ``autotile.mem_cap_frac`` or ``fuse.prefer``.

Enumeration strategies: ``grid`` (evenly strided subsample of the full
cartesian product when it exceeds the budget), ``random`` (seeded i.i.d.
per-axis draws), and ``hillclimb`` (greedy coordinate descent from the
stock point, driven by a caller-supplied score — the generic form of the
roofline hillclimb that used to live in ``benchmarks/stripe_hillclimb``).
"""
from __future__ import annotations

import dataclasses
import itertools
import random as _random
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from ..core.hwconfig import HardwareConfig, get_config

PIPELINE_VARIANTS: Dict[str, Callable[[HardwareConfig], HardwareConfig]] = {
    "default": lambda cfg: cfg,
    "no-fuse": lambda cfg: cfg.without_pass("fuse"),
    "no-stencil": lambda cfg: cfg.without_pass("stencil"),
}


@dataclasses.dataclass(frozen=True)
class Axis:
    """One swept knob: a structural path into the config and its candidate
    values.  ``default`` is the stock setting (the hillclimb start point
    and the value omitted from derived config names)."""

    path: str
    values: Tuple[Any, ...]
    default: Any = None

    def __post_init__(self):
        if not self.values:
            raise ValueError(f"axis {self.path!r} has no values")
        if self.default is None:
            object.__setattr__(self, "default", self.values[0])


def apply_axis(cfg: HardwareConfig, path: str, value: Any) -> HardwareConfig:
    """Apply one axis setting to a config (see module docstring for the
    path grammar)."""
    parts = path.split(".")
    if path == "pipeline":
        try:
            return PIPELINE_VARIANTS[value](cfg)
        except KeyError:
            raise KeyError(f"unknown pipeline variant {value!r}; "
                           f"available: {sorted(PIPELINE_VARIANTS)}") from None
    if path in ("peak_flops", "ici_link_bw", "pipeline_depth"):
        return dataclasses.replace(cfg, **{path: value})
    if path == "mesh":
        shape = (value,) if isinstance(value, int) else tuple(value)
        return cfg.with_mesh(shape)
    if len(parts) == 3 and parts[0] == "mem":
        return cfg.with_mem(parts[1], **{parts[2]: value})
    if len(parts) == 3 and parts[0] == "stencil":
        return cfg.with_stencil(parts[1], **{parts[2]: tuple(value) if parts[2] == "dims" else value})
    if len(parts) == 2:
        return cfg.with_params(**{path: value})
    raise ValueError(f"unrecognized axis path {path!r}")


def _fmt(v: Any) -> str:
    if isinstance(v, (tuple, list)):
        return "x".join(str(int(s)) for s in v)  # mesh shapes: "2x4"
    if isinstance(v, float):
        return f"{v:g}"
    if isinstance(v, int) and v >= 1 << 20 and v % (1 << 20) == 0:
        return f"{v >> 20}Mi"
    return str(v)


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """A named design space: base config + axes.  Pure data (picklable),
    so the parallel sweep runner can ship it to worker processes."""

    name: str
    base: str  # registry name of the base HardwareConfig
    axes: Tuple[Axis, ...]

    def base_config(self) -> HardwareConfig:
        return get_config(self.base)

    def default_point(self) -> Dict[str, Any]:
        return {a.path: a.default for a in self.axes}

    def size(self) -> int:
        n = 1
        for a in self.axes:
            n *= len(a.values)
        return n

    def point_name(self, point: Mapping[str, Any]) -> str:
        """Readable derived-config name: base plus only the non-stock
        settings (names never enter the fingerprint, so this is purely
        for reports)."""
        diffs = [f"{a.path}={_fmt(point[a.path])}"
                 for a in self.axes if point[a.path] != a.default]
        return self.base if not diffs else f"{self.base}+" + ",".join(diffs)

    def apply(self, point: Mapping[str, Any]) -> HardwareConfig:
        """Materialize a point: the base config with every axis applied.
        The ``pipeline`` axis (if any) is applied first so pass-parameter
        axes act on the selected pipeline."""
        cfg = self.base_config()
        ordered = sorted(self.axes, key=lambda a: a.path != "pipeline")
        for a in ordered:
            cfg = apply_axis(cfg, a.path, point[a.path])
        return cfg.renamed(self.point_name(point))

    # ---------------------------------------------------------- strategies
    def grid(self, budget: int) -> List[Dict[str, Any]]:
        """The full cartesian product, evenly stride-subsampled down to
        ``budget`` points when it is larger.  The stock (all-defaults)
        point always leads, so every grid sweep revisits the baseline
        fingerprint — the sweep runner dedupes it against the baseline
        compile instead of rescoring."""
        stock = tuple(a.default for a in self.axes)
        combos = [c for c in itertools.product(*(a.values for a in self.axes))
                  if c != stock]
        if budget:
            take = budget - 1  # the stock point spends one budget slot
            if len(combos) > take:
                if take <= 0:
                    combos = []
                else:
                    n = len(combos)
                    picks = sorted({round(i * (n - 1) / max(take - 1, 1))
                                    for i in range(take)})
                    combos = [combos[i] for i in picks]
        return [dict(zip((a.path for a in self.axes), c)) for c in [stock] + combos]

    def random(self, budget: int, seed: int = 0) -> List[Dict[str, Any]]:
        """Seeded i.i.d. per-axis draws, deduplicated, stock point first."""
        rng = _random.Random(seed)
        target = min(budget, self.size())
        out = [self.default_point()]
        seen = {tuple(out[0][a.path] for a in self.axes)}
        attempts = 0
        while len(out) < target and attempts < 100 * max(budget, 1):
            attempts += 1
            point = {a.path: rng.choice(a.values) for a in self.axes}
            key = tuple(point[a.path] for a in self.axes)
            if key not in seen:
                seen.add(key)
                out.append(point)
        return out

    def hillclimb(self, budget: int,
                  score: Callable[[Dict[str, Any]], float],
                  seed: int = 0) -> List[Dict[str, Any]]:
        """Greedy coordinate descent from the stock point: sweep one axis
        at a time (round-robin, seeded axis order), keep the best value,
        stop when a full round improves nothing or the budget is spent.
        Returns every point evaluated, in evaluation order."""
        rng = _random.Random(seed)
        axes = list(self.axes)
        rng.shuffle(axes)
        current = self.default_point()
        visited: List[Dict[str, Any]] = []
        scores: Dict[Tuple, float] = {}

        def eval_point(p: Dict[str, Any]) -> float:
            key = tuple(p[a.path] for a in self.axes)
            if key not in scores:
                if len(visited) >= budget:
                    return float("inf")
                visited.append(dict(p))
                scores[key] = score(p)
            return scores[key]

        best = eval_point(current)
        improved = True
        while improved and len(visited) < budget:
            improved = False
            for a in axes:
                for v in a.values:
                    if v == current[a.path]:
                        continue
                    trial = dict(current, **{a.path: v})
                    s = eval_point(trial)
                    if s < best:
                        best, current = s, trial
                        improved = True
                if len(visited) >= budget:
                    break
        return visited


# --------------------------------------------------------------------------
# Built-in spaces
# --------------------------------------------------------------------------
def tpu_sweep() -> SearchSpace:
    """Hardware/compiler co-design around the TPU v5e: memory-system
    alternatives (HBM bandwidth generations, VMEM arena sizes, DMA
    pipeline depth) crossed with pass parameterizations (autotile
    budget, fusion-grouping preference) and pipeline variants (fusion
    on/off)."""
    return SearchSpace(
        name="tpu-sweep", base="tpu_v5e",
        axes=(
            Axis("pipeline", ("default", "no-fuse"), default="default"),
            Axis("mem.HBM.bandwidth", (819e9, 1.2e12, 1.64e12), default=819e9),
            Axis("mem.VMEM.size_bytes",
                 (64 * 2**20, 128 * 2**20, 256 * 2**20), default=128 * 2**20),
            Axis("pipeline_depth", (2, 1, 3), default=2),
            Axis("autotile.mem_cap_frac", (0.3, 0.45, 0.6, 0.9), default=0.45),
            Axis("fuse.prefer", ("epilogue", "prologue"), default="epilogue"),
        ))


def cacheline_sweep() -> SearchSpace:
    """The paper's Fig. 4 machine swept over its two defining knobs: the
    transaction granularity (cache-line width) and the tile budget —
    stencil-dims-scale exploration on the cached-architecture model."""
    return SearchSpace(
        name="cacheline-sweep", base="paper_fig4",
        axes=(
            Axis("mem.DRAM.cache_line_elems", (4, 8, 16, 32), default=8),
            Axis("autotile.mem_cap_elems", (256, 512, 1024, 2048), default=512),
            Axis("autotile.search", ("divisors", "pow2"), default="divisors"),
        ))


def mesh_sweep() -> SearchSpace:
    """Multi-device co-design on the TPU v5e: device-mesh shapes (the
    partition pass's shard plan prices the collectives analytically — no
    devices are touched) crossed with interconnect-bandwidth generations
    and the DMA pipeline depth.  The sweep's Pareto front trades
    predicted latency against per-device communication bytes."""
    return SearchSpace(
        name="mesh-sweep", base="tpu_v5e",
        axes=(
            Axis("mesh", ((1,), (2,), (4,), (8,), (2, 2), (2, 4)),
                 default=(1,)),
            Axis("ici_link_bw", (50e9, 100e9, 25e9), default=50e9),
            Axis("pipeline_depth", (2, 1, 3), default=2),
        ))


BUILTIN_SPACES: Dict[str, Callable[[], SearchSpace]] = {
    "tpu-sweep": tpu_sweep,
    "cacheline-sweep": cacheline_sweep,
    "mesh-sweep": mesh_sweep,
}


def get_space(name: str) -> SearchSpace:
    try:
        return BUILTIN_SPACES[name]()
    except KeyError:
        raise KeyError(f"unknown search space {name!r}; "
                       f"available: {sorted(BUILTIN_SPACES)}") from None
