"""The roofline hillclimb narrative (formerly ``benchmarks/
stripe_hillclimb.py``) — the paper's technique applied to itself: the
Stripe autotiler iterating a llama-shaped TP matmul shard toward the TPU
roofline, one hypothesis -> change -> re-cost step at a time.

This is the *story* form of the generic coordinate-descent strategy in
``space.SearchSpace.hillclimb``: each named iteration is one move in the
(tiling x stencil x fusion) design space, scored with the same analytic
cost model the sweep runner uses.

The op is the per-chip shard of llama3-8b's LOGITS matmul during
train_4k on the 16x16 mesh: M = 8,192-token microbatch slice, K = 4096,
N = 128256-vocab / 16 model shards = 8,016 — large enough on both output
dims that the tiling decides how often each operand streams from HBM.

Iterations:
  0  flat (untiled) op               — infeasible: tile > VMEM cap
  1  naive square tiles 128^3/512^3  — feasible; HBM-bound
  2  autotile (roofline cost model)  — picks K-resident tiles, fewer fetches
  3  + MXU stencil pass              — aligns to 128x128x128, util -> 1.0
  4  + fusion (bias+silu epilogue)   — removes intermediate HBM round trip

Emits CSV rows: name,us_per_call,derived (us_per_call = modeled step time
of the dominant roofline term; derived = roofline fraction vs MXU peak).
"""
from __future__ import annotations

from ..core.cost import evaluate_tiling
from ..core.frontend import TileProgram, single_op_program
from ..core.hwconfig import get_config
from ..core.passes.autotile import choose_tiling

M, K, N = 8192, 4096, 8016
PARAMS = {"cost": "roofline", "search": "pow2", "mem_cap_frac": 0.45, "count_untiled": True}


def _block():
    prog = single_op_program(
        "O[i, j] += X[i, c] * W[c, j]",
        {"X": ((M, K), "bfloat16"), "W": ((K, N), "bfloat16"), "O": ((M, N), "bfloat16")},
        out="O",
    )
    return prog, prog.entry.stmts[0]


def _default_emit(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


def roofline_hillclimb(emit=_default_emit) -> None:
    """Run the iteration story; ``emit(name, us_per_call, derived)`` rows
    land in the benchmark harness's CSV/JSON stream."""
    hw = get_config("tpu_v5e")
    peak = hw.peak_flops
    prog, blk = _block()

    def report(name, cost, extra=""):
        ideal = 2.0 * M * K * N / peak
        t = max(cost.t_mem, cost.t_compute)
        frac = ideal / t if t else 0.0
        emit(f"stripe_hillclimb/{name}", t * 1e6, f"{frac:.4f}{extra}")

    # it0: whole-op "tile" (flat): footprint check
    c0 = evaluate_tiling(blk, {}, hw, PARAMS)
    emit("stripe_hillclimb/flat_infeasible", 0.0, f"{int(c0.feasible)}  # {c0.why or 'fits'}")

    # it1: naive square tiles
    c1 = evaluate_tiling(blk, {"i": 128, "c": 128, "j": 128}, hw, PARAMS)
    report("naive_128cube", c1)
    c1b = evaluate_tiling(blk, {"i": 512, "c": 512, "j": 512}, hw, PARAMS)
    report("naive_512cube", c1b)

    # it2: autotile
    tiles, c2 = choose_tiling(blk, hw, PARAMS)
    report("autotile", c2, extra=f"  # tiles={tiles}")

    # it3: stencil utilization — force MXU multiples
    snapped = {v: max(128, (t // 128) * 128) if t >= 128 else t for v, t in tiles.items()}
    c3 = evaluate_tiling(blk, snapped, hw, {**PARAMS, "stencil": "mxu"})
    report("stenciled", c3, extra=f"  # tiles={snapped}")

    # it4: fusion — bias+silu epilogue folded into the same tiles (the
    # intermediate T never goes to HBM): model it by dropping one full
    # output write + read (2 x M*N*2 bytes)
    import dataclasses

    saved = 2 * (M * N * 2)
    c4 = dataclasses.replace(c3, bytes_hbm=c3.bytes_hbm - saved,
                             t_mem=(c3.bytes_hbm - saved) / hw.mem_units[0].bandwidth)
    report("fused_epilogue", c4)

    # confirm the fused kernel actually builds through the real pipeline
    from ..core.ir import Block
    from ..core.passes import compile_program

    tp = TileProgram("ffn")
    tp.input("X", (M, K), "bfloat16")
    tp.input("W", (K, N), "bfloat16")
    tp.input("B", (N,), "float32")
    tp.temp("T", (M, N))
    tp.output("O", (M, N), "bfloat16")
    tp.op("T[i, j] += X[i, c] * W[c, j]")
    tp.op("O[i, j] = silu(T[i, j] + B[j])")
    out = compile_program(tp.build(), hw)
    blocks = [s for s in out.entry.stmts if isinstance(s, Block)]
    # boundary may split a fused grid into interior/boundary pieces
    fused = len(blocks) >= 1 and all("fused" in b.tags for b in blocks)
    emit("stripe_hillclimb/pipeline_fuses_ffn", 0.0, int(fused))


if __name__ == "__main__":
    roofline_hillclimb()
