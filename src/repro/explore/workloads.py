"""Workload corpus for design-space sweeps.

Every sweep point is scored on a *corpus* of programs, not one kernel —
a hardware config that wins on a single matmul but loses on attention or
MoE FFN shapes is exactly the false positive design exploration exists
to catch.  The corpus mirrors the shapes the framework actually runs:

* ``mm_bias_gelu``   — the oplib linear layer (matmul → bias → gelu);
* ``ffn_relu2``      — nemotron-style squared-ReLU FFN chain
                       (mm → bias → relu → square → mm), the fusion
                       bench's headline workload;
* ``attn_scores``    — the flash-attention score contraction
                       S[q,k] += Q[q,d]·K[k,d] at a serving shape;
* ``moe_ffn``        — one expert's gated FFN (llama/mixtral style):
                       silu(X·W1) ⊙ (X·W3) · W2, a multi-consumer
                       diamond for the fusion pass;
* ``fig4_conv``      — the paper's Fig. 4/5 int8 3×3 conv (the
                       cache-line cost model's reference program);
* ``fig5_conv_f32``  — the same conv in f32 (the executable Fig. 5
                       variant the benchmarks measure);
* ``conv_mlp``       — conv head + channel-mixing matmul, the mixed
                       program the per-block hybrid Pallas backend runs
                       (windowed conv kernel + dense matmul kernel).

Shapes are deliberately modest (compile-speed-bound: a 32-point sweep
compiles every workload at every unique config) but large enough on the
tiled dims that tiling decisions change predicted traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

from ..core.frontend import TileProgram, single_op_program
from ..core.ir import Program


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    build: Callable[[], Program]
    tags: tuple = ()


def mm_bias_gelu(m: int = 512, k: int = 512, n: int = 1024) -> Program:
    tp = TileProgram("mm_bias_gelu")
    tp.input("X", (m, k), "bfloat16")
    tp.input("W", (k, n), "bfloat16")
    tp.input("B", (n,), "float32")
    tp.temp("T", (m, n))
    tp.output("O", (m, n), "bfloat16")
    tp.op("T[i, j] += X[i, c] * W[c, j]", name="mm")
    tp.op("O[i, j] = gelu(T[i, j] + B[j])", name="bias_gelu")
    return tp.build()


def ffn_relu2(m: int = 512, k: int = 64, n: int = 1024, n2: int = 64) -> Program:
    tp = TileProgram("ffn_relu2")
    tp.input("A", (m, k), "bfloat16")
    tp.input("B", (k, n), "bfloat16")
    tp.input("b", (n,), "float32")
    tp.input("W2", (n, n2), "bfloat16")
    tp.temp("T", (m, n))
    tp.temp("U", (m, n))
    tp.temp("V", (m, n))
    tp.output("O", (m, n2), "bfloat16")
    tp.op("T[i, j] += A[i, c] * B[c, j]", name="mm1")
    tp.op("U[i, j] = T[i, j] + b[j]", name="bias")
    tp.op("V[i, j] = square(relu(U[i, j]))", name="relu2")
    tp.op("O[i, j2] += V[i, j] * W2[j, j2]", name="mm2")
    return tp.build()


def attn_scores(seq: int = 1024, head_dim: int = 128) -> Program:
    return single_op_program(
        "S[q, k] += Q[q, d] * K[k, d]",
        {"Q": ((seq, head_dim), "bfloat16"), "K": ((seq, head_dim), "bfloat16"),
         "S": ((seq, seq), "float32")},
        out="S", name="attn_scores")


def moe_ffn(tokens: int = 256, d: int = 512, hidden: int = 1024) -> Program:
    tp = TileProgram("moe_ffn")
    tp.input("X", (tokens, d), "bfloat16")
    tp.input("W1", (d, hidden), "bfloat16")
    tp.input("W3", (d, hidden), "bfloat16")
    tp.input("W2", (hidden, d), "bfloat16")
    tp.temp("H", (tokens, hidden))
    tp.temp("U", (tokens, hidden))
    tp.temp("G", (tokens, hidden))
    tp.output("O", (tokens, d), "bfloat16")
    tp.op("H[t, h] += X[t, c] * W1[c, h]", name="up")
    tp.op("U[t, h] += X[t, c] * W3[c, h]", name="gate_mm")
    tp.op("G[t, h] = silu(H[t, h]) * U[t, h]", name="gate")
    tp.op("O[t, e] += G[t, h] * W2[h, e]", name="down")
    return tp.build()


def fig4_conv() -> Program:
    return single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), "int8"), "F": ((3, 3, 8, 16), "int8"),
         "O": ((12, 16, 16), "int32")},
        out="O", name="fig4_conv")


def fig5_conv_f32() -> Program:
    return single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), "float32"), "F": ((3, 3, 8, 16), "float32"),
         "O": ((12, 16, 16), "float32")},
        out="O", name="fig5_conv_f32")


def conv_mlp(x: int = 24, y: int = 24, c: int = 8, k: int = 16, m: int = 32) -> Program:
    """Conv head + channel-mixing matmul: a mixed program for the
    per-block hybrid backend — the conv lowers via the halo-aware
    windowed path, the matmul via the dense contraction path, and the
    kernel-count axis reflects both."""
    tp = TileProgram("conv_mlp")
    tp.input("I", (x, y, c))
    tp.input("F", (3, 3, c, k))
    tp.input("W", (k, m))
    tp.temp("C", (x, y, k))
    tp.output("O", (x, y, m))
    tp.op("C[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]", name="conv")
    tp.op("O[x, y, m] += C[x, y, k] * W[k, m]", name="proj")
    return tp.build()


_ALL: Dict[str, Workload] = {w.name: w for w in (
    Workload("mm_bias_gelu", mm_bias_gelu, tags=("linear", "fusion")),
    Workload("ffn_relu2", ffn_relu2, tags=("ffn", "fusion")),
    Workload("attn_scores", attn_scores, tags=("attention",)),
    Workload("moe_ffn", moe_ffn, tags=("moe", "diamond")),
    Workload("fig4_conv", fig4_conv, tags=("paper", "conv")),
    Workload("fig5_conv_f32", fig5_conv_f32, tags=("paper", "conv")),
    Workload("conv_mlp", conv_mlp, tags=("conv", "hybrid")),
)}

CORPORA: Dict[str, Sequence[str]] = {
    "default": ("mm_bias_gelu", "ffn_relu2", "attn_scores", "moe_ffn", "fig4_conv"),
    "paper": ("fig4_conv", "fig5_conv_f32"),
    "quick": ("mm_bias_gelu", "fig4_conv"),
    "all": tuple(_ALL),
}


def get_workloads(spec: str = "default") -> List[Workload]:
    """Resolve a corpus name or a comma-separated workload list."""
    names = CORPORA.get(spec)
    if names is None:
        names = tuple(s.strip() for s in spec.split(",") if s.strip())
    out = []
    for n in names:
        if n not in _ALL:
            raise KeyError(f"unknown workload {n!r}; available workloads "
                           f"{sorted(_ALL)} or corpora {sorted(CORPORA)}")
        out.append(_ALL[n])
    if not out:
        raise KeyError(f"empty workload spec {spec!r}")
    return out
