"""Pareto-frontier extraction and report emission.

A sweep point is scored on four minimization axes — predicted corpus
latency, peak VMEM arena pressure, kernels launched, per-device
communication bytes (zero off-mesh) — and the report
extracts the non-dominated set, compares every point against the stock
baseline per workload, and emits both machine-readable JSON and a
markdown table (the CLI prints the latter).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from .runner import PointResult, SweepResult

PARETO_AXES = ("latency_s", "vmem_peak_bytes", "n_kernels", "comm_bytes")


def _axes(p: PointResult) -> Tuple[float, ...]:
    return tuple(float(getattr(p, a)) for a in PARETO_AXES)


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True iff ``a`` is no worse than ``b`` on every axis and strictly
    better on at least one (all axes minimized)."""
    return all(x <= y for x, y in zip(a, b)) and any(x < y for x, y in zip(a, b))


def pareto_front(points: Sequence[PointResult]) -> List[int]:
    """Indices (``PointResult.index``) of the non-dominated set."""
    front = []
    for p in points:
        if p.error or p.dedup_of is not None:
            continue
        pa = _axes(p)
        if not any(dominates(_axes(q), pa) for q in points
                   if q is not p and not q.error and q.dedup_of is None):
            front.append(p.index)
    return front


def dominating_baseline(sweep: SweepResult) -> Dict[str, List[int]]:
    """Per workload: sweep points strictly better than the stock baseline
    on predicted latency — the design-exploration headline ("what
    hardware change would make this workload faster")."""
    out: Dict[str, List[int]] = {}
    for w in sweep.baseline.scores:
        base = sweep.baseline.workload_latency(w)
        better = [p.index for p in sweep.unique_points()
                  if w in p.scores and p.workload_latency(w) < base]
        out[w] = sorted(better, key=lambda i: sweep.points[i].workload_latency(w))
    return out


def build_report(sweep: SweepResult) -> Dict:
    """The full JSON report document."""
    front = pareto_front(sweep.points)
    dom = dominating_baseline(sweep)
    n_dedup = sum(1 for p in sweep.points if p.dedup_of is not None)
    n_err = sum(1 for p in sweep.points if p.error)
    return {
        "space": sweep.space.name,
        "base_config": sweep.space.base,
        "axes": [{"path": a.path, "values": list(a.values), "default": a.default}
                 for a in sweep.space.axes],
        "strategy": sweep.strategy,
        "workloads": list(sweep.baseline.scores),
        "n_points": len(sweep.points),
        "n_unique": len(sweep.points) - n_dedup,
        "n_deduped": n_dedup,
        "n_errors": n_err,
        "wall_time_s": round(sweep.wall_time_s, 3),
        "cache_stats": sweep.cache_stats,
        "baseline": sweep.baseline.to_json(),
        "points": [p.to_json() for p in sweep.points],
        "pareto_front": front,
        "dominating_baseline": dom,
        "validation": sweep.validation,
        "measurement": sweep.measurement,
    }


def _fmt_lat(s: float) -> str:
    return f"{s * 1e6:.2f}"


def to_markdown(sweep: SweepResult, max_rows: int = 24) -> str:
    """Human-readable report: the Pareto table (best predicted latency
    first), baseline row marked, plus the dominance and validation
    summaries."""
    front = set(pareto_front(sweep.points))
    dom = dominating_baseline(sweep)
    lines = [
        f"# Design-space exploration: `{sweep.space.name}` "
        f"(base `{sweep.space.base}`, strategy {sweep.strategy})",
        "",
        f"{len(sweep.points)} points "
        f"({len(sweep.points) - sum(1 for p in sweep.points if p.dedup_of is not None)} unique, "
        f"{sum(1 for p in sweep.points if p.dedup_of is not None)} deduped by fingerprint) "
        f"x {len(sweep.baseline.scores)} workloads; "
        f"wall {sweep.wall_time_s:.1f}s.",
        "",
        "| rank | config | pred latency (us) | VMEM peak (B) | kernels | comm (B) | Pareto |",
        "|---:|---|---:|---:|---:|---:|:---:|",
    ]
    rows: List[PointResult] = sorted(sweep.unique_points(), key=lambda p: p.latency_s)
    table = [(sweep.baseline, True)] + [(p, False) for p in rows[:max_rows]]
    table.sort(key=lambda t: t[0].latency_s)
    for rank, (p, is_base) in enumerate(table):
        name = f"**{p.config_name} (baseline)**" if is_base else p.config_name
        lines.append(
            f"| {rank} | {name} | {_fmt_lat(p.latency_s)} | "
            f"{p.vmem_peak_bytes} | {p.n_kernels} | "
            f"{int(getattr(p, 'comm_bytes', 0) or 0)} | "
            f"{'x' if (not is_base and p.index in front) else ''} |")
    lines.append("")
    lines.append("## Baseline dominance (predicted latency, per workload)")
    lines.append("")
    for w, idxs in dom.items():
        base_us = _fmt_lat(sweep.baseline.workload_latency(w))
        if not idxs:
            lines.append(f"- `{w}`: baseline ({base_us} us) undominated")
        else:
            best = sweep.points[idxs[0]]
            lines.append(
                f"- `{w}`: {len(idxs)} config(s) beat baseline "
                f"({base_us} us); best `{best.config_name}` at "
                f"{_fmt_lat(best.workload_latency(w))} us")
    if sweep.validation:
        v = sweep.validation
        lines.append("")
        lines.append(f"## Measured validation (top-{v['top_k']}, "
                     f"backend `{v['backend']}`)")
        lines.append("")
        lines.append("| config | predicted (us) | measured (us/call) |")
        lines.append("|---|---:|---:|")
        for e in v["entries"]:
            meas = ("err: " + e["error"]) if e["error"] else f"{e['measured_total_us']:.1f}"
            lines.append(f"| {e['config']} | {_fmt_lat(e['predicted_latency_s'])} | {meas} |")
        lines.append("")
        lines.append(f"predicted rank: {v['predicted_rank']}  |  "
                     f"measured rank: {v['measured_rank']} "
                     f"(-1 = baseline)"
                     + (f"  |  {v['rounds']} interleaved rounds"
                        if v.get("rounds") else ""))
    if sweep.measurement:
        m = sweep.measurement
        lines.append("")
        lines.append(f"## Measured autotuning ({m['backend']}"
                     f"{', interpret' if m.get('interpret') else ''}; "
                     f"min of {m['rounds']} interleaved rounds)")
        lines.append("")
        lines.append("| workload | candidates | analytic (s/call) | "
                     "measured best (s/call) | speedup | winner |")
        lines.append("|---|---:|---:|---:|---:|---|")
        for w, wl in m["workloads"].items():
            if wl.get("error"):
                lines.append(f"| `{w}` | - | - | - | - | err: {wl['error']} |")
                continue
            # the measured winner is *promoted*: its candidate id is the
            # tuning-DB best, which stripe_jit(tune=...) replays
            speed = wl.get("speedup_vs_analytic")
            lines.append(
                f"| `{w}` | {wl['n_candidates']} | "
                f"{wl['analytic_s']:.4g} | {wl['best_s']:.4g} | "
                f"{speed:.2f}x | `{wl['best_candidate']}`"
                f"{' (analytic held)' if not wl['improved'] else ''} |")
        lines.append("")
        lines.append("every measurement above is recorded in the tuning DB; "
                     "`stripe_jit(..., tune=...)` replays each winner.")
    lines.append("")
    return "\n".join(lines)


def write_report(sweep: SweepResult, out_dir: str) -> Tuple[Path, Path]:
    """Emit ``explore_report.json`` + ``explore_report.md`` under
    ``out_dir``; returns both paths."""
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    jpath = out / "explore_report.json"
    mpath = out / "explore_report.md"
    jpath.write_text(json.dumps(build_report(sweep), indent=2, default=str))
    mpath.write_text(to_markdown(sweep))
    return jpath, mpath
