"""Design-space exploration — the paper's closing claim made executable.

Because the :class:`~repro.core.hwconfig.HardwareConfig` is the only
hardware-specific artifact in the compiler, sweeping memory hierarchies,
stencils, and pass parameterizations never touches an operation or a
pass.  This subsystem turns that property into an engine:

* :mod:`repro.explore.space`     — declarative search spaces over config
  fields and pass parameters (grid / random / hillclimb enumeration);
* :mod:`repro.explore.workloads` — the scenario corpus every point is
  scored on (matmul chains, attention, MoE FFN, the paper's conv);
* :mod:`repro.explore.runner`    — the parallel sweep driver: compile
  through the cached pipeline, dedupe by config fingerprint, score with
  the analytic cost model, optionally validate top-K by measurement;
* :mod:`repro.explore.report`    — Pareto-frontier extraction (predicted
  latency x VMEM pressure x kernels launched), JSON + markdown.

CLI::

    python -m repro.explore --space tpu-sweep --workloads default --budget 32
"""
from .report import build_report, dominating_baseline, pareto_front, to_markdown, write_report
from .runner import (PointResult, SweepResult, measure_candidates, run_sweep,
                     score_config, validate_top_k)
from .space import Axis, SearchSpace, apply_axis, get_space, BUILTIN_SPACES
from .workloads import CORPORA, Workload, get_workloads

__all__ = [
    "Axis", "SearchSpace", "apply_axis", "get_space", "BUILTIN_SPACES",
    "Workload", "get_workloads", "CORPORA",
    "PointResult", "SweepResult", "run_sweep", "score_config", "validate_top_k",
    "measure_candidates",
    "pareto_front", "dominating_baseline", "build_report", "to_markdown",
    "write_report",
]
