"""``python -m repro.explore`` — run a design-space sweep and report
Pareto frontiers.

Example::

    python -m repro.explore --space tpu-sweep --workloads default \
        --budget 32 --strategy grid --top-k 3 --out explore_out

Prints the markdown report and writes ``explore_report.json`` +
``explore_report.md`` under ``--out``.  The sweep's compilation cache
lives under ``--cache-dir`` (default ``<out>/cache``; honors
``$STRIPE_CACHE_DIR`` only when passed explicitly) so exploration never
pollutes the user's ``~/.cache/stripe-repro``.
"""
from __future__ import annotations

import argparse
import sys

from .report import to_markdown, write_report
from .runner import run_sweep
from .space import BUILTIN_SPACES, _fmt, get_space
from .workloads import CORPORA


def _space_epilog() -> str:
    """--help epilog enumerating every built-in space's axes (so the
    sweepable knobs — including the device-mesh shapes of `mesh-sweep` —
    are discoverable without reading the source)."""
    lines = ["built-in spaces and their axes:"]
    for name in sorted(BUILTIN_SPACES):
        sp = BUILTIN_SPACES[name]()
        lines.append(f"  {name} (base {sp.base}):")
        for a in sp.axes:
            vals = ", ".join(_fmt(v) for v in a.values)
            lines.append(f"    {a.path} = {{{vals}}} (default {_fmt(a.default)})")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.explore",
        description=__doc__.splitlines()[0],
        epilog=_space_epilog(),
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--space", default="tpu-sweep",
                    help=f"built-in search space: {sorted(BUILTIN_SPACES)}")
    ap.add_argument("--workloads", default="default",
                    help=f"corpus name {sorted(CORPORA)} or comma-separated workloads")
    ap.add_argument("--budget", type=int, default=32,
                    help="max sweep points to enumerate (default 32)")
    ap.add_argument("--strategy", default="grid",
                    choices=("grid", "random", "hillclimb"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top-k", type=int, default=3, dest="top_k",
                    help="validate the K best predicted points by real "
                         "measurement (0 disables)")
    ap.add_argument("--backend", default="jnp",
                    help="measurement backend for --top-k (default jnp)")
    ap.add_argument("--parallel", type=int, default=0,
                    help="process-pool width for scoring unique points "
                         "(0/1 = serial)")
    ap.add_argument("--measure", type=int, default=0,
                    help="measure mode: wall-time up to N candidate tilings "
                         "per workload on pallas-interpret and record every "
                         "measurement in the tuning DB (0 disables)")
    ap.add_argument("--tune-db", default=None, dest="tune_db",
                    help="tuning-DB directory for --measure "
                         "(default: the compilation-cache dir)")
    ap.add_argument("--out", default="explore_out",
                    help="output directory for the JSON/markdown report")
    ap.add_argument("--cache-dir", default=None,
                    help="compilation-cache directory (default <out>/cache)")
    args = ap.parse_args(argv)

    try:
        space = get_space(args.space)
    except KeyError as e:
        ap.error(str(e))
    cache_dir = args.cache_dir or f"{args.out}/cache"

    tune_db = None
    if args.measure > 0:
        from ..tune.db import TuningDB

        tune_db = TuningDB(dir=args.tune_db or cache_dir)

    sweep = run_sweep(
        space, args.workloads, budget=args.budget, strategy=args.strategy,
        seed=args.seed, cache_dir=cache_dir, parallel=args.parallel,
        measure_top_k=args.top_k, measure_backend=args.backend,
        measure=args.measure, tune_db=tune_db)
    jpath, mpath = write_report(sweep, args.out)
    print(to_markdown(sweep))
    print(f"wrote {jpath} and {mpath}")
    n_err = sum(1 for p in sweep.points if p.error)
    if n_err:
        print(f"warning: {n_err} point(s) failed to score", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
