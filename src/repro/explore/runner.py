"""Parallel sweep driver.

The pipeline per sweep point:

1. materialize the point's :class:`HardwareConfig` (``space.apply``);
2. **dedupe by fingerprint** — the config name never enters
   ``HardwareConfig.fingerprint()``, so two points that compile
   identically share one compilation-cache entry and the later one is
   never recompiled (it references the earlier result);
3. compile every corpus workload through ``compile_cached`` — the
   sweep-friendly driver entry that runs the pass pipeline under the
   two-level cache but never builds a backend;
4. score the pass trace analytically (``cost.score_pass_trace``):
   predicted latency (roofline), VMEM arena pressure, kernels launched.

Unique points fan out over a process pool (workers recompute from the
shared on-disk cache directory, so a re-run of the same sweep replays
recorded tilings instead of searching).  Optionally the top-K points by
predicted latency are *validated by measurement*: each workload is
lowered through ``stripe_jit`` on a real backend (jnp by default) and
timed, and the measured ranking is recorded next to the predicted one.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from ..core import cache as _cache
from ..core.cost import ProgramScore, score_pass_trace
from ..obs import trace as obs_trace
from ..core.driver import compile_cached, compile_with_tilings, stripe_jit
from ..core.hwconfig import HardwareConfig
from ..tune.measure import DEFAULT_CALLS, DEFAULT_ROUNDS, measure_interleaved
from .space import SearchSpace
from .workloads import Workload, get_workloads


@dataclasses.dataclass
class PointResult:
    """One sweep point's outcome — JSON-able for the report."""

    index: int
    config_name: str
    fingerprint: str
    point: Dict[str, Any]
    scores: Dict[str, Dict] = dataclasses.field(default_factory=dict)  # workload -> ProgramScore json
    latency_s: float = 0.0          # sum of per-workload predicted latencies
    vmem_peak_bytes: int = 0        # max across workloads
    n_kernels: int = 0              # sum across workloads (dispatches per corpus pass)
    comm_bytes: float = 0.0         # sum of per-device collective bytes (mesh axis)
    compile_time_s: float = 0.0
    dedup_of: Optional[int] = None  # earlier point index with the same fingerprint
    error: str = ""

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    def workload_latency(self, workload: str) -> float:
        return float(self.scores[workload]["latency_s"])


def score_config(hw: HardwareConfig, workloads: Sequence[Workload],
                 cache: Optional[_cache.CompilationCache] = None,
                 workers: Optional[int] = None) -> Tuple[Dict[str, ProgramScore], float]:
    """Compile + analytically score every workload on one config."""
    from ..core.passes.schedule import program_arena_peak

    scores: Dict[str, ProgramScore] = {}
    t_compile = 0.0
    for w in workloads:
        with obs_trace.span("explore.score", workload=w.name, hw=hw.name):
            opt, rec = compile_cached(w.build(), hw, cache=cache, workers=workers)
            t_compile += rec.compile_time_s
            score = score_pass_trace(rec.pass_trace, n_kernels=rec.n_kernels)
            # cross-check the trace-reported pressure against the scheduled
            # arena tags on the optimized program itself
            score.vmem_peak_bytes = max(score.vmem_peak_bytes, program_arena_peak(opt))
            scores[w.name] = score
    return scores, t_compile


def _aggregate(res: PointResult, scores: Mapping[str, ProgramScore]) -> None:
    res.scores = {w: s.to_json() for w, s in scores.items()}
    res.latency_s = sum(s.latency_s for s in scores.values())
    res.vmem_peak_bytes = max((s.vmem_peak_bytes for s in scores.values()), default=0)
    res.n_kernels = sum(s.n_kernels for s in scores.values())
    res.comm_bytes = sum(s.comm_bytes for s in scores.values())


def _score_point_task(space: SearchSpace, point: Dict[str, Any], index: int,
                      workload_spec: str, cache_dir: Optional[str]) -> Dict:
    """Process-pool task: score one point, JSON in / JSON out."""
    res = PointResult(index=index, config_name=space.point_name(point),
                      fingerprint="", point=dict(point))
    try:
        hw = space.apply(point)
        res.fingerprint = hw.fingerprint()
        cache = _cache.CompilationCache(disk_dir=cache_dir, use_disk=cache_dir is not None)
        scores, t = score_config(hw, get_workloads(workload_spec), cache=cache)
        _aggregate(res, scores)
        res.compile_time_s = t
    except Exception as e:  # a broken point must not kill the sweep
        res.error = f"{type(e).__name__}: {e}"
    return res.to_json()


def _run_points_parallel(space: SearchSpace, jobs: List[Tuple[int, Dict]],
                         workload_spec: str, cache_dir: Optional[str],
                         parallel: int) -> Optional[List[Dict]]:
    import concurrent.futures
    import multiprocessing

    try:
        # forkserver: children fork from a clean single-threaded server
        # process, never from this (jax-threaded) one — same rationale as
        # the parallel autotuner's pool
        try:
            ctx = multiprocessing.get_context("forkserver")
        except ValueError:
            ctx = multiprocessing.get_context("fork")
        with concurrent.futures.ProcessPoolExecutor(max_workers=parallel,
                                                    mp_context=ctx) as ex:
            futs = [ex.submit(_score_point_task, space, point, idx,
                              workload_spec, cache_dir)
                    for idx, point in jobs]
            return [f.result() for f in futs]
    except (OSError, ValueError, RuntimeError, ImportError):
        return None  # serial fallback — parallelism is never load-bearing


@dataclasses.dataclass
class SweepResult:
    space: SearchSpace
    workload_spec: str
    strategy: str
    baseline: PointResult
    points: List[PointResult]
    cache_stats: Dict[str, int]
    wall_time_s: float
    validation: Optional[Dict] = None
    measurement: Optional[Dict] = None  # measure-mode summary (tuning DB feed)

    def unique_points(self) -> List[PointResult]:
        return [p for p in self.points if p.dedup_of is None and not p.error]


def run_sweep(space: SearchSpace, workload_spec: str = "default", *,
              budget: int = 32, strategy: str = "grid", seed: int = 0,
              cache_dir: Optional[str] = None, parallel: int = 0,
              measure_top_k: int = 0, measure_backend: str = "jnp",
              measure: int = 0, tune_db=None) -> SweepResult:
    """Drive a full sweep.  ``cache_dir`` is the on-disk compilation-cache
    directory shared by all points/processes (None = in-memory only —
    sweeps never write the user's default ``~/.cache/stripe-repro``
    unless pointed there explicitly).  ``parallel`` > 1 fans unique
    points out over a process pool.  ``measure_top_k`` > 0 additionally
    runs the K best predicted points (plus the baseline) on the real
    ``measure_backend`` and records the measured ranking.

    ``measure`` > 0 runs the **measure mode**: up to that many candidate
    tilings per workload (analytic best, sweep-point winners, scaled
    perturbations) are wall-timed on pallas-interpret and every
    measurement lands in ``tune_db`` (a :class:`~repro.tune.TuningDB`;
    None opens one in ``cache_dir``) — later ``stripe_jit`` compiles of
    the same workload replay the measured winner."""
    with obs_trace.span("explore.sweep", strategy=strategy, budget=budget,
                        workloads=workload_spec):
        return _run_sweep(space, workload_spec, budget=budget,
                          strategy=strategy, seed=seed, cache_dir=cache_dir,
                          parallel=parallel, measure_top_k=measure_top_k,
                          measure_backend=measure_backend, measure=measure,
                          tune_db=tune_db)


def _run_sweep(space: SearchSpace, workload_spec: str = "default", *,
               budget: int = 32, strategy: str = "grid", seed: int = 0,
               cache_dir: Optional[str] = None, parallel: int = 0,
               measure_top_k: int = 0, measure_backend: str = "jnp",
               measure: int = 0, tune_db=None) -> SweepResult:
    t_start = time.perf_counter()
    workloads = get_workloads(workload_spec)
    cache = _cache.CompilationCache(disk_dir=cache_dir, use_disk=cache_dir is not None)

    # ---- baseline: the stock base config, scored on the same corpus ----
    base_hw = space.base_config()
    baseline = PointResult(index=-1, config_name=base_hw.name,
                           fingerprint=base_hw.fingerprint(), point={})
    scores, t = score_config(base_hw, workloads, cache=cache)
    _aggregate(baseline, scores)
    baseline.compile_time_s = t

    # ---- enumerate points -------------------------------------------------
    if strategy == "grid":
        points = space.grid(budget)
    elif strategy == "random":
        points = space.random(budget, seed=seed)
    elif strategy == "hillclimb":
        # interactive strategy: scored inline (sequentially), then folded
        # into the same result pipeline below via the score memo
        memo: Dict[str, PointResult] = {}

        def hc_score(point: Dict[str, Any]) -> float:
            hw = space.apply(point)
            fp = hw.fingerprint()
            if fp not in memo:
                res = PointResult(index=len(memo), config_name=hw.name,
                                  fingerprint=fp, point=dict(point))
                try:
                    s, tc = score_config(hw, workloads, cache=cache)
                    _aggregate(res, s)
                    res.compile_time_s = tc
                except Exception as e:
                    res.error = f"{type(e).__name__}: {e}"
                memo[fp] = res
            hit = memo[fp]
            # errored points never win the climb (and the inf sentinel
            # stays out of the serialized result)
            return float("inf") if hit.error else hit.latency_s

        points = space.hillclimb(budget, hc_score, seed=seed)
    else:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         "expected grid | random | hillclimb")

    # ---- fingerprint dedupe ----------------------------------------------
    # seeded with the baseline: a swept point that IS the stock config
    # (the grid strategy always revisits it) dedupes to index -1
    results: List[PointResult] = []
    first_by_fp: Dict[str, int] = {baseline.fingerprint: -1}
    jobs: List[Tuple[int, Dict]] = []
    for i, point in enumerate(points):
        hw = space.apply(point)
        fp = hw.fingerprint()
        res = PointResult(index=i, config_name=hw.name, fingerprint=fp,
                          point=dict(point))
        if fp in first_by_fp:
            res.dedup_of = first_by_fp[fp]
        else:
            first_by_fp[fp] = i
            jobs.append((i, point))
        results.append(res)

    # ---- score unique points ---------------------------------------------
    done: Optional[List[Dict]] = None
    if strategy == "hillclimb":
        done = []
        for idx, point in jobs:
            fp = results[idx].fingerprint
            hit = memo.get(fp)
            if hit is not None:
                d = hit.to_json()
                d["index"] = idx
                done.append(d)
            else:  # budget-exhausted point the climber never scored
                done.append(_score_point_task(space, point, idx, workload_spec,
                                              cache_dir))
    elif parallel and parallel > 1 and len(jobs) > 1:
        done = _run_points_parallel(space, jobs, workload_spec, cache_dir,
                                    parallel)
    if done is None:
        done = []
        for idx, point in jobs:
            hw = space.apply(point)
            res = results[idx]
            try:
                s, tc = score_config(hw, workloads, cache=cache)
                _aggregate(res, s)
                res.compile_time_s = tc
            except Exception as e:
                res.error = f"{type(e).__name__}: {e}"
            done.append(res.to_json())

    for d in done:
        res = results[d["index"]]
        # copy only the scored fields: identity (index/point/fingerprint/
        # dedup_of) was fixed by the dedupe pass above
        for f in ("scores", "latency_s", "vmem_peak_bytes", "n_kernels",
                  "comm_bytes", "compile_time_s", "error"):
            setattr(res, f, d[f])
    # deduped points reference (and copy the scores of) their original
    # (-1 = the baseline itself)
    for res in results:
        if res.dedup_of is not None:
            orig = baseline if res.dedup_of == -1 else results[res.dedup_of]
            res.scores = orig.scores
            res.latency_s = orig.latency_s
            res.vmem_peak_bytes = orig.vmem_peak_bytes
            res.n_kernels = orig.n_kernels
            res.comm_bytes = orig.comm_bytes
            res.error = orig.error

    sweep = SweepResult(space=space, workload_spec=workload_spec,
                        strategy=strategy, baseline=baseline, points=results,
                        cache_stats=cache.stats.as_dict(),
                        wall_time_s=time.perf_counter() - t_start)
    if measure_top_k > 0:
        sweep.validation = validate_top_k(sweep, measure_top_k,
                                          backend=measure_backend, cache=cache,
                                          db=tune_db)
    if measure > 0:
        if tune_db is None:
            from ..tune.db import TuningDB

            tune_db = TuningDB(dir=cache_dir)
        sweep.measurement = measure_candidates(sweep, db=tune_db,
                                               max_candidates=measure,
                                               cache=cache)
    sweep.wall_time_s = time.perf_counter() - t_start
    return sweep


# --------------------------------------------------------------------------
# Measured validation (cost model predicts, measurement validates)
# --------------------------------------------------------------------------
def _random_arrays(prog, seed: int = 0):
    import numpy as np

    rng = np.random.RandomState(seed)
    arrays = {}
    for name in prog.inputs:
        decl = prog.buffers[name]
        if decl.dtype.startswith("int"):
            arrays[name] = rng.randint(-3, 4, size=decl.shape).astype(decl.dtype)
        else:
            import jax.numpy as jnp

            arrays[name] = jnp.asarray(rng.randn(*decl.shape),
                                       jnp.dtype(decl.dtype))
    return arrays


def _timed_thunk(compiled, arrays):
    import jax

    def thunk():
        jax.block_until_ready(compiled(arrays))
    return thunk


def validate_top_k(sweep: SweepResult, k: int, backend: str = "jnp",
                   cache=None, rounds: int = DEFAULT_ROUNDS,
                   calls: int = DEFAULT_CALLS, db=None) -> Dict:
    """Measure the K best predicted points plus the baseline on a real
    backend; report predicted vs measured ranking.

    Timing uses the min-of-interleaved-rounds estimator (all candidates
    compile and warm first, then alternate within each round — a noise
    burst inflates one round of everything instead of biasing whichever
    config ran last), with the round count recorded in the result.  When
    ``db`` is a :class:`~repro.tune.TuningDB`, every measurement is also
    recorded there."""
    workloads = get_workloads(sweep.workload_spec)
    ranked = sorted(sweep.unique_points(), key=lambda p: p.latency_s)[:k]
    entries = []
    thunks: Dict[Tuple[int, str], Any] = {}
    records: Dict[Tuple[int, str], Any] = {}
    for pos, res in enumerate([sweep.baseline] + ranked):
        entry = {"index": res.index, "config": res.config_name,
                 "predicted_latency_s": res.latency_s, "error": ""}
        with obs_trace.span("explore.validate", config=res.config_name,
                            backend=backend) as sp:
            try:
                hw = sweep.space.base_config() if res.index < 0 else sweep.space.apply(res.point)
                for w in workloads:
                    compiled = stripe_jit(w.build(), hw, backend=backend,
                                          cache=cache)
                    arrays = _random_arrays(compiled.program.source
                                            or compiled.program)
                    thunks[(pos, w.name)] = _timed_thunk(compiled, arrays)
                    records[(pos, w.name)] = compiled.record
            except Exception as e:
                entry["error"] = f"{type(e).__name__}: {e}"
                entry["measured_total_us"] = None  # JSON-safe; ranked last
                sp.set(error=entry["error"])
        entries.append(entry)

    measures = measure_interleaved(thunks, rounds=rounds, calls=calls)
    for pos, entry in enumerate(entries):
        if entry["error"]:
            continue
        per_wl = {w.name: measures[(pos, w.name)].min_s * 1e6
                  for w in workloads if (pos, w.name) in measures}
        if len(per_wl) < len(workloads):
            entry["error"] = "measurement dropped (thunk failed in warmup)"
            entry["measured_total_us"] = None
            continue
        entry["measured_us"] = per_wl
        entry["measured_total_us"] = sum(per_wl.values())
    if db is not None:
        for key, m in measures.items():
            rec = records.get(key)
            if rec is None or not rec.ir_fingerprint:
                continue
            db.record(rec.ir_fingerprint, rec.hw_fingerprint, backend, True,
                      tilings=rec.tilings, measured_s=m.min_s,
                      predicted_s=score_pass_trace(rec.pass_trace).latency_s,
                      block_backends=rec.block_backends, rounds=m.rounds,
                      calls=m.calls, source="explore.validate",
                      workload=key[1])
    by_pred = sorted(entries, key=lambda e: e["predicted_latency_s"])
    by_meas = sorted(entries, key=lambda e: (e["measured_total_us"] is None,
                                             e["measured_total_us"] or 0.0))
    return {
        "top_k": k, "backend": backend, "entries": entries,
        "rounds": rounds, "calls": calls,
        "estimator": "min-of-interleaved-rounds",
        "predicted_rank": [e["index"] for e in by_pred],
        "measured_rank": [e["index"] for e in by_meas],
    }


# --------------------------------------------------------------------------
# Measure mode: candidate tilings -> wall time -> tuning DB
# --------------------------------------------------------------------------
def _scale_tiling(tilings: Mapping[str, Mapping[str, int]],
                  factor: float) -> Dict[str, Dict[str, int]]:
    return {blk: {v: max(1, int(t * factor)) for v, t in tiles.items()}
            for blk, tiles in tilings.items()}


def _candidate_tilings(sweep: SweepResult, workload: Workload, base_tilings,
                       cache, max_candidates: int) -> List[Dict[str, Dict[str, int]]]:
    """Candidate tilings for one workload, analytic first: the base
    config's analytic choice, sweep-point winners' tilings remapped onto
    the base blocks (by block name — a point whose fusion decisions
    differ contributes only its matching groups), and global halve /
    double perturbations of the analytic tiles."""
    from ..tune.db import candidate_id as cid

    cands: List[Dict[str, Dict[str, int]]] = [dict(base_tilings)]
    seen = {cid(base_tilings)}

    def add(c):
        key = cid(c)
        if key not in seen and len(cands) < max_candidates:
            seen.add(key)
            cands.append(c)

    base_by_name = {k.split("#")[0]: k for k in base_tilings}
    for p in sorted(sweep.unique_points(), key=lambda r: r.latency_s):
        if len(cands) >= max_candidates:
            break
        try:
            hw = sweep.space.apply(p.point)
            _, rec = compile_cached(workload.build(), hw, cache=cache)
        except Exception:
            continue
        remapped = dict(base_tilings)
        hit = False
        for key, tiles in rec.tilings.items():
            bk = base_by_name.get(key.split("#")[0])
            if bk is not None and remapped[bk] != tiles:
                remapped[bk] = dict(tiles)
                hit = True
        if hit:
            add(remapped)
    for factor in (0.5, 2.0, 0.25):
        add(_scale_tiling(base_tilings, factor))
    return cands


def measure_candidates(sweep: SweepResult, *, db, backend: str = "pallas",
                       max_candidates: int = 6, rounds: int = 2,
                       calls: int = 1, reject_factor: float = 5.0,
                       cache=None) -> Dict:
    """Measure-mode autotuning: wall-time candidate tilings per workload
    on the sweep's base config and record **every** measurement into the
    tuning DB (``db``); the measured winner becomes the entry's best,
    which later ``stripe_jit(..., tune=...)`` compiles replay.

    Candidates run on ``backend`` under ``interpret=True`` (tile sizes
    change the pallas grid, so interpreted wall time carries real tiling
    signal; the jnp lowering is tiling-independent).  A real-hardware
    timer drops in via ``measure_interleaved``'s ``timer`` hook — the
    estimator and DB schema don't change.  The analytic choice is always
    candidate 0, so the summary's ``improved`` flag is measured-winner
    vs analytic on identical harnesses.

    Interpreted wall time grows with grid-step count, so a badly-tiled
    candidate can cost 100x the analytic one per call: any candidate
    whose single warmup call runs slower than ``reject_factor`` x the
    analytic warmup is **early-rejected** — recorded in the DB from that
    one shot (``rounds=1``, honestly labeled) instead of burning full
    interleaved rounds on a certain loser."""
    base_hw = sweep.space.base_config()
    workloads = get_workloads(sweep.workload_spec)
    summary: Dict[str, Any] = {"backend": backend, "interpret": True,
                               "rounds": rounds, "calls": calls,
                               "workloads": {}}
    for w in workloads:
        with obs_trace.span("explore.measure", workload=w.name,
                            backend=backend):
            try:
                _, base_rec = compile_cached(w.build(), base_hw, cache=cache)
            except Exception as e:
                summary["workloads"][w.name] = {
                    "error": f"{type(e).__name__}: {e}"}
                continue
            cands = _candidate_tilings(sweep, w, base_rec.tilings, cache,
                                       max_candidates)
            thunks: Dict[int, Any] = {}
            meta: Dict[int, Any] = {}
            warm_s: Dict[int, float] = {}
            for i, cand in enumerate(cands):
                try:
                    compiled = compile_with_tilings(
                        w.build(), base_hw, cand, backend=backend,
                        interpret=True)
                    arrays = _random_arrays(compiled.program.source
                                            or compiled.program)
                    thunk = _timed_thunk(compiled, arrays)
                    t0 = time.perf_counter()
                    thunk()  # trace + compile + one warm execution
                    warm_s[i] = time.perf_counter() - t0
                    thunks[i] = thunk
                    meta[i] = compiled.record
                except Exception:
                    continue  # an infeasible perturbation is just skipped
            cut = (reject_factor * warm_s[0]
                   if 0 in warm_s and reject_factor > 0 else None)
            rejected = {i for i in thunks
                        if cut is not None and i != 0 and warm_s[i] > cut}
            measures = measure_interleaved(
                {i: thunks[i] for i in thunks if i not in rejected},
                rounds=rounds, calls=calls, warmup=0)
            wl: Dict[str, Any] = {"n_candidates": len(measures) + len(rejected),
                                  "n_rejected": len(rejected),
                                  "analytic_s": None, "best_s": None,
                                  "best_candidate": None, "improved": False}
            timings = {i: (m.min_s, m.rounds, m.calls)
                       for i, m in measures.items()}
            for i in rejected:  # one-shot evidence: still worth keeping
                timings[i] = (warm_s[i], 1, 1)
            for i, (min_s, n_rounds, n_calls) in sorted(timings.items()):
                rec = meta[i]
                predicted = score_pass_trace(rec.pass_trace).latency_s
                cid = db.record(
                    base_rec.ir_fingerprint, base_rec.hw_fingerprint,
                    backend, True, tilings=rec.tilings, measured_s=min_s,
                    predicted_s=predicted, rounds=n_rounds, calls=n_calls,
                    source=("explore.measure.rejected" if i in rejected
                            else "explore.measure"), workload=w.name)
                if i == 0:
                    wl["analytic_s"] = min_s
                if wl["best_s"] is None or min_s < wl["best_s"]:
                    wl["best_s"] = min_s
                    wl["best_candidate"] = cid
            if wl["analytic_s"] is not None and wl["best_s"] is not None:
                wl["improved"] = wl["best_s"] < wl["analytic_s"]
                wl["speedup_vs_analytic"] = (wl["analytic_s"] / wl["best_s"]
                                             if wl["best_s"] else None)
            summary["workloads"][w.name] = wl
    return summary
