"""Parallel sweep driver.

The pipeline per sweep point:

1. materialize the point's :class:`HardwareConfig` (``space.apply``);
2. **dedupe by fingerprint** — the config name never enters
   ``HardwareConfig.fingerprint()``, so two points that compile
   identically share one compilation-cache entry and the later one is
   never recompiled (it references the earlier result);
3. compile every corpus workload through ``compile_cached`` — the
   sweep-friendly driver entry that runs the pass pipeline under the
   two-level cache but never builds a backend;
4. score the pass trace analytically (``cost.score_pass_trace``):
   predicted latency (roofline), VMEM arena pressure, kernels launched.

Unique points fan out over a process pool (workers recompute from the
shared on-disk cache directory, so a re-run of the same sweep replays
recorded tilings instead of searching).  Optionally the top-K points by
predicted latency are *validated by measurement*: each workload is
lowered through ``stripe_jit`` on a real backend (jnp by default) and
timed, and the measured ranking is recorded next to the predicted one.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from ..core import cache as _cache
from ..core.cost import ProgramScore, score_pass_trace
from ..obs import trace as obs_trace
from ..core.driver import compile_cached, stripe_jit
from ..core.hwconfig import HardwareConfig
from .space import SearchSpace
from .workloads import Workload, get_workloads


@dataclasses.dataclass
class PointResult:
    """One sweep point's outcome — JSON-able for the report."""

    index: int
    config_name: str
    fingerprint: str
    point: Dict[str, Any]
    scores: Dict[str, Dict] = dataclasses.field(default_factory=dict)  # workload -> ProgramScore json
    latency_s: float = 0.0          # sum of per-workload predicted latencies
    vmem_peak_bytes: int = 0        # max across workloads
    n_kernels: int = 0              # sum across workloads (dispatches per corpus pass)
    compile_time_s: float = 0.0
    dedup_of: Optional[int] = None  # earlier point index with the same fingerprint
    error: str = ""

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    def workload_latency(self, workload: str) -> float:
        return float(self.scores[workload]["latency_s"])


def score_config(hw: HardwareConfig, workloads: Sequence[Workload],
                 cache: Optional[_cache.CompilationCache] = None,
                 workers: Optional[int] = None) -> Tuple[Dict[str, ProgramScore], float]:
    """Compile + analytically score every workload on one config."""
    from ..core.passes.schedule import program_arena_peak

    scores: Dict[str, ProgramScore] = {}
    t_compile = 0.0
    for w in workloads:
        with obs_trace.span("explore.score", workload=w.name, hw=hw.name):
            opt, rec = compile_cached(w.build(), hw, cache=cache, workers=workers)
            t_compile += rec.compile_time_s
            score = score_pass_trace(rec.pass_trace, n_kernels=rec.n_kernels)
            # cross-check the trace-reported pressure against the scheduled
            # arena tags on the optimized program itself
            score.vmem_peak_bytes = max(score.vmem_peak_bytes, program_arena_peak(opt))
            scores[w.name] = score
    return scores, t_compile


def _aggregate(res: PointResult, scores: Mapping[str, ProgramScore]) -> None:
    res.scores = {w: s.to_json() for w, s in scores.items()}
    res.latency_s = sum(s.latency_s for s in scores.values())
    res.vmem_peak_bytes = max((s.vmem_peak_bytes for s in scores.values()), default=0)
    res.n_kernels = sum(s.n_kernels for s in scores.values())


def _score_point_task(space: SearchSpace, point: Dict[str, Any], index: int,
                      workload_spec: str, cache_dir: Optional[str]) -> Dict:
    """Process-pool task: score one point, JSON in / JSON out."""
    res = PointResult(index=index, config_name=space.point_name(point),
                      fingerprint="", point=dict(point))
    try:
        hw = space.apply(point)
        res.fingerprint = hw.fingerprint()
        cache = _cache.CompilationCache(disk_dir=cache_dir, use_disk=cache_dir is not None)
        scores, t = score_config(hw, get_workloads(workload_spec), cache=cache)
        _aggregate(res, scores)
        res.compile_time_s = t
    except Exception as e:  # a broken point must not kill the sweep
        res.error = f"{type(e).__name__}: {e}"
    return res.to_json()


def _run_points_parallel(space: SearchSpace, jobs: List[Tuple[int, Dict]],
                         workload_spec: str, cache_dir: Optional[str],
                         parallel: int) -> Optional[List[Dict]]:
    import concurrent.futures
    import multiprocessing

    try:
        # forkserver: children fork from a clean single-threaded server
        # process, never from this (jax-threaded) one — same rationale as
        # the parallel autotuner's pool
        try:
            ctx = multiprocessing.get_context("forkserver")
        except ValueError:
            ctx = multiprocessing.get_context("fork")
        with concurrent.futures.ProcessPoolExecutor(max_workers=parallel,
                                                    mp_context=ctx) as ex:
            futs = [ex.submit(_score_point_task, space, point, idx,
                              workload_spec, cache_dir)
                    for idx, point in jobs]
            return [f.result() for f in futs]
    except (OSError, ValueError, RuntimeError, ImportError):
        return None  # serial fallback — parallelism is never load-bearing


@dataclasses.dataclass
class SweepResult:
    space: SearchSpace
    workload_spec: str
    strategy: str
    baseline: PointResult
    points: List[PointResult]
    cache_stats: Dict[str, int]
    wall_time_s: float
    validation: Optional[Dict] = None

    def unique_points(self) -> List[PointResult]:
        return [p for p in self.points if p.dedup_of is None and not p.error]


def run_sweep(space: SearchSpace, workload_spec: str = "default", *,
              budget: int = 32, strategy: str = "grid", seed: int = 0,
              cache_dir: Optional[str] = None, parallel: int = 0,
              measure_top_k: int = 0, measure_backend: str = "jnp") -> SweepResult:
    """Drive a full sweep.  ``cache_dir`` is the on-disk compilation-cache
    directory shared by all points/processes (None = in-memory only —
    sweeps never write the user's default ``~/.cache/stripe-repro``
    unless pointed there explicitly).  ``parallel`` > 1 fans unique
    points out over a process pool.  ``measure_top_k`` > 0 additionally
    runs the K best predicted points (plus the baseline) on the real
    ``measure_backend`` and records the measured ranking."""
    with obs_trace.span("explore.sweep", strategy=strategy, budget=budget,
                        workloads=workload_spec):
        return _run_sweep(space, workload_spec, budget=budget,
                          strategy=strategy, seed=seed, cache_dir=cache_dir,
                          parallel=parallel, measure_top_k=measure_top_k,
                          measure_backend=measure_backend)


def _run_sweep(space: SearchSpace, workload_spec: str = "default", *,
               budget: int = 32, strategy: str = "grid", seed: int = 0,
               cache_dir: Optional[str] = None, parallel: int = 0,
               measure_top_k: int = 0, measure_backend: str = "jnp") -> SweepResult:
    t_start = time.perf_counter()
    workloads = get_workloads(workload_spec)
    cache = _cache.CompilationCache(disk_dir=cache_dir, use_disk=cache_dir is not None)

    # ---- baseline: the stock base config, scored on the same corpus ----
    base_hw = space.base_config()
    baseline = PointResult(index=-1, config_name=base_hw.name,
                           fingerprint=base_hw.fingerprint(), point={})
    scores, t = score_config(base_hw, workloads, cache=cache)
    _aggregate(baseline, scores)
    baseline.compile_time_s = t

    # ---- enumerate points -------------------------------------------------
    if strategy == "grid":
        points = space.grid(budget)
    elif strategy == "random":
        points = space.random(budget, seed=seed)
    elif strategy == "hillclimb":
        # interactive strategy: scored inline (sequentially), then folded
        # into the same result pipeline below via the score memo
        memo: Dict[str, PointResult] = {}

        def hc_score(point: Dict[str, Any]) -> float:
            hw = space.apply(point)
            fp = hw.fingerprint()
            if fp not in memo:
                res = PointResult(index=len(memo), config_name=hw.name,
                                  fingerprint=fp, point=dict(point))
                try:
                    s, tc = score_config(hw, workloads, cache=cache)
                    _aggregate(res, s)
                    res.compile_time_s = tc
                except Exception as e:
                    res.error = f"{type(e).__name__}: {e}"
                memo[fp] = res
            hit = memo[fp]
            # errored points never win the climb (and the inf sentinel
            # stays out of the serialized result)
            return float("inf") if hit.error else hit.latency_s

        points = space.hillclimb(budget, hc_score, seed=seed)
    else:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         "expected grid | random | hillclimb")

    # ---- fingerprint dedupe ----------------------------------------------
    # seeded with the baseline: a swept point that IS the stock config
    # (the grid strategy always revisits it) dedupes to index -1
    results: List[PointResult] = []
    first_by_fp: Dict[str, int] = {baseline.fingerprint: -1}
    jobs: List[Tuple[int, Dict]] = []
    for i, point in enumerate(points):
        hw = space.apply(point)
        fp = hw.fingerprint()
        res = PointResult(index=i, config_name=hw.name, fingerprint=fp,
                          point=dict(point))
        if fp in first_by_fp:
            res.dedup_of = first_by_fp[fp]
        else:
            first_by_fp[fp] = i
            jobs.append((i, point))
        results.append(res)

    # ---- score unique points ---------------------------------------------
    done: Optional[List[Dict]] = None
    if strategy == "hillclimb":
        done = []
        for idx, point in jobs:
            fp = results[idx].fingerprint
            hit = memo.get(fp)
            if hit is not None:
                d = hit.to_json()
                d["index"] = idx
                done.append(d)
            else:  # budget-exhausted point the climber never scored
                done.append(_score_point_task(space, point, idx, workload_spec,
                                              cache_dir))
    elif parallel and parallel > 1 and len(jobs) > 1:
        done = _run_points_parallel(space, jobs, workload_spec, cache_dir,
                                    parallel)
    if done is None:
        done = []
        for idx, point in jobs:
            hw = space.apply(point)
            res = results[idx]
            try:
                s, tc = score_config(hw, workloads, cache=cache)
                _aggregate(res, s)
                res.compile_time_s = tc
            except Exception as e:
                res.error = f"{type(e).__name__}: {e}"
            done.append(res.to_json())

    for d in done:
        res = results[d["index"]]
        # copy only the scored fields: identity (index/point/fingerprint/
        # dedup_of) was fixed by the dedupe pass above
        for f in ("scores", "latency_s", "vmem_peak_bytes", "n_kernels",
                  "compile_time_s", "error"):
            setattr(res, f, d[f])
    # deduped points reference (and copy the scores of) their original
    # (-1 = the baseline itself)
    for res in results:
        if res.dedup_of is not None:
            orig = baseline if res.dedup_of == -1 else results[res.dedup_of]
            res.scores = orig.scores
            res.latency_s = orig.latency_s
            res.vmem_peak_bytes = orig.vmem_peak_bytes
            res.n_kernels = orig.n_kernels
            res.error = orig.error

    sweep = SweepResult(space=space, workload_spec=workload_spec,
                        strategy=strategy, baseline=baseline, points=results,
                        cache_stats=cache.stats.as_dict(),
                        wall_time_s=time.perf_counter() - t_start)
    if measure_top_k > 0:
        sweep.validation = validate_top_k(sweep, measure_top_k,
                                          backend=measure_backend, cache=cache)
    sweep.wall_time_s = time.perf_counter() - t_start
    return sweep


# --------------------------------------------------------------------------
# Measured validation (cost model predicts, measurement validates)
# --------------------------------------------------------------------------
def _random_arrays(prog, seed: int = 0):
    import numpy as np

    rng = np.random.RandomState(seed)
    arrays = {}
    for name in prog.inputs:
        decl = prog.buffers[name]
        if decl.dtype.startswith("int"):
            arrays[name] = rng.randint(-3, 4, size=decl.shape).astype(decl.dtype)
        else:
            import jax.numpy as jnp

            arrays[name] = jnp.asarray(rng.randn(*decl.shape),
                                       jnp.dtype(decl.dtype))
    return arrays


def _measure_config(hw: HardwareConfig, workloads: Sequence[Workload],
                    backend: str, cache, n: int = 3) -> Dict[str, float]:
    import jax

    out: Dict[str, float] = {}
    for w in workloads:
        prog = w.build()
        compiled = stripe_jit(prog, hw, backend=backend, cache=cache)
        arrays = _random_arrays(compiled.program.source or compiled.program)
        jax.block_until_ready(compiled(arrays))  # compile + warm
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(compiled(arrays))
        out[w.name] = (time.perf_counter() - t0) / n * 1e6  # us/call
    return out


def validate_top_k(sweep: SweepResult, k: int, backend: str = "jnp",
                   cache=None) -> Dict:
    """Measure the K best predicted points plus the baseline on a real
    backend; report predicted vs measured ranking."""
    workloads = get_workloads(sweep.workload_spec)
    ranked = sorted(sweep.unique_points(), key=lambda p: p.latency_s)[:k]
    entries = []
    for res in [sweep.baseline] + ranked:
        entry = {"index": res.index, "config": res.config_name,
                 "predicted_latency_s": res.latency_s, "error": ""}
        with obs_trace.span("explore.validate", config=res.config_name,
                            backend=backend) as sp:
            try:
                hw = sweep.space.base_config() if res.index < 0 else sweep.space.apply(res.point)
                per_wl = _measure_config(hw, workloads, backend, cache)
                entry["measured_us"] = per_wl
                entry["measured_total_us"] = sum(per_wl.values())
            except Exception as e:
                entry["error"] = f"{type(e).__name__}: {e}"
                entry["measured_total_us"] = None  # JSON-safe; ranked last
                sp.set(error=entry["error"])
        entries.append(entry)
    by_pred = sorted(entries, key=lambda e: e["predicted_latency_s"])
    by_meas = sorted(entries, key=lambda e: (e["measured_total_us"] is None,
                                             e["measured_total_us"] or 0.0))
    return {
        "top_k": k, "backend": backend, "entries": entries,
        "predicted_rank": [e["index"] for e in by_pred],
        "measured_rank": [e["index"] for e in by_meas],
    }
