"""Measured-feedback autotuning (ROADMAP item 2).

Three pieces close the loop between the analytic cost model and real
measurement:

* :mod:`repro.tune.db` — the persistent :class:`TuningDB` keyed by
  IR fingerprint x hardware fingerprint x backend x interpret-mode,
  recording measured candidate tilings and serving the measured best
  back into ``stripe_jit`` (decision source ``tuned``);
* :mod:`repro.tune.measure` — the min-of-interleaved-rounds timing
  harness every DB-feeding measurement goes through;
* :mod:`repro.tune.calibrate` — robust per-term regression fitting
  ``measured ~= a*t_mem + b*t_compute + c`` from accumulated residual
  pairs, activated per hardware fingerprint so ``evaluate_tiling``
  predicts calibrated latencies.
"""
from .calibrate import (
    Calibration,
    clear_calibrations,
    fit_calibration,
    get_calibration,
    load_calibrations,
    save_calibrations,
    set_calibration,
)
from .db import TunedEntry, TuningDB, candidate_id, entry_key
from .measure import Measurement, measure_interleaved

__all__ = [
    "Calibration",
    "Measurement",
    "TunedEntry",
    "TuningDB",
    "candidate_id",
    "clear_calibrations",
    "entry_key",
    "fit_calibration",
    "get_calibration",
    "load_calibrations",
    "measure_interleaved",
    "save_calibrations",
    "set_calibration",
]
