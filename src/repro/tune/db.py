"""The measured-feedback tuning database (ROADMAP item 2).

A persistent store layered over the compilation cache's disk directory,
keyed by **IR fingerprint x hardware fingerprint x backend x
interpret-mode** — exactly the identity ``stripe_jit`` compiles under —
recording *measured* latencies for candidate tilings (and per-unit
backend choices) so the driver can replay the measured winner instead of
trusting the analytic cost model.  Following Tensor Comprehensions'
autotuner cache: every measurement ever taken is kept (deduped by
candidate content), and the best survives as the entry's ``best``.

One JSON file (``tuning_db.json``) holds the whole database:

    {"version": 1,
     "entries": {<key>: {"ir_fingerprint": ..., "hw_fingerprint": ...,
                         "backend": "pallas", "interpret": true,
                         "workload": "mm_bias_gelu", "updated_ts": ...,
                         "candidates": {<cid>: {"tilings": {...},
                                                "block_backends": {...},
                                                "measured_s": 1.2e-3,
                                                "predicted_s": 8.0e-6,
                                                "rounds": 4, "calls": 2,
                                                "source": "explore.measure",
                                                "ts": ...}},
                         "best": <cid>}},
     "residual_summaries": {<skey>: {"hw_fingerprint": ..., "backend": ...,
                                     "interpret": ..., "rows": n,
                                     "pairs": k, "sum_log_ratio": x}}}

``residual_summaries`` receives rows compacted out of the profiling
residual log (``obs.profile.append_residuals`` rotation), so the
combined measured/predicted bias survives log rotation.

Durability: writes go through read-merge-write under an ``fcntl.flock``
file lock (cross-process) plus a thread lock (in-process), published
atomically via tempfile + ``os.replace``.  The write path honors the
``cache.disk_write_torn`` fault site exactly like ``cache.put_disk``, so
the fault-injection tests can force a torn final file; the read side
recovers a corrupt database by moving it aside and starting empty —
a broken DB must never fail a compile.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional

from ..core.cache import content_key, default_cache_dir, stable_hash
from ..reliability import faults

DB_VERSION = 1
DB_NAME = "tuning_db.json"

try:
    import fcntl
except ImportError:  # non-POSIX: atomic replace alone is the guarantee
    fcntl = None


def entry_key(ir_fingerprint: str, hw_fingerprint: str, backend: str,
              interpret: bool) -> str:
    """The DB key of one (program, hardware, backend, interpret) point."""
    return content_key("tune-entry", ir_fingerprint, hw_fingerprint,
                       str(backend), bool(interpret))


def candidate_id(tilings: Mapping[str, Mapping[str, int]],
                 block_backends: Optional[Mapping[str, str]] = None) -> str:
    """Content id of one candidate: the tiling assignment plus any
    per-unit backend overrides.  Doubles as the tuned-artifact cache-key
    component — a better measurement changes the id, which re-keys (and
    therefore recompiles) the tuned artifact."""
    return stable_hash([
        {k: dict(v) for k, v in sorted(tilings.items())},
        dict(sorted((block_backends or {}).items())),
    ])[:16]


@dataclasses.dataclass
class TunedEntry:
    """The measured-best candidate ``TuningDB.lookup`` serves back."""

    tilings: Dict[str, Dict[str, int]]
    block_backends: Dict[str, str]
    measured_s: float
    predicted_s: Optional[float]
    source: str
    rounds: int
    ts: float
    workload: str
    candidate_id: str
    n_candidates: int = 1

    @property
    def fingerprint(self) -> str:
        """What the driver folds into the compile cache key."""
        return self.candidate_id

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class TuningDB:
    """The persistent tuning database (one JSON file, see module doc).

    ``dir`` defaults to the process cache directory
    (``$STRIPE_CACHE_DIR`` or ``~/.cache/stripe-repro``) so the DB lives
    next to the disk compilation cache it feeds.  ``max_age_s`` bounds
    candidate freshness: ``lookup`` ignores measurements older than it
    (None = measurements never expire).
    """

    def __init__(self, dir: Optional[os.PathLike] = None, name: str = DB_NAME,
                 max_age_s: Optional[float] = None):
        self.dir = Path(dir) if dir is not None else default_cache_dir()
        self.path = self.dir / name
        self.max_age_s = max_age_s
        self.recovered = 0      # corrupt-file recoveries observed by loads
        self.write_errors = 0   # swallowed write failures (incl. injected)
        self._lock = threading.Lock()

    # ----------------------------------------------------------------- io
    @contextlib.contextmanager
    def _file_lock(self):
        """Cross-process exclusive lock for read-merge-write cycles.
        Lock-file failures degrade to lockless atomic-replace (last
        writer wins) — durability hiccups never break recording."""
        if fcntl is None:
            yield
            return
        lock_path = self.path.with_suffix(".lock")
        try:
            lock_path.parent.mkdir(parents=True, exist_ok=True)
            f = open(lock_path, "w")
        except OSError:
            yield
            return
        try:
            fcntl.flock(f, fcntl.LOCK_EX)
            yield
        finally:
            try:
                fcntl.flock(f, fcntl.LOCK_UN)
            finally:
                f.close()

    def _empty(self) -> Dict[str, Any]:
        return {"version": DB_VERSION, "entries": {}, "residual_summaries": {}}

    def load(self) -> Dict[str, Any]:
        """The whole database document; a corrupt or torn file is moved
        aside (``<name>.corrupt``) and replaced by an empty DB — the
        reader recovers, never raises."""
        try:
            raw = self.path.read_text()
        except OSError:
            return self._empty()
        try:
            doc = json.loads(raw)
            if not isinstance(doc, dict) or "entries" not in doc:
                raise ValueError("not a tuning DB document")
        except ValueError:
            self.recovered += 1
            try:
                os.replace(self.path, self.path.with_suffix(".corrupt"))
            except OSError:
                pass
            return self._empty()
        if doc.get("version") != DB_VERSION:
            # incompatible schema: start fresh (the next write replaces it)
            return self._empty()
        doc.setdefault("entries", {})
        doc.setdefault("residual_summaries", {})
        return doc

    def _store(self, doc: Dict[str, Any]) -> bool:
        try:
            data = json.dumps(doc, sort_keys=True)
        except (TypeError, ValueError):
            self.write_errors += 1
            return False
        if faults.fires("cache.disk_write_torn", key=str(self.path)):
            # same torn-write semantics as cache.put_disk: a truncated
            # document lands at the final path; load() must recover it
            try:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                self.path.write_text(data[: max(1, len(data) // 2)])
            except OSError:
                pass
            self.write_errors += 1
            return False
        try:
            faults.check("cache.disk_write", key=str(self.path))
            self.path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=str(self.path.parent), suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(data)
                os.replace(tmp, self.path)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
        except (faults.InjectedFault, OSError):
            self.write_errors += 1
            return False
        return True

    # ------------------------------------------------------------- record
    def record(self, ir_fingerprint: str, hw_fingerprint: str, backend: str,
               interpret: bool, *, tilings: Mapping[str, Mapping[str, int]],
               measured_s: float, predicted_s: Optional[float] = None,
               block_backends: Optional[Mapping[str, str]] = None,
               rounds: int = 1, calls: int = 1, source: str = "",
               workload: str = "") -> str:
        """Record one measurement; returns the candidate id.  Re-measuring
        a known candidate keeps the *minimum* (the noise-robust
        estimator, matching the interleaved-rounds harness)."""
        key = entry_key(ir_fingerprint, hw_fingerprint, backend, interpret)
        cid = candidate_id(tilings, block_backends)
        cand = {
            "tilings": {k: {v: int(t) for v, t in tv.items()}
                        for k, tv in tilings.items()},
            "block_backends": dict(block_backends or {}),
            "measured_s": float(measured_s),
            "predicted_s": (float(predicted_s) if predicted_s is not None
                            else None),
            "rounds": int(rounds), "calls": int(calls),
            "source": str(source), "ts": time.time(),
        }
        with self._lock, self._file_lock():
            doc = self.load()
            entry = doc["entries"].setdefault(key, {
                "ir_fingerprint": ir_fingerprint,
                "hw_fingerprint": hw_fingerprint,
                "backend": str(backend), "interpret": bool(interpret),
                "workload": str(workload), "candidates": {}, "best": None,
            })
            if workload and not entry.get("workload"):
                entry["workload"] = str(workload)
            prev = entry["candidates"].get(cid)
            if prev is not None and prev.get("measured_s", float("inf")) <= cand["measured_s"]:
                prev["ts"] = cand["ts"]  # refresh, keep the better minimum
                prev["rounds"] = max(int(prev.get("rounds", 1)), cand["rounds"])
            else:
                entry["candidates"][cid] = cand
            entry["best"] = min(
                entry["candidates"],
                key=lambda c: entry["candidates"][c].get("measured_s", float("inf")))
            entry["updated_ts"] = time.time()
            self._store(doc)
        return cid

    # ------------------------------------------------------------- lookup
    def lookup(self, ir_fingerprint: str, hw_fingerprint: str, backend: str,
               interpret: bool,
               max_age_s: Optional[float] = None) -> Optional[TunedEntry]:
        """The measured-best fresh candidate for one compile identity, or
        None (no entry, or everything staler than the freshness bound)."""
        age_cap = max_age_s if max_age_s is not None else self.max_age_s
        key = entry_key(ir_fingerprint, hw_fingerprint, backend, interpret)
        entry = self.load()["entries"].get(key)
        if not entry:
            return None
        now = time.time()
        fresh = {cid: c for cid, c in entry.get("candidates", {}).items()
                 if isinstance(c, dict) and c.get("measured_s") is not None
                 and (age_cap is None or now - float(c.get("ts", 0)) <= age_cap)}
        if not fresh:
            return None
        cid = min(fresh, key=lambda c: float(fresh[c]["measured_s"]))
        c = fresh[cid]
        return TunedEntry(
            tilings={k: {v: int(t) for v, t in tv.items()}
                     for k, tv in c.get("tilings", {}).items()},
            block_backends=dict(c.get("block_backends", {})),
            measured_s=float(c["measured_s"]),
            predicted_s=c.get("predicted_s"),
            source=str(c.get("source", "")), rounds=int(c.get("rounds", 1)),
            ts=float(c.get("ts", 0.0)), workload=str(entry.get("workload", "")),
            candidate_id=cid, n_candidates=len(entry.get("candidates", {})))

    def entries(self) -> Dict[str, Dict[str, Any]]:
        return dict(self.load()["entries"])

    def __len__(self) -> int:
        return len(self.load()["entries"])

    # -------------------------------------------- residual-log compaction
    def fold_residuals(self, rows: List[Dict[str, Any]]) -> int:
        """Fold rotated-out residual rows into per-(hw, backend,
        interpret) running summaries, so the combined bias statistics
        survive log rotation.  Returns the number of rows folded."""
        import math

        if not rows:
            return 0
        agg: Dict[str, Dict[str, Any]] = {}
        for r in rows:
            if not isinstance(r, dict):
                continue
            hw_fp = str(r.get("hw_fingerprint", ""))
            backend = str(r.get("backend", ""))
            interp = bool(r.get("interpret", False))
            skey = content_key("residual-summary", hw_fp, backend, interp)
            s = agg.setdefault(skey, {
                "hw_fingerprint": hw_fp, "backend": backend,
                "interpret": interp, "rows": 0, "pairs": 0,
                "sum_log_ratio": 0.0,
            })
            s["rows"] += 1
            p, m = r.get("predicted_s"), r.get("measured_s")
            if p and m and p > 0 and m > 0:
                s["pairs"] += 1
                s["sum_log_ratio"] += math.log(m / p)
        folded = sum(s["rows"] for s in agg.values())
        with self._lock, self._file_lock():
            doc = self.load()
            sums = doc["residual_summaries"]
            for skey, s in agg.items():
                prev = sums.get(skey)
                if prev is not None:
                    s["rows"] += int(prev.get("rows", 0))
                    s["pairs"] += int(prev.get("pairs", 0))
                    s["sum_log_ratio"] += float(prev.get("sum_log_ratio", 0.0))
                sums[skey] = s
            self._store(doc)
        return folded

    def residual_summaries(self) -> List[Dict[str, Any]]:
        return list(self.load()["residual_summaries"].values())
