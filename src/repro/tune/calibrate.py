"""Online cost-model calibration from measured residuals.

The roofline/pipeline model predicts per-block latency from two terms —
HBM traffic time ``t_mem`` and compute time ``t_compute`` — priced at
datasheet peaks.  Real dispatch never hits datasheet peaks, so the
profiling residual log accumulates (predicted, measured) pairs with a
large systematic bias.  This module fits per-term scale coefficients

    measured  ~=  a * t_mem_raw  +  b * t_compute_raw  +  c

by iteratively-reweighted least squares with Huber weights (pure numpy,
robust to outlier dispatches), clamped non-negative.  With too few pairs
or a degenerate design matrix it degrades to a single geometric-mean
scale on both terms — the gmean bias correction.

A fitted :class:`Calibration` is **hardware-fingerprint scoped**:
``set_calibration`` activates it in a process-wide registry keyed by
``HardwareConfig.fingerprint()``, and ``cost.evaluate_tiling`` applies
the active calibration's scales to its roofline terms — so the autotile
search, ``score_pass_trace``, and the explore sweeps all rank candidates
on *calibrated* predictions.  The calibration fingerprint enters the
compilation-cache key (calibrated and uncalibrated artifacts never
collide), and calibrations persist as ``calibration.json`` next to the
tuning DB.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional

from ..core.cache import stable_hash

CALIBRATION_NAME = "calibration.json"

# fewer (term-bearing) pairs than this and the per-term fit is
# under-determined — fall back to the single gmean scale
MIN_PAIRS_FOR_FIT = 4


@dataclasses.dataclass
class Calibration:
    """Per-term scale coefficients for one hardware fingerprint."""

    hw_fingerprint: str = ""
    scale_mem: float = 1.0
    scale_compute: float = 1.0
    overhead_s: float = 0.0
    n_pairs: int = 0
    method: str = ""      # "irls" | "gmean"
    backend: str = ""     # measurement backend the pairs came from
    ts: float = 0.0

    def fingerprint(self) -> str:
        """Cache-key component: any coefficient change re-keys every
        artifact compiled under this calibration."""
        return stable_hash([
            "calibration", self.hw_fingerprint,
            round(self.scale_mem, 9), round(self.scale_compute, 9),
            round(self.overhead_s, 12),
        ])[:16]

    def apply(self, t_mem: float, t_compute: float) -> tuple:
        return t_mem * self.scale_mem, t_compute * self.scale_compute

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "Calibration":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


# --------------------------------------------------------------------------
# Fitting
# --------------------------------------------------------------------------
def _gmean_scale(pairs: List[tuple]) -> Optional[float]:
    logs = [math.log(m / p) for p, m in pairs if p > 0 and m > 0]
    if not logs:
        return None
    return math.exp(sum(logs) / len(logs))


def fit_calibration(rows: List[Mapping[str, Any]], hw_fingerprint: str = "",
                    backend: str = "", iters: int = 10) -> Optional[Calibration]:
    """Fit a :class:`Calibration` from residual-log rows.

    Rows carrying raw roofline terms (``t_mem_raw``/``t_compute_raw``,
    written by profiled compiles) feed the per-term IRLS fit; rows with
    only ``predicted_s`` still contribute to the gmean fallback.  Returns
    None when no usable pair exists.
    """
    import time

    import numpy as np

    term_rows = []
    pred_pairs = []
    for r in rows:
        m = r.get("measured_s")
        if not m or m <= 0:
            continue
        tm, tc = r.get("t_mem_raw"), r.get("t_compute_raw")
        if tm is not None and tc is not None and (tm > 0 or tc > 0):
            term_rows.append((float(tm), float(tc), float(m)))
        p = r.get("predicted_s")
        if p and p > 0:
            pred_pairs.append((float(p), float(m)))

    cal = Calibration(hw_fingerprint=hw_fingerprint, backend=backend,
                      ts=time.time())

    if len(term_rows) >= MIN_PAIRS_FOR_FIT:
        X = np.array([[tm, tc, 1.0] for tm, tc, _ in term_rows])
        y = np.array([m for _, _, m in term_rows])
        # columns with no variation (e.g. every block pure-compute) make
        # the normal equations singular; lstsq handles rank deficiency.
        # Robustness is two-stage per iteration: hard-reject gross
        # outliers (> 3.5 sigma by MAD — Huber alone barely discounts a
        # dispatch 1000x off, e.g. a GC pause) and Huber-weight the rest.
        w = np.ones(len(y))
        beta = np.zeros(3)
        for _ in range(max(int(iters), 1)):
            Xw = X * w[:, None]
            beta, *_ = np.linalg.lstsq(Xw, y * w, rcond=None)
            resid = y - X @ beta
            a = np.abs(resid)
            # MAD sigma, floored so near-exact fits don't reject everything
            scale = max(np.median(a) * 1.4826,
                        1e-6 * float(np.median(np.abs(y))), 1e-30)
            k = 1.345 * scale
            w = np.sqrt(np.where(a <= k, 1.0, k / a))
            keep = a <= 3.5 * scale
            if keep.sum() >= MIN_PAIRS_FOR_FIT:
                w = np.where(keep, w, 0.0)
        a_mem, b_comp, c = (max(float(beta[0]), 0.0),
                            max(float(beta[1]), 0.0),
                            max(float(beta[2]), 0.0))
        if a_mem > 0 or b_comp > 0:
            cal.scale_mem, cal.scale_compute = a_mem, b_comp
            cal.overhead_s = c
            cal.n_pairs = len(term_rows)
            cal.method = "irls"
            # a term the fit zeroed out (column had no signal) keeps the
            # other term's scale so its predictions move the same way
            if cal.scale_mem == 0.0:
                cal.scale_mem = cal.scale_compute
            if cal.scale_compute == 0.0:
                cal.scale_compute = cal.scale_mem
            return cal

    s = _gmean_scale(pred_pairs)
    if s is None:
        return None
    cal.scale_mem = cal.scale_compute = s
    cal.n_pairs = len(pred_pairs)
    cal.method = "gmean"
    return cal


# --------------------------------------------------------------------------
# The process-wide active registry (what evaluate_tiling consults)
# --------------------------------------------------------------------------
_ACTIVE: Dict[str, Calibration] = {}


def any_active() -> bool:
    """Fast-path guard for the per-candidate cost-model hook."""
    return bool(_ACTIVE)


def set_calibration(cal: Calibration) -> None:
    if not cal.hw_fingerprint:
        raise ValueError("calibration needs a hw_fingerprint to scope to")
    _ACTIVE[cal.hw_fingerprint] = cal


def get_calibration(hw_fingerprint: str) -> Optional[Calibration]:
    return _ACTIVE.get(hw_fingerprint)


def clear_calibrations() -> None:
    _ACTIVE.clear()


def active_fingerprint(hw_fingerprint: str) -> str:
    """The cache-key component for one hardware config: the active
    calibration's fingerprint, or "" when predictions are raw."""
    cal = _ACTIVE.get(hw_fingerprint)
    return cal.fingerprint() if cal is not None else ""


# --------------------------------------------------------------------------
# Persistence (next to the tuning DB)
# --------------------------------------------------------------------------
def save_calibrations(dir: os.PathLike, name: str = CALIBRATION_NAME,
                      cals: Optional[List[Calibration]] = None) -> Optional[Path]:
    """Persist calibrations (default: every active one) as JSON under
    ``dir``; atomic publish, I/O failures swallowed (returns None)."""
    path = Path(dir) / name
    doc = {"version": 1,
           "calibrations": [c.to_json() for c in
                            (cals if cals is not None else _ACTIVE.values())]}
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, sort_keys=True, indent=1)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
    except OSError:
        return None
    return path


def load_calibrations(dir: os.PathLike, name: str = CALIBRATION_NAME,
                      activate: bool = True) -> List[Calibration]:
    """Load persisted calibrations; ``activate`` installs them in the
    process registry.  A missing or corrupt file is an empty list."""
    path = Path(dir) / name
    try:
        doc = json.loads(path.read_text())
        cals = [Calibration.from_json(d) for d in doc.get("calibrations", [])
                if isinstance(d, dict)]
    except (OSError, ValueError, TypeError):
        return []
    if activate:
        for c in cals:
            if c.hw_fingerprint:
                _ACTIVE[c.hw_fingerprint] = c
    return cals
