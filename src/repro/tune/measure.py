"""Noise-robust measurement harness: min of interleaved rounds.

Single-shot wall times on a shared CPU host are dominated by scheduling
noise.  The estimator used throughout the benches (and now everywhere a
measurement feeds the tuning DB) is:

* run several **rounds**; each round times every candidate once (a short
  burst of ``calls`` dispatches, averaged);
* **interleave**: alternate the candidate order per round, so a
  contention burst lands on different candidates in different rounds
  instead of biasing whoever runs last;
* take the per-candidate **minimum** across rounds — contention only
  ever *adds* time (timeit's rationale), so the minimum is the
  noise-robust location estimate.

The timer is injectable: the default wall clock is right for CPU /
pallas-interpret measurement today; a real-TPU device-event timer slots
into ``timer=`` without touching the harness structure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Hashable, Mapping, Optional

DEFAULT_ROUNDS = 4
DEFAULT_CALLS = 2


@dataclasses.dataclass
class Measurement:
    """One candidate's estimate: best per-call seconds and how it was
    taken (recorded into the tuning DB next to the value)."""

    min_s: float
    rounds: int
    calls: int
    all_rounds_s: tuple = ()

    def to_json(self) -> Dict[str, Any]:
        return {"min_s": self.min_s, "rounds": self.rounds,
                "calls": self.calls,
                "all_rounds_s": list(self.all_rounds_s)}


def measure_interleaved(thunks: Mapping[Hashable, Callable[[], Any]], *,
                        rounds: int = DEFAULT_ROUNDS,
                        calls: int = DEFAULT_CALLS, warmup: int = 1,
                        timer: Optional[Callable[[], float]] = None,
                        ) -> Dict[Hashable, Measurement]:
    """Measure every zero-arg thunk (one dispatch per call, including any
    device sync — the caller bakes in ``block_until_ready``) and return
    per-key :class:`Measurement`.  A thunk that raises is simply absent
    from the result (one broken candidate must not sink the batch)."""
    clock = timer if timer is not None else time.perf_counter
    keys = [k for k in thunks]
    alive: Dict[Hashable, list] = {}
    for k in keys:
        try:
            for _ in range(max(int(warmup), 0)):
                thunks[k]()
            alive[k] = []
        except Exception:
            continue
    n_calls = max(int(calls), 1)
    for r in range(max(int(rounds), 1)):
        order = [k for k in keys if k in alive]
        if r % 2:
            order.reverse()
        for k in order:
            fn = thunks[k]
            try:
                t0 = clock()
                for _ in range(n_calls):
                    fn()
                alive[k].append((clock() - t0) / n_calls)
            except Exception:
                del alive[k]
    return {k: Measurement(min_s=min(ts), rounds=len(ts), calls=n_calls,
                           all_rounds_s=tuple(ts))
            for k, ts in alive.items() if ts}
