"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — RoPE 2d (rotary on half the head dims), GQA
[arXiv:2406.12793; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    act="silu_glu",
    rope="half",
    source="[arXiv:2406.12793; hf]",
)
