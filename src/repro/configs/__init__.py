"""Architecture registry: ``get(name)`` / ``names()``."""
from __future__ import annotations

from typing import Dict, List

from .base import SHAPES, ArchConfig, ShapeSpec, applicable_shapes

_MODULES = [
    "xlstm_125m",
    "nemotron_4_15b",
    "chatglm3_6b",
    "llama3_8b",
    "qwen3_4b",
    "qwen3_moe_30b_a3b",
    "dbrx_132b",
    "internvl2_26b",
    "seamless_m4t_large_v2",
    "zamba2_2_7b",
]

_REGISTRY: Dict[str, ArchConfig] = {}


def _load() -> None:
    if _REGISTRY:
        return
    import importlib

    for m in _MODULES:
        mod = importlib.import_module(f".{m}", __package__)
        cfg: ArchConfig = mod.CONFIG
        _REGISTRY[cfg.name] = cfg


def get(name: str) -> ArchConfig:
    _load()
    return _REGISTRY[name]


def names() -> List[str]:
    _load()
    return list(_REGISTRY)


__all__ = ["get", "names", "ArchConfig", "ShapeSpec", "SHAPES", "applicable_shapes"]
