"""Architecture configuration schema + input-shape specs.

Every assigned architecture is a frozen ``ArchConfig`` in its own module;
``registry.get(name)`` returns it and ``ArchConfig.scaled()`` produces the
reduced smoke-test variant.  Input shapes (train_4k / prefill_32k /
decode_32k / long_500k) are ``ShapeSpec``\\ s shared across archs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    n_ssm_heads: int = 0      # 0 => d_model // head_dim-like default
    head_dim: int = 64        # channels per SSD head
    expand: int = 2
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    slstm_at: Tuple[int, ...] = ()   # layer indices using sLSTM blocks
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    conv_width: int = 4
    n_heads: int = 4


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    shared_attn_every: int = 6  # a shared transformer block every k layers


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    act: str = "silu_glu"        # silu_glu | gelu_glu | relu2 | gelu
    norm: str = "rmsnorm"
    qk_norm: bool = False
    rope: str = "full"           # full | half | none
    rope_theta: float = 10_000.0
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    hybrid: Optional[HybridCfg] = None
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str = "none"       # none | patches | frames (stub embeddings)
    frontend_len: int = 0        # patches/frames prepended / encoded
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 256
    dtype: str = "bfloat16"
    source: str = ""             # provenance note "[arXiv:...; tier]"

    # ------------------------------------------------------------------ api
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab + m - 1) // m * m

    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM/hybrid/linear-attn)"""
        return self.family in ("ssm", "hybrid")

    def has_decoder(self) -> bool:
        return True  # all assigned archs have decoders (seamless is enc-dec)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for roofline
        MODEL_FLOPS = 6 N D."""
        d = self.d_model
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.hd
        # attention
        per_layer += d * (self.n_heads * hd) + d * (2 * self.n_kv_heads * hd) + (self.n_heads * hd) * d
        if self.moe:
            per_layer += d * self.moe.n_experts * self.moe.d_ff_expert * 3 + d * self.moe.n_experts
        elif self.d_ff:
            mult = 3 if self.act.endswith("_glu") else 2
            per_layer += mult * d * self.d_ff
        if self.family == "ssm" and self.xlstm:
            per_layer = int(2 * d * d * self.xlstm.proj_factor_mlstm * 2.2)
        if self.family == "hybrid" and self.ssm:
            inner = self.ssm.expand * d
            per_layer = 2 * d * inner + inner * d + 2 * inner * self.ssm.d_state
        n_l = self.n_layers + self.n_enc_layers
        return emb + n_l * per_layer

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        d = self.d_model
        per_layer_moe_all = d * self.moe.n_experts * self.moe.d_ff_expert * 3
        per_layer_moe_act = d * self.moe.top_k * self.moe.d_ff_expert * 3
        return self.param_count() - self.n_layers * (per_layer_moe_all - per_layer_moe_act)

    def scaled(self, **kw) -> "ArchConfig":
        """Reduced config of the same family for CPU smoke tests."""
        base = dict(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128 if self.d_ff else 0, vocab=128, head_dim=16,
            vocab_pad_multiple=32, dtype="float32",
        )
        if self.moe:
            base["moe"] = MoECfg(n_experts=4, top_k=2, d_ff_expert=32)
        if self.ssm:
            base["ssm"] = SSMCfg(d_state=8, head_dim=16, expand=2, conv_width=4)
        if self.xlstm:
            base["xlstm"] = XLSTMCfg(slstm_at=(1,), n_heads=2)
        if self.hybrid:
            base["hybrid"] = HybridCfg(shared_attn_every=2)
        if self.enc_dec:
            base["n_enc_layers"] = 2
        if self.frontend != "none":
            base["frontend_len"] = 8
        base.update(kw)
        return dataclasses.replace(self, **base)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig):
    """The assignment's skip rules: long_500k only for sub-quadratic archs."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic():
            continue
        out.append(s)
    return out
