"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks [arXiv:2405.04517; unverified].  xLSTM[7:1]-style: sLSTM at layers
5 and 11, mLSTM elsewhere; no separate FFN (d_ff=0) — the blocks carry
their own up/down projections."""
from .base import ArchConfig, XLSTMCfg

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=192,
    rope="none",
    xlstm=XLSTMCfg(slstm_at=(5, 11), n_heads=4),
    tie_embeddings=True,
    source="[arXiv:2405.04517; unverified]",
)
