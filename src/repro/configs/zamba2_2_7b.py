"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240
ssm_state=64 — Mamba2 backbone + shared attention block applied every 6
layers (weights shared, distinct KV caches) [arXiv:2411.15242; hf]."""
from .base import ArchConfig, HybridCfg, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    act="gelu_glu",
    rope="full",   # zamba2's shared attention block uses rotary embeddings
    ssm=SSMCfg(d_state=64, head_dim=64, expand=2, conv_width=4),
    hybrid=HybridCfg(shared_attn_every=6),
    source="[arXiv:2411.15242; hf]",
)
