"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

Backbone only: the speech frontend is a stub — ``input_specs()`` provides
precomputed frame embeddings fed to the encoder; the decoder decodes text
with cross-attention.  vocab 256206 padded to 256256."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,          # decoder layers
    n_enc_layers=24,      # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    act="relu2",          # conformer-ish FFN; squared relu stand-in for swish-glu-free
    norm="layernorm",
    # seamless uses learned/relative positions; RoPE is the length-safe
    # TPU-framework stand-in (documented adaptation in DESIGN.md)
    rope="full",
    enc_dec=True,
    frontend="frames",
    frontend_len=0,       # frames take the full encoder length
    source="[arXiv:2308.11596; hf]",
)
