"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 [arXiv:2404.16821; hf].

The assignment specifies the transformer BACKBONE only: the InternViT
frontend is a stub — ``input_specs()`` provides precomputed patch
embeddings (frontend_len tokens of d_model) prepended to the text
sequence.  vocab 92553 is padded to 92672 (multiple of 256) for clean TP
sharding."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    act="silu_glu",
    rope="full",
    frontend="patches",
    frontend_len=256,
    source="[arXiv:2404.16821; hf]",
)
