"""Op library: the bridge between the NN layers and the Stripe compiler.

Every dense contraction in the framework's models routes through here:
the op is expressed in the Tile frontend, compiled through the
hardware-config pass pipeline (fuse -> autotile -> stencil -> boundary ->
localize -> schedule), and lowered with the selected backend:

* ``jnp``     — reference backend (runs everywhere; what XLA sees on CPU
                and in the distributed dry-run, where GSPMD handles layout)
* ``pallas``  — TPU kernels emitted from the optimized IR
* ``pallas_interpret`` — the same kernels executed with ``interpret=True``
                (CPU validation of the TPU path)

Backend selection: ``set_backend()`` or the ``REPRO_BACKEND`` env var.
Compilation results are cached per (op text, shapes, dtypes, hw, backend).
"""
from __future__ import annotations

import functools
import os
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax.numpy as jnp

from .frontend import TileProgram
from .hwconfig import HardwareConfig, get_config
from .ir import Block, Program
from .lower_jnp import lower_program_jnp
from .lower_pallas import UnsupportedPallas, lower_program_pallas
from .passes import compile_program

_BACKEND = os.environ.get("REPRO_BACKEND", "jnp")


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("jnp", "pallas", "pallas_interpret")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


class CompiledOp:
    """A Stripe-compiled tensor program with jnp + pallas lowerings."""

    def __init__(self, prog: Program, hw: HardwareConfig, backend: str):
        self.optimized = compile_program(prog, hw)
        self.backend = backend
        self.jnp_fn = lower_program_jnp(self.optimized.source)
        self.pallas_fn: Optional[Callable] = None
        self.pallas_ok = False
        if backend.startswith("pallas"):
            interpret = backend == "pallas_interpret"
            try:
                # one pallas_call per fusion group, composed in program order
                self.pallas_fn = lower_program_pallas(
                    self.optimized, interpret=interpret,
                    pipeline_depth=hw.pipeline_depth)
                self.pallas_ok = True
            except UnsupportedPallas:
                self.pallas_ok = False

    def __call__(self, arrays: Mapping[str, jnp.ndarray]):
        if self.pallas_ok:
            return self.pallas_fn(arrays)
        return self.jnp_fn(arrays)


@functools.lru_cache(maxsize=512)
def _compiled_linear(m: int, k: int, n: int, dtype: str, acc_dtype: str,
                     act: Optional[str], has_bias: bool, backend: str) -> CompiledOp:
    tp = TileProgram("linear")
    tp.input("X", (m, k), dtype)
    tp.input("W", (k, n), dtype)
    if has_bias:
        tp.input("B", (n,), acc_dtype)
    needs_epilogue = has_bias or act
    if needs_epilogue:
        tp.temp("T", (m, n))
        tp.output("O", (m, n), dtype)
        tp.op("T[i, j] += X[i, c] * W[c, j]")
        expr = "T[i, j]"
        if has_bias:
            expr = f"({expr} + B[j])"
        if act:
            expr = f"{act}({expr})"
        tp.op(f"O[i, j] = {expr}")
    else:
        tp.output("O", (m, n), dtype)
        tp.op("O[i, j] += X[i, c] * W[c, j]")
    return CompiledOp(tp.build(), get_config("tpu_v5e"), backend)


def linear(x: jnp.ndarray, w: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
           act: Optional[str] = None) -> jnp.ndarray:
    """Stripe-compiled linear layer: ``act(x @ w + bias)``.

    On the jnp backend this lowers to a plain einsum (so XLA/GSPMD handle
    sharding in the distributed setting); on the pallas backends it runs
    the Stripe-generated fused kernel.
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    m = 1
    for s in lead:
        m *= s
    backend = _BACKEND
    if backend == "jnp":
        # fast path: identical semantics, no per-shape Program build
        out = jnp.einsum("mk,kn->mn", x.reshape(m, k), w)
        if bias is not None:
            out = out + bias
        if act is not None:
            from .lower_jnp import _J_UNARY

            out = _J_UNARY[act](out)
        return out.reshape(*lead, n)
    op = _compiled_linear(m, k, n, str(x.dtype), str(bias.dtype) if bias is not None else "float32",
                          act, bias is not None, backend)
    arrays = {"X": x.reshape(m, k), "W": w}
    if bias is not None:
        arrays["B"] = bias
    out = op(arrays)["O"]
    return out.reshape(*lead, n)
