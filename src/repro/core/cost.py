"""Cost models for autotiling (paper §3.3, Fig. 4).

Two models, selected by the hardware config:

* ``cache_lines`` — the paper's model, verbatim: *number of cache lines
  accessed divided by the number of multiply-accumulate operations
  performed*.  Overflow elements still cost lines; constrained-out points
  do not count as MACs.
* ``roofline`` — the TPU generalization: per-tile HBM traffic and MXU
  compute are converted to seconds and the dominant term is minimized
  (Williams et al. roofline, which §3.3 cites as the autotiler's target).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

from .affine import Affine
from .hwconfig import HardwareConfig
from .ir import Block, RefDir, Refinement, dtype_bytes
from .poly import Polyhedron, ceil_div


@dataclasses.dataclass
class TileCost:
    cost: float
    lines: float = 0.0
    macs: float = 0.0
    bytes_hbm: float = 0.0
    t_mem: float = 0.0
    t_compute: float = 0.0
    mem_elems: int = 0
    mem_bytes: int = 0
    n_tiles: int = 1
    feasible: bool = True
    why: str = ""
    latency_s: float = 0.0  # pipelined per-block latency (pipelined_latency)
    plan_bytes: int = 0     # planner-exact VMEM footprint of one tile
    halo_bytes: float = 0.0  # HBM traffic added by halo windows (overlap
    #                          re-fetch + one-time materialization of the
    #                          gathered operand the Pallas lowerer builds)
    # raw (datasheet-peak) roofline terms, kept next to the possibly
    # calibrated t_mem/t_compute — the calibration fit always regresses
    # on raw terms, never on its own previous output
    t_mem_raw: float = 0.0
    t_compute_raw: float = 0.0
    calibrated: bool = False


def _active_calibration(hw: HardwareConfig):
    """The measured-feedback calibration active for this config, or None.
    The no-calibration fast path never hashes the config (this runs once
    per candidate tiling inside the autotile search)."""
    from ..tune import calibrate

    if not calibrate.any_active():
        return None
    return calibrate.get_calibration(hw.fingerprint())


def pipelined_latency(t_mem: float, t_compute: float, n_tiles: int,
                      depth: int) -> float:
    """Predicted block latency under a depth-``depth`` double-buffered
    grid pipeline: prologue (first tile's fetch) + steady state (memory
    and compute overlap, the dominant per-step term repeats) + drain
    (last tile's compute).  With ``depth < 2`` (no double buffering) or
    a single tile there is nothing to overlap and the terms serialize.
    Depths beyond 2 change the memory *footprint* (more slots), not the
    steady state — one buffer ahead already hides the smaller term."""
    n = max(int(n_tiles), 1)
    if depth < 2 or n <= 1:
        return t_mem + t_compute
    step_mem = t_mem / n
    step_comp = t_compute / n
    return step_mem + (n - 1) * max(step_mem, step_comp) + step_comp


def _contig_dim(ref: Refinement) -> int:
    if not ref.strides:
        return ref.rank - 1
    best = min(range(ref.rank), key=lambda d: abs(ref.strides[d]) or 10**9)
    return best


def lines_for_view(shape: Tuple[int, ...], ref: Refinement, line: int, aligned: bool) -> int:
    """Cache lines touched by one tile-view of ``ref``."""
    cd = _contig_dim(ref)
    n = 1
    for d, ext in enumerate(shape):
        if d != cd:
            n *= ext
    ext = shape[cd]
    if aligned:
        per_row = ceil_div(ext, line)
    else:
        # worst-case unaligned: a run of ext elements can straddle one extra line
        per_row = ceil_div(ext + line - 1, line)
    return n * per_row


def _tile_view_shapes(block: Block, tiles: Mapping[str, int]) -> List[Tuple[Refinement, Tuple[int, ...], bool, bool]]:
    """For each refinement of a flat block: (ref, tile view shape, is_tiled,
    aligned_in_contig_dim)."""
    free = {i.name: i.range for i in block.idxs if not i.is_passthrough()}
    eff = {v: min(tiles.get(v, free[v]), free[v]) for v in free}
    out = []
    for r in block.refs:
        shape = []
        uses_tiled_var = False
        for e, orig in zip(r.offsets, r.shape):
            span = 0
            for n, c in e.terms:
                if n in eff:
                    span += abs(c) * (eff[n] - 1)
                    if eff[n] < free[n]:
                        uses_tiled_var = True
            shape.append(span + orig)
        # alignment of the contiguous dim: the outer-step in that dim must be
        # a multiple of the line; conservatively aligned iff the tile covers
        # the full contiguous dim or starts at offsets that are multiples.
        cd = _contig_dim(r)
        e = r.offsets[cd]
        full = all(eff.get(n, 1) >= free.get(n, 1) for n in e.names())
        out.append((r, tuple(shape), uses_tiled_var, full))
    return out


# Exact-MAC memo: keyed by IR content fingerprint (never object identity
# — ``id()`` can be reused after GC, silently returning another block's
# count) and bounded by a small LRU so long sweep processes never grow it
# without bound.
_MACS_CACHE: "collections.OrderedDict[str, Optional[int]]" = collections.OrderedDict()
_MACS_CACHE_MAX = 128


def macs_cache_key(block: Block) -> str:
    from .ir import ir_fingerprint

    return ir_fingerprint(block)


def seed_macs_cache(key: str, value: Optional[int]) -> None:
    """Pre-populate the exact-MAC memo (parallel autotune workers seed it
    with the parent process's precomputed count)."""
    _MACS_CACHE[key] = value
    _MACS_CACHE.move_to_end(key)
    while len(_MACS_CACHE) > _MACS_CACHE_MAX:
        _MACS_CACHE.popitem(last=False)


def count_macs_exact(block: Block, limit: int = 2_000_000,
                     key: Optional[str] = None) -> Optional[int]:
    key = key or macs_cache_key(block)
    if key in _MACS_CACHE:
        _MACS_CACHE.move_to_end(key)
        return _MACS_CACHE[key]
    poly = block.poly
    if poly.rect_size() > limit:
        out = None
    else:
        out = poly.count()
    seed_macs_cache(key, out)
    return out


def block_points(block: Block) -> int:
    """Total leaf iteration points (rect) including nested sub-blocks —
    the MAC count proxy for fused/nested structures."""
    rect = 1
    for i in block.idxs:
        if not i.is_passthrough():
            rect *= i.range
    subs = [s for s in block.stmts if isinstance(s, Block)]
    if not subs:
        return rect
    return rect * sum(block_points(s) for s in subs)


def evaluate_tiling(block: Block, tiles: Mapping[str, int], hw: HardwareConfig, params: Mapping) -> TileCost:
    """Cost of tiling a flat contraction/elementwise block by ``tiles``."""
    free = {i.name: i.range for i in block.idxs if not i.is_passthrough()}
    eff = {v: min(tiles.get(v, free[v]), free[v]) for v in free}
    n_tiles = 1
    for v, r in free.items():
        n_tiles *= ceil_div(r, eff[v])

    views = _tile_view_shapes(block, eff)
    inner_mem = hw.inner_mem()
    line = hw.mem_units[0].cache_line_elems
    count_untiled = params.get("count_untiled", True)

    # ---- memory footprint of one tile -------------------------------------
    mem_elems = 0
    mem_bytes = 0
    any_tiled = any(uses for _, _, uses, _ in views)
    for r, shape, uses_tiled, _ in views:
        elems = 1
        for s in shape:
            elems *= s
        # when nothing is tiled (flat candidate) every view IS the tile
        if count_untiled or uses_tiled or not any_tiled:
            mem_elems += elems
            mem_bytes += elems * dtype_bytes(r.dtype)

    # ---- planner-exact footprint of one tile -------------------------------
    # (memplan's slot model: streamed views get pipeline_depth slots, grid-
    # invariant views one, a revisited output one slot + f32 scratch)
    from . import memplan

    depth = hw.pipeline_depth
    tiled_vars = {v for v in free if eff[v] < free[v]}
    entries: List[Tuple[int, str, int]] = []
    for r, shape, _uses, _al in views:
        elems = 1
        for s in shape:
            elems *= s
        ref_grid = {n for e in r.offsets for n in e.names()} & tiled_vars
        is_out = r.dir in (RefDir.OUT, RefDir.INOUT)
        revisited = is_out and bool(tiled_vars - ref_grid)
        kind, slots = memplan.slots_for(is_out, bool(ref_grid), revisited, depth)
        entries.append((elems * dtype_bytes(r.dtype), kind, slots))
        if revisited:
            entries.append((elems * 4, "scratch", 1))  # f32 partial sums
    plan_bytes = memplan.tile_footprint_bytes(entries)

    cap_e = params.get("mem_cap_elems")
    cap_frac = params.get("mem_cap_frac")
    feasible = True
    why = ""
    if cap_e is not None and mem_elems > cap_e:
        feasible, why = False, f"tile footprint {mem_elems}e > cap {cap_e}e"
    if cap_frac is not None:
        cap = inner_mem.size_bytes * cap_frac
        if params.get("memplan", True):
            if plan_bytes > cap:
                feasible, why = False, (
                    f"planned tile {plan_bytes}B > {cap_frac} of {inner_mem.name}")
        elif mem_bytes * 2 > cap:
            feasible, why = False, f"2x tile bytes {2*mem_bytes} > {cap_frac} of {inner_mem.name}"

    # ---- MACs --------------------------------------------------------------
    macs = block_points(block)
    if params.get("exact_macs"):
        # the tile search injects the block's precomputed fingerprint so a
        # thousand-candidate sweep hashes the IR once, not per candidate
        exact = count_macs_exact(block, key=params.get("_macs_key"))
        if exact is not None and not any(isinstance(s, Block) for s in block.stmts):
            macs = exact

    model = params.get("cost", "cache_lines")
    if model == "cache_lines":
        lines = 0
        bytes_hbm = 0.0
        for r, shape, uses_tiled, aligned in views:
            if not count_untiled and not uses_tiled:
                continue
            n = lines_for_view(shape, r, line, aligned)
            lines += n
            bytes_hbm += n * line * dtype_bytes(r.dtype)
        total_lines = n_tiles * lines
        cost = total_lines / max(macs, 1)
        # seconds-uniform terms so every TileCost converts to a predicted
        # latency (the explore sweeps score cache-line configs too): line
        # transactions priced at outer-memory bandwidth, MACs at peak.
        total_bytes = n_tiles * bytes_hbm
        t_mem = total_bytes / hw.mem_units[0].bandwidth
        t_compute = 2.0 * macs / hw.peak_flops if hw.peak_flops > 0 else 0.0
        t_mem_raw, t_compute_raw = t_mem, t_compute
        cal = _active_calibration(hw)
        overhead = 0.0
        if cal is not None:
            # the paper-exact lines/MAC ranking is left untouched; only
            # the seconds-uniform terms (what the sweeps score) calibrate
            t_mem, t_compute = cal.apply(t_mem, t_compute)
            overhead = cal.overhead_s
        return TileCost(cost=cost, lines=total_lines, macs=macs,
                        bytes_hbm=total_bytes, t_mem=t_mem, t_compute=t_compute,
                        mem_elems=mem_elems, mem_bytes=mem_bytes, n_tiles=n_tiles,
                        feasible=feasible, why=why, plan_bytes=plan_bytes,
                        t_mem_raw=t_mem_raw, t_compute_raw=t_compute_raw,
                        calibrated=cal is not None,
                        latency_s=pipelined_latency(t_mem, t_compute, n_tiles,
                                                    depth) + overhead)

    # ---- roofline model ----------------------------------------------------
    # HBM traffic with *consecutive* reuse, matching the Pallas emission:
    # the grid iterates parallel (output) dims outer, reduction dims inner;
    # a ref's block stays resident only while the innermost grid dims that
    # vary do not address it (BlockSpec revisiting).  The output block is
    # revisited across the whole reduction (scratch accumulation).
    out_vars: List[str] = []
    for r, *_ in views:
        if r.dir in (RefDir.OUT, RefDir.INOUT):
            for e in r.offsets:
                for n in e.names():
                    if n not in out_vars:
                        out_vars.append(n)
    grid_dims = [v for v in free if eff[v] < free[v]]
    # order: parallel first, reduction innermost (lower_pallas.grid_order)
    grid_order = [v for v in grid_dims if v in out_vars] + [v for v in grid_dims if v not in out_vars]
    steps = {v: ceil_div(free[v], eff[v]) for v in grid_dims}
    total_steps = 1
    for v in grid_dims:
        total_steps *= steps[v]

    bytes_hbm = 0.0
    halo_bytes = 0.0
    for r, shape, _uses, _al in views:
        elems = 1
        for s in shape:
            elems *= s
        ref_vars = set()
        for e in r.offsets:
            ref_vars.update(n for n in e.names() if n in steps)
        reuse = 1
        for v in reversed(grid_order):
            if v in ref_vars:
                break
            reuse *= steps[v]
        fetches = max(total_steps // max(reuse, 1), 1)
        factor = 2 if r.dir == RefDir.INOUT else 1
        bytes_hbm += fetches * elems * dtype_bytes(r.dtype) * factor
        # Halo windows (tile view extent > the grid step along a tiled
        # dim — the conv overlap): the Pallas lowerer materializes the
        # overlapping tiles once per input (write the gathered array,
        # read the source), so charge that one-time traffic on top of the
        # per-step fetches, which already include the margin.  Larger
        # tiles along the halo dims shrink both terms — exactly the
        # amortization the autotiler should buy.
        core = 1
        for e, ext in zip(r.offsets, shape):
            step = sum(abs(c) * eff[n] for n, c in e.terms if n in steps)
            core *= step if 0 < step < ext else ext
        if elems > core:
            unique = 1
            for v in grid_dims:
                if v in ref_vars:
                    unique *= steps[v]
            halo_bytes += 2.0 * unique * elems * dtype_bytes(r.dtype)
    bytes_hbm += halo_bytes
    t_mem = bytes_hbm / hw.mem_units[0].bandwidth

    # compute term with stencil-padding utilization
    flops = 2.0 * macs
    util = 1.0
    stencil = None
    for s in hw.stencils:
        if s.name == params.get("stencil", "mxu"):
            stencil = s
            break
    if stencil is not None and "contraction" in block.tags:
        dims = _classify_mnk(block, eff)
        for extent, mult in zip(dims, stencil.dims):
            if extent is None:
                continue
            padded = ceil_div(extent, mult) * mult
            util *= extent / padded
    t_compute = flops / (hw.peak_flops * max(util, 1e-6))
    t_mem_raw, t_compute_raw = t_mem, t_compute
    cal = _active_calibration(hw)
    overhead = 0.0
    if cal is not None:
        # calibrated terms drive the ranking too: measured feedback can
        # flip which term dominates and therefore which tiling wins
        t_mem, t_compute = cal.apply(t_mem, t_compute)
        overhead = cal.overhead_s
    cost = max(t_mem, t_compute) + 1e-12 * n_tiles
    return TileCost(cost=cost, macs=macs, bytes_hbm=bytes_hbm, t_mem=t_mem,
                    t_compute=t_compute, mem_elems=mem_elems, mem_bytes=mem_bytes,
                    n_tiles=n_tiles, feasible=feasible, why=why,
                    plan_bytes=plan_bytes, halo_bytes=halo_bytes,
                    t_mem_raw=t_mem_raw, t_compute_raw=t_compute_raw,
                    calibrated=cal is not None,
                    latency_s=pipelined_latency(t_mem, t_compute, n_tiles,
                                                depth) + overhead)


# --------------------------------------------------------------------------
# Fusion profitability (fusion-group formation, fuse.py)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class FusionDecision:
    """One accepted/rejected merge during fusion-group formation.

    The model arbitrates HBM bytes saved (the eliminated intermediate's
    write + read) against HBM bytes added (inputs refetched once per grid
    tile that revisits them) and VMEM arena pressure (the canonical tile's
    footprint priced with schedule.py's address-assignment arithmetic)."""

    group: str
    member: str
    kind: str  # "prologue" | "epilogue"
    accepted: bool
    hbm_saved: int = 0
    hbm_added: int = 0
    vmem_bytes: int = 0
    vmem_cap: int = 0
    reason: str = ""

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def canonical_tile(ranges: Mapping[str, int], params: Mapping,
                   clamp_vars=None) -> Dict[str, int]:
    """The tile shape the profitability model prices a group at — fusion
    runs before autotiling, so merges are judged at a plausible tile (the
    stencil-ish default 128, clamped to each range), not the final one.
    Only ``clamp_vars`` (typically the anchor's output indices) are
    clamped: a fused group keeps its whole reduction extent resident in
    the inner memory, so reduction dims are priced at full range."""
    ct = int(params.get("canonical_tile", 128))
    if clamp_vars is None:
        clamp_vars = set(ranges)
    return {v: (min(r, ct) if v in clamp_vars else r) for v, r in ranges.items()}


def tile_view_bytes(ref: Refinement, ranges: Mapping[str, int], tile: Mapping[str, int]) -> int:
    """Bytes of one canonical-tile view of ``ref`` (span of the tiled
    index extents through the ref's affine offsets, times dtype).
    Variables absent from ``tile`` span their full range."""
    elems = 1
    for e, orig in zip(ref.offsets, ref.shape):
        span = 0
        for n, c in e.terms:
            extent = tile.get(n, ranges.get(n, 1))
            span += abs(c) * (extent - 1)
        elems *= span + orig
    return elems * dtype_bytes(ref.dtype)


def refetch_bytes(ref_vars, free: Mapping[str, int], out_vars, tile: Mapping[str, int],
                  full_bytes: int) -> int:
    """Extra HBM traffic a fused read of ``full_bytes`` incurs: the buffer
    is re-fetched once per grid tile along every *output* dimension that
    does not address it (reduction dims revisit for free — the block stays
    resident across the reduction, matching the Pallas emission)."""
    revisits = 1
    for v in out_vars:
        if v not in ref_vars:
            revisits *= ceil_div(free[v], tile.get(v, free[v]))
    return full_bytes * max(revisits - 1, 0)


def fusion_vmem_pressure(refs, ranges: Mapping[str, int], hw: HardwareConfig,
                         params: Mapping, clamp_vars=None) -> Tuple[int, int, bool]:
    """(arena bytes for one canonical tile of the candidate group, cap,
    fits).  Pressure is priced with memplan's slot model: views streamed
    by a clamped (grid) index get ``pipeline_depth`` slots, grid-
    invariant views (addressed only by the resident reduction) one slot,
    and the group's output one slot plus its f32 partial-sum scratch —
    the same arithmetic the autotiler's feasibility check and the
    schedule-time allocator use.  ``params["memplan"] = False`` restores
    the legacy blanket rule (everything double-buffered, no slot
    classes)."""
    from . import memplan
    from .passes.schedule import arena_bytes

    tile = canonical_tile(ranges, params, clamp_vars)
    cap = int(hw.inner_mem().size_bytes * params.get("mem_cap_frac", 0.45))
    if not params.get("memplan", True):
        sizes = [tile_view_bytes(r, ranges, tile) for r in refs]
        pressure = 2 * arena_bytes(sizes)
        return pressure, cap, pressure <= cap

    depth = hw.pipeline_depth
    streaming_vars = {v for v, t in tile.items() if t < ranges.get(v, 1)}
    entries: List[Tuple[int, str, int]] = []
    for r in refs:
        nbytes = tile_view_bytes(r, ranges, tile)
        ref_vars = {n for e in r.offsets for n in e.names()}
        streamed = bool(ref_vars & streaming_vars)
        is_out = r.dir in (RefDir.OUT, RefDir.INOUT)
        # at fusion time the whole reduction stays inside the tile, so an
        # output with any reduction extent is a revisited accumulator
        revisited = is_out and any(v not in ref_vars for v in ranges)
        kind, slots = memplan.slots_for(is_out, streamed, revisited, depth)
        entries.append((nbytes, kind, slots))
        if revisited:
            elems = nbytes // max(dtype_bytes(r.dtype), 1)
            entries.append((elems * 4, "scratch", 1))
    pressure = memplan.tile_footprint_bytes(entries)
    return pressure, cap, pressure <= cap


# --------------------------------------------------------------------------
# Interconnect model (multi-device lowering, core.shardplan / mesh_lower)
# --------------------------------------------------------------------------
# Fallback link bandwidth when a config models no interconnect
# (ici_link_bw == 0): a conservative PCIe-ish number so mesh plans on
# such configs still get finite, comparable communication costs instead
# of dividing by zero.
DEFAULT_LINK_BW = 16e9
# Fixed per-step cost of one ring-overlap stage (ppermute launch + loop
# bookkeeping).  A ring that cannot hide at least this much per step is
# not worth its n extra kernel launches and stays a plain psum.
RING_STEP_OVERHEAD_S = 5e-6


def link_bandwidth(hw: HardwareConfig, mesh_shape: Tuple[int, ...] = ()) -> float:
    """Effective per-device interconnect bandwidth for ring collectives
    on ``mesh_shape``.  Each mesh axis of a torus contributes an
    independent link pair, so a 2-D mesh moves ring traffic twice as
    fast as a flat ring over the same chips — this is how the mesh
    *shape* (not just its size) enters the cost model."""
    bw = hw.ici_link_bw or DEFAULT_LINK_BW
    axes = len([s for s in mesh_shape if int(s) > 1]) or 1
    return bw * axes


def collective_seconds(op: str, nbytes: float, n: int, bw: float) -> float:
    """Per-device time of one ring collective moving ``nbytes`` of
    *global* payload over ``n`` devices at link bandwidth ``bw``.

    Ring formulas (per device): all-gather and reduce-scatter each move
    ``(n-1)/n`` of the full payload; an all-reduce (psum) is
    reduce-scatter + all-gather, ``2(n-1)/n``; a halo exchange moves
    exactly its margin bytes (``nbytes`` is already the margin)."""
    n = max(int(n), 1)
    if n <= 1 or nbytes <= 0:
        return 0.0
    if op in ("all_gather", "reduce_scatter", "slice"):
        frac = (n - 1) / n
    elif op in ("psum", "ring_matmul"):
        frac = 2 * (n - 1) / n
    else:  # halo: nbytes is the exchanged margin itself
        frac = 1.0
    return frac * float(nbytes) / max(bw, 1.0)


# --------------------------------------------------------------------------
# Whole-program analytic scoring (design-space exploration, repro.explore)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ProgramScore:
    """Analytic score of one compiled program on one hardware config —
    the three Pareto axes the explore subsystem reports (predicted
    latency, VMEM arena pressure, kernels launched) plus the roofline
    ingredients they came from.

    Built from the JSON pass trace (``score_pass_trace``), so a program
    can be scored from a disk-cache payload without recompiling — the
    sweep runner's fingerprint dedupe path."""

    latency_s: float = 0.0       # pipelined-wavefront latency (see below)
    latency_serial_s: float = 0.0  # blocks serialized (the legacy model)
    bytes_hbm: float = 0.0
    flops: float = 0.0
    vmem_peak_bytes: int = 0     # largest planned arena across blocks
    vmem_bump_peak_bytes: int = 0  # same views under the legacy bump model
    n_kernels: int = 0           # fusion groups = dispatch units
    n_blocks: int = 0
    n_levels: int = 0            # wavefront levels the schedule found
    # interconnect terms (partition pass's shard plan; zero on
    # single-device compiles)
    comm_bytes: float = 0.0      # predicted per-device collective bytes
    comm_s: float = 0.0          # total collective time (incl. hidden)
    n_collectives: int = 0
    per_block: List[Dict] = dataclasses.field(default_factory=list)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def score_pass_trace(trace, n_kernels: int = 0) -> ProgramScore:
    """Aggregate a ``PassManager`` trace (or its JSON round-trip from the
    disk cache) into a :class:`ProgramScore`.

    The autotile pass reports each block's chosen tiling with its
    roofline terms and pipelined per-block latency; the schedule pass
    reports per-block wavefront levels and planned arena bytes.  The
    **pipelined wavefront model** overlaps work the schedule proved
    independent: blocks in one wavefront level share the memory system
    and the compute units concurrently, so a level costs
    ``max(sum t_mem, sum t_compute, max block latency)`` and levels run
    back-to-back.  Blocks the schedule did not level (older traces, or
    passes that renamed blocks) serialize after the levels — which
    degrades exactly to the legacy sum-of-blocks model."""
    score = ProgramScore(n_kernels=n_kernels)
    recs: List[Dict] = []
    levels: Dict[str, int] = {}
    splits: Dict[str, int] = {}      # semantic block -> mesh devices
    comm_exposed = 0.0               # collective time not hidden by compute

    def split_of(block: str) -> int:
        """Shard factor for an autotile rec's block, matching the
        partition pass's semantic names against post-fuse/post-tile
        names (anchor, anchor.sub, or a+b fusion-group names)."""
        for b, k in splits.items():
            if block == b or block.startswith(b + ".") or b in block.split("+"):
                return k
        return 1

    for entry in trace or ():
        name = entry[0]
        report = entry[2] if len(entry) > 2 else []
        if name == "partition":
            # shard-plan annotations: split records scale per-device
            # compute; collective records price the interconnect.  The
            # driver's mesh path appends pre-scaled traces (segments are
            # already local-sized) and emits no split records.
            for rec in report:
                if not isinstance(rec, dict):
                    continue
                if "split" in rec and "block" in rec and rec.get("n"):
                    splits[str(rec["block"])] = max(int(rec["n"]), 1)
                if "collective" in rec:
                    t = float(rec.get("t_comm_s", 0.0))
                    hidden = float(rec.get("t_hidden_s", 0.0)) if rec.get("overlap") else 0.0
                    score.comm_bytes += float(rec.get("bytes", 0.0))
                    score.comm_s += t
                    score.n_collectives += 1
                    comm_exposed += max(t - hidden, 0.0)
        elif name == "autotile":
            for rec in report:
                if not isinstance(rec, dict) or "t_mem" not in rec:
                    continue
                k = split_of(str(rec.get("block", "")))
                if k > 1:
                    rec = dict(rec)
                    for f in ("t_mem", "t_compute", "latency_s", "bytes_hbm",
                              "macs", "t_mem_raw", "t_compute_raw"):
                        if f in rec and rec[f] is not None:
                            rec[f] = rec[f] / k
                recs.append(rec)
                score.bytes_hbm += rec.get("bytes_hbm", 0.0)
                score.flops += 2.0 * rec.get("macs", 0.0)
                # tile footprint is the pressure floor even when no arena
                # is scheduled (single-tile "fits_inner" blocks)
                score.vmem_peak_bytes = max(score.vmem_peak_bytes,
                                            int(rec.get("plan_bytes",
                                                        rec.get("mem_bytes", 0))))
                score.n_blocks += 1
                score.per_block.append(dict(rec))
        elif name == "schedule":
            for rec in report:
                if not isinstance(rec, dict):
                    continue
                if "level" in rec and "block" in rec:
                    levels[str(rec["block"])] = int(rec["level"])
                if "arena_bytes" in rec:
                    score.vmem_peak_bytes = max(score.vmem_peak_bytes,
                                                int(rec["arena_bytes"]))
                if "arena_bump_bytes" in rec:
                    score.vmem_bump_peak_bytes = max(
                        score.vmem_bump_peak_bytes, int(rec["arena_bump_bytes"]))

    def block_latency(rec: Dict) -> float:
        lat = rec.get("latency_s")
        if lat is None:
            lat = max(rec.get("t_mem", 0.0), rec.get("t_compute", 0.0))
        return float(lat)

    def level_of(rec: Dict) -> Optional[int]:
        name = str(rec.get("block", ""))
        cands = [lvl for n, lvl in levels.items()
                 if n == name or n.startswith(name + ".")]
        return min(cands) if cands else None

    by_level: Dict[int, List[Dict]] = {}
    serial: List[Dict] = []
    for rec in recs:
        lvl = level_of(rec)
        (by_level.setdefault(lvl, []) if lvl is not None else serial).append(rec)
    for lvl in sorted(by_level):
        group = by_level[lvl]
        score.latency_s += max(sum(r.get("t_mem", 0.0) for r in group),
                               sum(r.get("t_compute", 0.0) for r in group),
                               max(block_latency(r) for r in group))
    for rec in serial:
        score.latency_s += block_latency(rec)
    # collective time the overlap decisions could not hide serializes
    # after the wavefront (ring-overlapped collectives contribute only
    # their exposed remainder)
    score.latency_s += comm_exposed
    score.latency_serial_s = sum(block_latency(r) for r in recs) + comm_exposed
    score.n_levels = len(by_level)
    return score


def _classify_mnk(block: Block, eff: Mapping[str, int]):
    """(m, n, k) tile extents for stencil utilization: n = output contiguous
    var, k = largest reduction var, m = product of remaining output vars."""
    out_ref = None
    for r in block.refs:
        if r.dir in (RefDir.OUT, RefDir.INOUT):
            out_ref = r
    if out_ref is None:
        return (None, None, None)
    out_vars = [e.terms[0][0] for e in out_ref.offsets if len(e.terms) == 1]
    if not out_vars:
        return (None, None, None)
    n_var = out_vars[-1]
    red = [v for v in eff if v not in out_vars]
    k = max((eff[v] for v in red), default=None)
    # range-1 indexes are dropped from eff by the tiler; they still appear
    # in the output ref (e.g. batch=1 decode), so default their extent to 1
    m = 1
    for v in out_vars[:-1]:
        m *= eff.get(v, 1)
    return (m if out_vars[:-1] else None, eff.get(n_var, 1), k)
