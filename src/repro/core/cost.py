"""Cost models for autotiling (paper §3.3, Fig. 4).

Two models, selected by the hardware config:

* ``cache_lines`` — the paper's model, verbatim: *number of cache lines
  accessed divided by the number of multiply-accumulate operations
  performed*.  Overflow elements still cost lines; constrained-out points
  do not count as MACs.
* ``roofline`` — the TPU generalization: per-tile HBM traffic and MXU
  compute are converted to seconds and the dominant term is minimized
  (Williams et al. roofline, which §3.3 cites as the autotiler's target).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

from .affine import Affine
from .hwconfig import HardwareConfig
from .ir import Block, RefDir, Refinement, dtype_bytes
from .poly import Polyhedron, ceil_div


@dataclasses.dataclass
class TileCost:
    cost: float
    lines: float = 0.0
    macs: float = 0.0
    bytes_hbm: float = 0.0
    t_mem: float = 0.0
    t_compute: float = 0.0
    mem_elems: int = 0
    mem_bytes: int = 0
    n_tiles: int = 1
    feasible: bool = True
    why: str = ""


def _contig_dim(ref: Refinement) -> int:
    if not ref.strides:
        return ref.rank - 1
    best = min(range(ref.rank), key=lambda d: abs(ref.strides[d]) or 10**9)
    return best


def lines_for_view(shape: Tuple[int, ...], ref: Refinement, line: int, aligned: bool) -> int:
    """Cache lines touched by one tile-view of ``ref``."""
    cd = _contig_dim(ref)
    n = 1
    for d, ext in enumerate(shape):
        if d != cd:
            n *= ext
    ext = shape[cd]
    if aligned:
        per_row = ceil_div(ext, line)
    else:
        # worst-case unaligned: a run of ext elements can straddle one extra line
        per_row = ceil_div(ext + line - 1, line)
    return n * per_row


def _tile_view_shapes(block: Block, tiles: Mapping[str, int]) -> List[Tuple[Refinement, Tuple[int, ...], bool, bool]]:
    """For each refinement of a flat block: (ref, tile view shape, is_tiled,
    aligned_in_contig_dim)."""
    free = {i.name: i.range for i in block.idxs if not i.is_passthrough()}
    eff = {v: min(tiles.get(v, free[v]), free[v]) for v in free}
    out = []
    for r in block.refs:
        shape = []
        uses_tiled_var = False
        for e, orig in zip(r.offsets, r.shape):
            span = 0
            for n, c in e.terms:
                if n in eff:
                    span += abs(c) * (eff[n] - 1)
                    if eff[n] < free[n]:
                        uses_tiled_var = True
            shape.append(span + orig)
        # alignment of the contiguous dim: the outer-step in that dim must be
        # a multiple of the line; conservatively aligned iff the tile covers
        # the full contiguous dim or starts at offsets that are multiples.
        cd = _contig_dim(r)
        e = r.offsets[cd]
        full = all(eff.get(n, 1) >= free.get(n, 1) for n in e.names())
        out.append((r, tuple(shape), uses_tiled_var, full))
    return out


_MACS_CACHE: Dict[int, Optional[int]] = {}


def count_macs_exact(block: Block, limit: int = 2_000_000) -> Optional[int]:
    key = id(block)
    if key in _MACS_CACHE:
        return _MACS_CACHE[key]
    poly = block.poly
    if poly.rect_size() > limit:
        out = None
    else:
        out = poly.count()
    _MACS_CACHE[key] = out
    return out


def block_points(block: Block) -> int:
    """Total leaf iteration points (rect) including nested sub-blocks —
    the MAC count proxy for fused/nested structures."""
    rect = 1
    for i in block.idxs:
        if not i.is_passthrough():
            rect *= i.range
    subs = [s for s in block.stmts if isinstance(s, Block)]
    if not subs:
        return rect
    return rect * sum(block_points(s) for s in subs)


def evaluate_tiling(block: Block, tiles: Mapping[str, int], hw: HardwareConfig, params: Mapping) -> TileCost:
    """Cost of tiling a flat contraction/elementwise block by ``tiles``."""
    free = {i.name: i.range for i in block.idxs if not i.is_passthrough()}
    eff = {v: min(tiles.get(v, free[v]), free[v]) for v in free}
    n_tiles = 1
    for v, r in free.items():
        n_tiles *= ceil_div(r, eff[v])

    views = _tile_view_shapes(block, eff)
    inner_mem = hw.inner_mem()
    line = hw.mem_units[0].cache_line_elems
    count_untiled = params.get("count_untiled", True)

    # ---- memory footprint of one tile -------------------------------------
    mem_elems = 0
    mem_bytes = 0
    any_tiled = any(uses for _, _, uses, _ in views)
    for r, shape, uses_tiled, _ in views:
        elems = 1
        for s in shape:
            elems *= s
        # when nothing is tiled (flat candidate) every view IS the tile
        if count_untiled or uses_tiled or not any_tiled:
            mem_elems += elems
            mem_bytes += elems * dtype_bytes(r.dtype)

    cap_e = params.get("mem_cap_elems")
    cap_frac = params.get("mem_cap_frac")
    feasible = True
    why = ""
    if cap_e is not None and mem_elems > cap_e:
        feasible, why = False, f"tile footprint {mem_elems}e > cap {cap_e}e"
    if cap_frac is not None and mem_bytes * 2 > inner_mem.size_bytes * cap_frac:
        feasible, why = False, f"2x tile bytes {2*mem_bytes} > {cap_frac} of {inner_mem.name}"

    # ---- MACs --------------------------------------------------------------
    macs = block_points(block)
    if params.get("exact_macs"):
        exact = count_macs_exact(block)
        if exact is not None and not any(isinstance(s, Block) for s in block.stmts):
            macs = exact

    model = params.get("cost", "cache_lines")
    if model == "cache_lines":
        lines = 0
        bytes_hbm = 0.0
        for r, shape, uses_tiled, aligned in views:
            if not count_untiled and not uses_tiled:
                continue
            n = lines_for_view(shape, r, line, aligned)
            lines += n
            bytes_hbm += n * line * dtype_bytes(r.dtype)
        total_lines = n_tiles * lines
        cost = total_lines / max(macs, 1)
        # seconds-uniform terms so every TileCost converts to a predicted
        # latency (the explore sweeps score cache-line configs too): line
        # transactions priced at outer-memory bandwidth, MACs at peak.
        total_bytes = n_tiles * bytes_hbm
        t_mem = total_bytes / hw.mem_units[0].bandwidth
        t_compute = 2.0 * macs / hw.peak_flops if hw.peak_flops > 0 else 0.0
        return TileCost(cost=cost, lines=total_lines, macs=macs,
                        bytes_hbm=total_bytes, t_mem=t_mem, t_compute=t_compute,
                        mem_elems=mem_elems, mem_bytes=mem_bytes, n_tiles=n_tiles,
                        feasible=feasible, why=why)

    # ---- roofline model ----------------------------------------------------
    # HBM traffic with *consecutive* reuse, matching the Pallas emission:
    # the grid iterates parallel (output) dims outer, reduction dims inner;
    # a ref's block stays resident only while the innermost grid dims that
    # vary do not address it (BlockSpec revisiting).  The output block is
    # revisited across the whole reduction (scratch accumulation).
    out_vars: List[str] = []
    for r, *_ in views:
        if r.dir in (RefDir.OUT, RefDir.INOUT):
            for e in r.offsets:
                for n in e.names():
                    if n not in out_vars:
                        out_vars.append(n)
    grid_dims = [v for v in free if eff[v] < free[v]]
    # order: parallel first, reduction innermost (lower_pallas.grid_order)
    grid_order = [v for v in grid_dims if v in out_vars] + [v for v in grid_dims if v not in out_vars]
    steps = {v: ceil_div(free[v], eff[v]) for v in grid_dims}
    total_steps = 1
    for v in grid_dims:
        total_steps *= steps[v]

    bytes_hbm = 0.0
    for r, shape, _uses, _al in views:
        elems = 1
        for s in shape:
            elems *= s
        ref_vars = set()
        for e in r.offsets:
            ref_vars.update(n for n in e.names() if n in steps)
        reuse = 1
        for v in reversed(grid_order):
            if v in ref_vars:
                break
            reuse *= steps[v]
        fetches = max(total_steps // max(reuse, 1), 1)
        factor = 2 if r.dir == RefDir.INOUT else 1
        bytes_hbm += fetches * elems * dtype_bytes(r.dtype) * factor
    t_mem = bytes_hbm / hw.mem_units[0].bandwidth

    # compute term with stencil-padding utilization
    flops = 2.0 * macs
    util = 1.0
    stencil = None
    for s in hw.stencils:
        if s.name == params.get("stencil", "mxu"):
            stencil = s
            break
    if stencil is not None and "contraction" in block.tags:
        dims = _classify_mnk(block, eff)
        for extent, mult in zip(dims, stencil.dims):
            if extent is None:
                continue
            padded = ceil_div(extent, mult) * mult
            util *= extent / padded
    t_compute = flops / (hw.peak_flops * max(util, 1e-6))
    cost = max(t_mem, t_compute) + 1e-12 * n_tiles
    return TileCost(cost=cost, macs=macs, bytes_hbm=bytes_hbm, t_mem=t_mem,
                    t_compute=t_compute, mem_elems=mem_elems, mem_bytes=mem_bytes,
                    n_tiles=n_tiles, feasible=feasible, why=why)


# --------------------------------------------------------------------------
# Fusion profitability (fusion-group formation, fuse.py)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class FusionDecision:
    """One accepted/rejected merge during fusion-group formation.

    The model arbitrates HBM bytes saved (the eliminated intermediate's
    write + read) against HBM bytes added (inputs refetched once per grid
    tile that revisits them) and VMEM arena pressure (the canonical tile's
    footprint priced with schedule.py's address-assignment arithmetic)."""

    group: str
    member: str
    kind: str  # "prologue" | "epilogue"
    accepted: bool
    hbm_saved: int = 0
    hbm_added: int = 0
    vmem_bytes: int = 0
    vmem_cap: int = 0
    reason: str = ""

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def canonical_tile(ranges: Mapping[str, int], params: Mapping,
                   clamp_vars=None) -> Dict[str, int]:
    """The tile shape the profitability model prices a group at — fusion
    runs before autotiling, so merges are judged at a plausible tile (the
    stencil-ish default 128, clamped to each range), not the final one.
    Only ``clamp_vars`` (typically the anchor's output indices) are
    clamped: a fused group keeps its whole reduction extent resident in
    the inner memory, so reduction dims are priced at full range."""
    ct = int(params.get("canonical_tile", 128))
    if clamp_vars is None:
        clamp_vars = set(ranges)
    return {v: (min(r, ct) if v in clamp_vars else r) for v, r in ranges.items()}


def tile_view_bytes(ref: Refinement, ranges: Mapping[str, int], tile: Mapping[str, int]) -> int:
    """Bytes of one canonical-tile view of ``ref`` (span of the tiled
    index extents through the ref's affine offsets, times dtype).
    Variables absent from ``tile`` span their full range."""
    elems = 1
    for e, orig in zip(ref.offsets, ref.shape):
        span = 0
        for n, c in e.terms:
            extent = tile.get(n, ranges.get(n, 1))
            span += abs(c) * (extent - 1)
        elems *= span + orig
    return elems * dtype_bytes(ref.dtype)


def refetch_bytes(ref_vars, free: Mapping[str, int], out_vars, tile: Mapping[str, int],
                  full_bytes: int) -> int:
    """Extra HBM traffic a fused read of ``full_bytes`` incurs: the buffer
    is re-fetched once per grid tile along every *output* dimension that
    does not address it (reduction dims revisit for free — the block stays
    resident across the reduction, matching the Pallas emission)."""
    revisits = 1
    for v in out_vars:
        if v not in ref_vars:
            revisits *= ceil_div(free[v], tile.get(v, free[v]))
    return full_bytes * max(revisits - 1, 0)


def fusion_vmem_pressure(refs, ranges: Mapping[str, int], hw: HardwareConfig,
                         params: Mapping, clamp_vars=None) -> Tuple[int, int, bool]:
    """(arena bytes for one canonical tile of the candidate group, cap,
    fits).  Pressure is priced with schedule.py's arena arithmetic and
    doubled for the double-buffering headroom the autotiler also budgets."""
    from .passes.schedule import arena_bytes

    tile = canonical_tile(ranges, params, clamp_vars)
    sizes = [tile_view_bytes(r, ranges, tile) for r in refs]
    pressure = 2 * arena_bytes(sizes)
    cap = int(hw.inner_mem().size_bytes * params.get("mem_cap_frac", 0.45))
    return pressure, cap, pressure <= cap


# --------------------------------------------------------------------------
# Whole-program analytic scoring (design-space exploration, repro.explore)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ProgramScore:
    """Analytic score of one compiled program on one hardware config —
    the three Pareto axes the explore subsystem reports (predicted
    latency, VMEM arena pressure, kernels launched) plus the roofline
    ingredients they came from.

    Built from the JSON pass trace (``score_pass_trace``), so a program
    can be scored from a disk-cache payload without recompiling — the
    sweep runner's fingerprint dedupe path."""

    latency_s: float = 0.0       # sum over blocks of max(t_mem, t_compute)
    bytes_hbm: float = 0.0
    flops: float = 0.0
    vmem_peak_bytes: int = 0     # largest scheduled arena across grid blocks
    n_kernels: int = 0           # fusion groups = dispatch units
    n_blocks: int = 0
    per_block: List[Dict] = dataclasses.field(default_factory=list)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def score_pass_trace(trace, n_kernels: int = 0) -> ProgramScore:
    """Aggregate a ``PassManager`` trace (or its JSON round-trip from the
    disk cache) into a :class:`ProgramScore`.

    The autotile pass reports each block's chosen tiling with its
    roofline terms; the schedule pass reports per-grid-block arena bytes.
    Latency is the sum of per-block dominant roofline terms — blocks run
    back-to-back, which matches the per-group dispatch model."""
    score = ProgramScore(n_kernels=n_kernels)
    for entry in trace or ():
        name = entry[0]
        report = entry[2] if len(entry) > 2 else []
        if name == "autotile":
            for rec in report:
                if not isinstance(rec, dict) or "t_mem" not in rec:
                    continue
                score.latency_s += max(rec.get("t_mem", 0.0), rec.get("t_compute", 0.0))
                score.bytes_hbm += rec.get("bytes_hbm", 0.0)
                score.flops += 2.0 * rec.get("macs", 0.0)
                # tile footprint is the pressure floor even when no arena
                # is scheduled (single-tile "fits_inner" blocks)
                score.vmem_peak_bytes = max(score.vmem_peak_bytes,
                                            int(rec.get("mem_bytes", 0)))
                score.n_blocks += 1
                score.per_block.append(dict(rec))
        elif name == "schedule":
            for rec in report:
                if isinstance(rec, dict) and "arena_bytes" in rec:
                    score.vmem_peak_bytes = max(score.vmem_peak_bytes,
                                                int(rec["arena_bytes"]))
    return score


def _classify_mnk(block: Block, eff: Mapping[str, int]):
    """(m, n, k) tile extents for stencil utilization: n = output contiguous
    var, k = largest reduction var, m = product of remaining output vars."""
    out_ref = None
    for r in block.refs:
        if r.dir in (RefDir.OUT, RefDir.INOUT):
            out_ref = r
    if out_ref is None:
        return (None, None, None)
    out_vars = [e.terms[0][0] for e in out_ref.offsets if len(e.terms) == 1]
    if not out_vars:
        return (None, None, None)
    n_var = out_vars[-1]
    red = [v for v in eff if v not in out_vars]
    k = max((eff[v] for v in red), default=None)
    m = 1
    for v in out_vars[:-1]:
        m *= eff[v]
    return (m if out_vars[:-1] else None, eff[n_var], k)
