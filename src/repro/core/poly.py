"""Bounded integer polyhedra (paper Definition 1, restricted as in §3.2).

Stripe encourages rectilinear iteration spaces: every index carries a
``range`` (``0 <= idx < range``) and a block may add extra affine
constraints (``expr >= 0``) for the non-rectilinear parts (halos, overflow
removal).  This module provides the small amount of polyhedral math the
passes need: point enumeration (small spaces only), membership, cardinality,
bounds propagation, and emptiness checks.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, Iterator, List, Mapping, Sequence, Tuple

from .affine import Affine


@dataclasses.dataclass(frozen=True)
class Index:
    """A polyhedron dimension: ``0 <= name < range``.

    ``affine`` (when set) declares this index to be a pass-through of a
    parent-block expression instead of a free iteration variable (Stripe
    passes parent indices to children explicitly this way); such an index
    has range 1 and contributes no iteration.
    """

    name: str
    range: int
    affine: Affine | None = None

    def __post_init__(self):
        if self.affine is None and self.range < 0:
            raise ValueError(f"index {self.name} has negative range {self.range}")

    def is_passthrough(self) -> bool:
        return self.affine is not None

    def __str__(self) -> str:
        if self.affine is not None:
            return f"{self.name}={self.affine}"
        return f"{self.name}:{self.range}"


@dataclasses.dataclass(frozen=True)
class Constraint:
    """``expr >= 0`` over the block's (and its parents') index names."""

    expr: Affine

    def satisfied(self, env: Mapping[str, int]) -> bool:
        return self.expr.eval(env) >= 0

    def __str__(self) -> str:
        return f"{self.expr} >= 0"


class Polyhedron:
    """Iteration space: free indices with ranges + affine constraints."""

    def __init__(self, idxs: Sequence[Index], constraints: Sequence[Constraint] = ()):
        self.idxs = list(idxs)
        self.constraints = list(constraints)

    # ------------------------------------------------------------- helpers
    def free_idxs(self) -> List[Index]:
        return [i for i in self.idxs if not i.is_passthrough()]

    def names(self) -> List[str]:
        return [i.name for i in self.idxs]

    def rect_size(self) -> int:
        """Cardinality ignoring constraints (the bounding box)."""
        n = 1
        for i in self.free_idxs():
            n *= i.range
        return n

    # ----------------------------------------------------------- iteration
    def points(self, parent_env: Mapping[str, int] | None = None) -> Iterator[Dict[str, int]]:
        """Enumerate integer points (small spaces; oracle / tests only)."""
        parent_env = dict(parent_env or {})
        free = self.free_idxs()
        ranges = [range(i.range) for i in free]
        for combo in itertools.product(*ranges):
            env = dict(parent_env)
            env.update({i.name: v for i, v in zip(free, combo)})
            for i in self.idxs:
                if i.is_passthrough():
                    env[i.name] = i.affine.eval(env)
            if all(c.satisfied(env) for c in self.constraints):
                yield env

    def contains(self, env: Mapping[str, int]) -> bool:
        for i in self.free_idxs():
            v = env[i.name]
            if not (0 <= v < i.range):
                return False
        full = dict(env)
        for i in self.idxs:
            if i.is_passthrough():
                full[i.name] = i.affine.eval(full)
        return all(c.satisfied(full) for c in self.constraints)

    def count(self, parent_env: Mapping[str, int] | None = None) -> int:
        return sum(1 for _ in self.points(parent_env))

    # ------------------------------------------------- bounds / emptiness
    def expr_bounds(self, expr: Affine, outer_bounds: Mapping[str, Tuple[int, int]] | None = None) -> Tuple[int, int]:
        """Inclusive (lo, hi) interval bound of ``expr`` over the bounding
        box (interval arithmetic — sound, not tight w.r.t. constraints)."""
        lo = hi = expr.const
        bounds = dict(outer_bounds or {})
        for i in self.idxs:
            if not i.is_passthrough():
                bounds.setdefault(i.name, (0, i.range - 1))
        # Passthrough indices: resolve recursively via their affine defs.
        for i in self.idxs:
            if i.is_passthrough() and i.name not in bounds:
                bounds[i.name] = self.expr_bounds(i.affine, bounds)
        for n, c in expr.terms:
            if n not in bounds:
                raise KeyError(f"no bounds known for index '{n}'")
            blo, bhi = bounds[n]
            lo += min(c * blo, c * bhi)
            hi += max(c * blo, c * bhi)
        return lo, hi

    def definitely_empty(self, outer_bounds: Mapping[str, Tuple[int, int]] | None = None) -> bool:
        """True if some constraint can never be satisfied (interval test)."""
        if any(i.range == 0 for i in self.free_idxs()):
            return True
        for c in self.constraints:
            _, hi = self.expr_bounds(c.expr, outer_bounds)
            if hi < 0:
                return True
        return False

    def constraint_always_true(self, c: Constraint, outer_bounds: Mapping[str, Tuple[int, int]] | None = None) -> bool:
        lo, _ = self.expr_bounds(c.expr, outer_bounds)
        return lo >= 0

    def simplified_constraints(self, outer_bounds: Mapping[str, Tuple[int, int]] | None = None) -> List[Constraint]:
        """Drop constraints that the bounding box already implies."""
        return [c for c in self.constraints if not self.constraint_always_true(c, outer_bounds)]

    def __str__(self) -> str:
        s = ", ".join(str(i) for i in self.idxs)
        if self.constraints:
            s += " | " + ", ".join(str(c) for c in self.constraints)
        return f"[{s}]"


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def factors(n: int) -> List[int]:
    out = [d for d in range(1, int(math.isqrt(n)) + 1) if n % d == 0]
    out += [n // d for d in reversed(out) if d * d != n]
    return out
