"""Liveness-driven inner-memory (VMEM) planning.

The nested polyhedral model makes memory placement a first-class,
optimizable decision (paper §2.3/§3.2): every refinement of a grid block
names a view that must be materialized in the inner memory while the
grid streams over tiles.  This module turns that into an explicit
**memory plan**:

* **View classification** — a grid block's tile views are *streamed*
  (their offsets are addressed by a grid index, so the Pallas pipeline
  re-fetches them as the grid steps; they need ``pipeline_depth`` arena
  slots for fetch/compute overlap), *resident* (grid-invariant views —
  e.g. an untiled weight — fetched once and held in a single slot), or
  the *accumulator* (an output revisited across reduction grid steps:
  one slot, written at flush, plus a float32 scratch tile that carries
  the partial sums between steps — exactly the scratch
  ``lower_pallas`` allocates).
* **Live intervals** — inside a flat (single-tile) block, a view is
  live only over the span of body statements that touch it, in the
  scheduled statement order; across the program, a block's whole arena
  is live only during its wavefront level.  (Inside a *grid* block
  every view persists across grid steps, so intervals there are whole-
  body by construction.)
* **Interval-graph best-fit allocation** — views are placed into one
  arena address space; a dead view's space is reused by the best-fit
  (smallest sufficient) gap, every slot aligned to ``ARENA_ALIGN``.

The plan replaces two blanket approximations:

* the bump allocator in ``passes/schedule.py`` that assigned addresses
  with zero reuse, and
* the ``mem_bytes * 2`` feasibility rule in ``cost.evaluate_tiling``
  that double-buffered *every* view — the planner's exact footprint
  double-buffers only the streamed ones, so the autotiler can legally
  pick tiles up to ~2x larger under the same VMEM capacity.

For before/after reporting, every :class:`BlockPlan` also carries
``bump_bytes``: the legacy model priced on the same view list (no
liveness, no slot classes, everything double-buffered).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Set, Tuple

from .ir import Block, Load, RefDir, Refinement, Store, dtype_bytes

ARENA_ALIGN = 512  # bytes; every arena slot starts on this boundary


def align_up(n: int, align: int = ARENA_ALIGN) -> int:
    return (int(n) + align - 1) & ~(align - 1)


# --------------------------------------------------------------------------
# Views and allocations
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ViewSpec:
    """One object the planner must place in the inner-memory arena."""

    name: str
    nbytes: int          # bytes of ONE slot, unaligned
    slots: int = 1       # pipeline slots (streamed views get pipeline_depth)
    start: int = 0       # live interval [start, end], inclusive, in
    end: int = 0         # scheduled-statement-order positions
    kind: str = "resident"  # stream | halo | resident | acc | scratch | local
    halo_bytes: int = 0  # margin bytes of a halo-windowed streamed slot
    #                      (slot = tile core + this overlap, already in
    #                      nbytes — recorded so reports can price the
    #                      overlap the conv windows carry)


@dataclasses.dataclass(frozen=True)
class Allocation:
    view: ViewSpec
    addr: int
    nbytes: int  # total allocated bytes: align_up(view.nbytes) * view.slots


def allocate(views: Sequence[ViewSpec], align: int = ARENA_ALIGN
             ) -> Tuple[List[Allocation], int]:
    """Interval-graph best-fit arena allocation.

    Views are placed in order of live-interval start (larger requests
    first on ties, name as the final deterministic tie-break).  A view
    whose interval has ended releases its space; the allocator fills the
    best-fit (smallest sufficient) gap between still-live allocations
    before growing the arena top.  Two views whose live intervals
    overlap are never given overlapping address ranges (the hypothesis
    property in ``tests/test_memplan.py``).

    Returns ``(allocations, peak_bytes)``.
    """
    live: List[Allocation] = []
    out: List[Allocation] = []
    peak = 0
    order = sorted(views, key=lambda v: (v.start, -(align_up(v.nbytes, align)
                                                    * max(v.slots, 1)), v.name))
    for v in order:
        total = align_up(v.nbytes, align) * max(v.slots, 1)
        live = [a for a in live if a.view.end >= v.start]
        best_addr: Optional[int] = None
        best_gap: Optional[int] = None
        cursor = 0
        for a in sorted(live, key=lambda a: a.addr):
            gap = a.addr - cursor
            if gap >= total and (best_gap is None or gap < best_gap):
                best_addr, best_gap = cursor, gap
            cursor = max(cursor, a.addr + a.nbytes)
        addr = cursor if best_addr is None else best_addr
        alloc = Allocation(view=v, addr=addr, nbytes=total)
        live.append(alloc)
        out.append(alloc)
        peak = max(peak, addr + total)
    return out, peak


def bump_bytes(views: Iterable[ViewSpec], align: int = ARENA_ALIGN) -> int:
    """The legacy arena model on the same view list: no liveness reuse,
    no slot classes — every view blanket-double-buffered (the old
    ``mem_bytes * 2`` rule, expressed in the address assigner's aligned
    arithmetic).  The f32 partial-sum scratch is priced once: it is a
    real buffer both models must hold, and only the planner's *slot*
    policy is under comparison — doubling it would inflate the baseline
    with an allocation the legacy rule never made."""
    return sum((1 if v.kind == "scratch" else 2) * align_up(v.nbytes, align)
               for v in views)


# --------------------------------------------------------------------------
# Block plans
# --------------------------------------------------------------------------
@dataclasses.dataclass
class BlockPlan:
    """The memory plan of one top-level block (grid or single-tile)."""

    block: str
    allocs: List[Allocation]
    peak_bytes: int
    bump_bytes: int
    depth: int
    grid: bool
    red_vars: Tuple[str, ...] = ()      # grid vars that revisit the output
    parallel_vars: Tuple[str, ...] = ()  # grid vars that stream the output
    acc_bytes: int = 0                  # f32 accumulator scratch (0 = none)
    halo_bytes: int = 0                 # total halo margin across slots

    def addr_of(self, name: str) -> Optional[int]:
        for a in self.allocs:
            if a.view.name == name:
                return a.addr
        return None

    def to_json(self) -> Dict:
        return {
            "block": self.block,
            "peak_bytes": self.peak_bytes,
            "bump_bytes": self.bump_bytes,
            "depth": self.depth,
            "acc_bytes": self.acc_bytes,
            "halo_bytes": self.halo_bytes,
            "slots": {a.view.name: {"addr": a.addr, "bytes": a.nbytes,
                                    "kind": a.view.kind, "slots": a.view.slots,
                                    "halo_bytes": a.view.halo_bytes}
                      for a in self.allocs},
        }


def view_span_bytes(ref: Refinement, ranges: Mapping[str, int]) -> int:
    """Bytes of the view ``ref`` spans when its offset variables sweep
    ``ranges`` — the resident footprint of a single-tile block's view."""
    elems = 1
    for e, orig in zip(ref.offsets, ref.shape):
        span = 0
        for n, c in e.terms:
            span += abs(c) * (ranges.get(n, 1) - 1)
        elems *= span + orig
    return elems * dtype_bytes(ref.dtype)


def _touches(stmt, name: str) -> bool:
    if isinstance(stmt, Block):
        if any(r.from_buf == name for r in stmt.refs):
            return True
        return any(_touches(s, name) for s in stmt.stmts)
    if isinstance(stmt, (Load, Store)):
        return stmt.buf == name
    return False


def _body_interval(body: Sequence, name: str) -> Tuple[int, int]:
    """Live interval of ``name`` over the block body's statement order
    (whole body when the name is never found — conservative)."""
    positions = [i for i, s in enumerate(body) if _touches(s, name)]
    if not positions:
        return 0, max(len(body) - 1, 0)
    return positions[0], positions[-1]


def slots_for(is_output: bool, streamed: bool, revisited: bool, depth: int
              ) -> Tuple[str, int]:
    """(kind, slots) of one tile view under the pipeline model."""
    if is_output:
        if revisited:
            return "acc", 1          # written once at flush; scratch carries
        return ("stream", max(depth, 1)) if streamed else ("resident", 1)
    return ("stream", max(depth, 1)) if streamed else ("resident", 1)


def plan_block(block: Block, depth: int = 2) -> BlockPlan:
    """Plan the inner-memory arena of one top-level block.

    For a ``grid``-tagged block the refs' view shapes *are* the tile
    views the pipeline materializes; every view persists across grid
    steps, so intervals are whole-body and the classification (streamed
    / resident / accumulator) does the work.  For a flat (single-tile)
    block, views span the block's own index ranges and are live only
    over the body statements that touch them — the liveness reuse case.
    """
    grid = "grid" in block.tags
    grid_vars: Set[str] = (
        {i.name for i in block.idxs if not i.is_passthrough()} if grid else set())
    ranges = block.idx_ranges()

    out_ref: Optional[Refinement] = None
    for r in block.refs:
        if r.dir in (RefDir.OUT, RefDir.INOUT):
            out_ref = r
    out_vars: Set[str] = set()
    if out_ref is not None:
        for e in out_ref.offsets:
            out_vars.update(n for n in e.names() if n in grid_vars)
    red_vars = tuple(v for v in grid_vars if v not in out_vars)
    parallel_vars = tuple(v for v in grid_vars if v in out_vars)

    body: Sequence = block.stmts
    if grid:
        subs = block.sub_blocks()
        if len(subs) == 1:
            body = subs[0].stmts

    views: List[ViewSpec] = []
    for r in block.refs:
        if r.dir == RefDir.NONE:
            if r.is_scalar_view():
                continue  # per-iteration scalar temporaries live in registers
            nbytes = view_span_bytes(r, ranges)
            s, e = (0, max(len(body) - 1, 0)) if grid else _body_interval(body, r.into)
            views.append(ViewSpec(name=r.into, nbytes=nbytes, slots=1,
                                  start=s, end=e, kind="local"))
            continue
        ref_vars = {n for e in r.offsets for n in e.names()}
        streamed = bool(ref_vars & grid_vars)
        is_out = r.dir in (RefDir.OUT, RefDir.INOUT)
        revisited = is_out and bool(red_vars)
        kind, slots = slots_for(is_out, streamed, revisited, depth)
        nbytes = prod_bytes(r) if grid else view_span_bytes(r, ranges)
        halo = halo_margin_bytes(r, grid_vars) if grid else 0
        if halo > 0 and kind == "stream":
            # a halo-windowed streamed slot: the pipeline fetches the tile
            # core PLUS the overlap margin every grid step (priced in
            # nbytes already — the view shape carries the halo)
            kind = "halo"
        if grid:
            s, e = 0, max(len(body) - 1, 0)
        else:
            s, e = _body_interval(body, r.into)
        views.append(ViewSpec(name=r.into, nbytes=nbytes, slots=slots,
                              start=s, end=e, kind=kind, halo_bytes=halo))

    acc_bytes = 0
    if out_ref is not None and red_vars:
        # the cross-grid-step partial-sum carrier lower_pallas allocates
        elems = 1
        for s in out_ref.shape:
            elems *= s
        acc_bytes = elems * 4  # float32 accumulation
        views.append(ViewSpec(name=f"{out_ref.into}.acc", nbytes=acc_bytes,
                              slots=1, start=0, end=max(len(body) - 1, 0),
                              kind="scratch"))

    allocs, peak = allocate(views)
    return BlockPlan(block=block.name, allocs=allocs, peak_bytes=peak,
                     bump_bytes=bump_bytes(views), depth=depth, grid=grid,
                     red_vars=red_vars, parallel_vars=parallel_vars,
                     acc_bytes=acc_bytes,
                     halo_bytes=sum(v.halo_bytes * max(v.slots, 1) for v in views))


def halo_margin_bytes(ref: Refinement, grid_vars: Set[str]) -> int:
    """Overlap margin of one grid-streamed view: bytes beyond the tile
    *core* (the grid step) that a halo window re-fetches every grid step.
    A dim stepped by a grid var with coefficient < extent (the conv case:
    offset ``8*x - 1`` with extent 10) contributes ``extent - step``
    margin; block-aligned dims contribute none."""
    core = 1
    full = 1
    for e, size in zip(ref.offsets, ref.shape):
        step = sum(abs(c) for n, c in e.terms if n in grid_vars)
        core *= step if 0 < step < size else size
        full *= size
    return (full - core) * dtype_bytes(ref.dtype)


def prod_bytes(ref: Refinement) -> int:
    n = dtype_bytes(ref.dtype)
    for s in ref.shape:
        n *= s
    return n


def assign_addresses(block: Block, plan: BlockPlan, unit: str) -> None:
    """Write the planned slot base addresses into the block's inner
    refinements located in ``unit`` (the views through which the tile is
    addressed), replacing the old no-reuse bump assignment."""
    for b in block.walk():
        if b is block:
            continue
        for i, r in enumerate(b.refs):
            if r.location is None or r.location.unit != unit or r.location.addr is not None:
                continue
            addr = plan.addr_of(r.from_buf)
            if addr is None:
                addr = plan.addr_of(r.into)
            if addr is not None:
                b.refs[i] = _with_addr(r, addr)


def _with_addr(r: Refinement, addr: int) -> Refinement:
    from .ir import Location

    out = r.clone()
    out.location = Location(unit=r.location.unit, bank=r.location.bank, addr=addr)
    return out


# --------------------------------------------------------------------------
# Tile-footprint model (autotile feasibility / fusion pressure)
# --------------------------------------------------------------------------
def tile_footprint_bytes(entries: Iterable[Tuple[int, str, int]],
                         align: int = ARENA_ALIGN) -> int:
    """Exact planned footprint of one tile: ``entries`` are
    ``(nbytes, kind, slots)`` triples as produced by :func:`slots_for`.
    All views of one tile are concurrently live (the pipeline holds
    them across grid steps), so the footprint is the slot sum — the
    reuse the planner buys over the legacy rule is in the *slots*
    (streamed-only double-buffering), not the intervals."""
    return sum(align_up(b, align) * max(s, 1) for b, _k, s in entries)


# --------------------------------------------------------------------------
# Program-level plan (wavefront-scheduled statement order)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ProgramPlan:
    """One arena across the whole program: each top-level block's arena
    is live only during its wavefront level, so sequential blocks reuse
    the same address space while same-level (parallel) blocks coexist."""

    block_plans: Dict[str, BlockPlan]
    block_base: Dict[str, int]     # arena base offset per block
    peak_bytes: int                # liveness-packed program arena
    bump_bytes: int                # no-reuse: sum of per-block bump arenas
    n_levels: int

    def to_json(self) -> Dict:
        return {
            "peak_bytes": self.peak_bytes,
            "bump_bytes": self.bump_bytes,
            "n_levels": self.n_levels,
            "blocks": {n: {"base": self.block_base.get(n, 0),
                           "peak_bytes": p.peak_bytes,
                           "bump_bytes": p.bump_bytes}
                       for n, p in self.block_plans.items()},
        }


def plan_program(blocks_with_levels: Sequence[Tuple[Block, int]],
                 depth: int = 2) -> ProgramPlan:
    """Plan every top-level block and pack the per-block arenas into one
    program arena over the wavefront-scheduled statement order."""
    plans: Dict[str, BlockPlan] = {}
    views: List[ViewSpec] = []
    bump = 0
    levels: Set[int] = set()
    for blk, lvl in blocks_with_levels:
        plan = plan_block(blk, depth=depth)
        plans[blk.name] = plan
        levels.add(lvl)
        bump += plan.bump_bytes
        if plan.peak_bytes > 0:
            views.append(ViewSpec(name=blk.name, nbytes=plan.peak_bytes,
                                  slots=1, start=lvl, end=lvl, kind="block"))
    allocs, peak = allocate(views)
    base = {a.view.name: a.addr for a in allocs}
    return ProgramPlan(block_plans=plans, block_base=base, peak_bytes=peak,
                       bump_bytes=bump, n_levels=len(levels))
