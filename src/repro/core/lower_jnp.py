"""Lowering Stripe programs to jax.numpy (the "reference backend").

The jnp backend consumes *frontend-shaped* flat blocks (one polyhedron, a
scalar-view load/compute/store body) and emits vectorized JAX:

* pure-index contractions        -> ``jnp.einsum``
* windowed contractions (convs,
  strided/offset accesses)       -> pad + shifted-slice + einsum per window
  point, aggregated with the block's aggregation op, with halo constraints
  materialized as masks on the output grid (the paper's Fig. 4 "accesses to
  overflow elements are removed by constraints in execution")
* elementwise DAGs               -> broadcast + intrinsic table

This is the execution path used on CPU (tests, smoke training) and the
oracle for the Pallas backend.  Optimization passes do not change this
lowering's semantics — they restructure blocks for the Pallas/TPU backend
and for the cost model; `lower_program_jnp` always lowers from the
semantic (flat) form, which passes preserve via the ``frontend`` tag.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from functools import partial
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .affine import Affine
from .ir import (
    AGG_IDENTITY,
    Block,
    Constant,
    Intrinsic,
    Load,
    Program,
    RefDir,
    Refinement,
    Store,
)

_EINSUM_LETTERS = "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ"

_J_UNARY = {
    "neg": jnp.negative, "exp": jnp.exp, "log": jnp.log, "tanh": jnp.tanh,
    "sqrt": jnp.sqrt, "rsqrt": jax.lax.rsqrt, "sigmoid": jax.nn.sigmoid,
    "relu": jax.nn.relu, "abs": jnp.abs, "square": jnp.square,
    "erf": jax.lax.erf, "gelu": partial(jax.nn.gelu, approximate=False),
    "silu": jax.nn.silu, "sign": jnp.sign, "floor": jnp.floor, "cast": lambda a: a,
}
_J_BINARY = {
    "add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
    "div": jnp.divide, "max": jnp.maximum, "min": jnp.minimum, "pow": jnp.power,
}

_AGG_JNP = {
    "add": jnp.add, "max": jnp.maximum, "min": jnp.minimum, "mul": jnp.multiply,
}


# --------------------------------------------------------------------------
# Block analysis: rebuild the expression DAG from the statement list
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Node:
    kind: str  # 'load' | 'const' | 'op'
    ref: Optional[Refinement] = None
    value: float = 0.0
    op: str = ""
    args: Tuple["_Node", ...] = ()


@dataclasses.dataclass
class FlatOp:
    block: Block
    out_ref: Refinement
    agg: str
    root: _Node
    ranges: Dict[str, int]
    out_vars: List[str]  # one per non-degenerate output dim


def analyze_flat(block: Block) -> FlatOp:
    env: Dict[str, _Node] = {}
    out_ref = None
    root = None
    for s in block.stmts:
        if isinstance(s, Load):
            env[s.into] = _Node("load", ref=block.ref(s.buf))
        elif isinstance(s, Constant):
            env[s.into] = _Node("const", value=s.value)
        elif isinstance(s, Intrinsic):
            env[s.into] = _Node("op", op=s.op, args=tuple(env[a] for a in s.args))
        elif isinstance(s, Store):
            out_ref = block.ref(s.buf)
            root = env[s.scalar]
        elif isinstance(s, Block):
            raise ValueError("analyze_flat: nested block (not frontend-shaped)")
    if out_ref is None or root is None:
        raise ValueError("analyze_flat: no store")
    out_vars = []
    for e in out_ref.offsets:
        if len(e.terms) == 1 and e.const == 0 and e.terms[0][1] == 1:
            out_vars.append(e.terms[0][0])
        elif e.is_const():
            out_vars.append(None)  # degenerate dim, fixed position
        else:
            raise ValueError(f"output access must be plain index, got {e}")
    return FlatOp(
        block=block, out_ref=out_ref, agg=out_ref.agg or "assign", root=root,
        ranges=block.idx_ranges(), out_vars=[v for v in out_vars if v is not None],
    )


def _product_leaves(n: _Node) -> Optional[Tuple[List[_Node], float]]:
    if n.kind == "load":
        return [n], 1.0
    if n.kind == "const":
        return [], n.value
    if n.kind == "op" and n.op == "mul":
        leaves: List[_Node] = []
        scale = 1.0
        for a in n.args:
            sub = _product_leaves(a)
            if sub is None:
                return None
            leaves.extend(sub[0])
            scale *= sub[1]
        return leaves, scale
    return None


# --------------------------------------------------------------------------
# Operand materialization
# --------------------------------------------------------------------------
def _materialize(arr: jnp.ndarray, exprs: Sequence[Affine], ranges: Mapping[str, int], wenv: Mapping[str, int]) -> Tuple[jnp.ndarray, List[str]]:
    """Slice ``arr`` so each remaining axis corresponds to one index var.

    Every expr must reduce (after substituting ``wenv``) to ``c*v + k`` or a
    constant.  Returns (array, axis var names)."""
    var_axes: List[str] = []
    index: List[object] = []
    pads: List[Tuple[int, int]] = []
    need_pad = False
    for d, e in enumerate(exprs):
        e = e.partial_eval(wenv)
        size = arr.shape[d]
        if e.is_const():
            k = e.const
            pl = max(0, -k)
            ph = max(0, k - (size - 1))
            pads.append((pl, ph))
            need_pad = need_pad or pl or ph
            index.append(k + pl)
        else:
            if len(e.terms) != 1:
                raise ValueError(f"unwindowed multi-var access {e}")
            (v, c), k = e.terms[0], e.const
            rv = ranges[v]
            lo = min(k, k + c * (rv - 1))
            hi = max(k, k + c * (rv - 1))
            pl = max(0, -lo)
            ph = max(0, hi - (size - 1))
            pads.append((pl, ph))
            need_pad = need_pad or pl or ph
            start = k + pl
            if c > 0:
                index.append(slice(start, start + c * (rv - 1) + 1, c))
            else:
                stop = start + c * (rv - 1) - 1
                index.append(slice(start, None if stop < 0 else stop, c))
            var_axes.append(v)
    if need_pad:
        arr = jnp.pad(arr, pads)
    return arr[tuple(index)], var_axes


def _mask_on_grid(constraints, grid_vars: List[str], ranges, wenv, dtype=bool):
    """AND of ``expr >= 0`` over the grid spanned by grid_vars."""
    shape = tuple(ranges[v] for v in grid_vars)
    mask = None
    for c in constraints:
        e = c.expr.partial_eval(wenv)
        if e.is_const():
            val = e.const >= 0
            m = jnp.full(shape, val)
        else:
            acc = jnp.full(shape, e.const, dtype=jnp.int32)
            for n, coef in e.terms:
                ax = grid_vars.index(n)
                iota = jax.lax.broadcasted_iota(jnp.int32, shape, ax)
                acc = acc + coef * iota
            m = acc >= 0
        mask = m if mask is None else (mask & m)
    return mask


def _unhandled_constraint_vars(constraints, wenv, allowed):
    out = set()
    for c in constraints:
        e = c.expr.partial_eval(wenv)
        for n in e.names():
            if n not in allowed:
                out.add(n)
    return out


# --------------------------------------------------------------------------
# Lowering paths
# --------------------------------------------------------------------------
def _acc_dtype(out_dtype: str) -> jnp.dtype:
    d = np.dtype(out_dtype)
    if d.kind in "iu":
        return jnp.int32
    if d == np.float64:
        return jnp.float64
    return jnp.float32


def _window_vars(op: FlatOp, leaves: List[_Node]) -> List[str]:
    """Vars that must be enumerated: every var beyond the first carrier in a
    multi-var access dim, plus constraint vars that are not output vars."""
    window: set = set()
    out_set = set(op.out_vars)
    if op.agg not in ("add", "assign"):
        # einsum can only sum; other aggregations enumerate every reduction
        # point and combine with the aggregation op across steps.
        window.update(v for v, r in op.ranges.items() if v not in out_set and r > 1)
    for leaf in leaves:
        for e in leaf.ref.offsets:
            names = [n for n in e.names() if op.ranges.get(n, 1) > 1]
            if len(names) <= 1:
                continue
            carriers = [n for n in names if n in out_set] or names
            carrier = max(carriers, key=lambda n: op.ranges[n])
            window.update(n for n in names if n != carrier)
    # constraints must end up over output vars only
    for _ in range(4):
        extra = _unhandled_constraint_vars(op.block.constraints, {w: 0 for w in window}, out_set)
        if not extra:
            break
        window.update(extra)
    return sorted(window)


def lower_contraction(op: FlatOp, leaves: List[_Node], scale: float) -> Callable:
    wvars = _window_vars(op, leaves)
    wsizes = [op.ranges[v] for v in wvars]
    n_steps = int(np.prod(wsizes)) if wvars else 1
    if n_steps > 16384:
        raise ValueError(f"window too large ({n_steps} steps)")
    out_shape = tuple(op.ranges[v] for v in op.out_vars)
    agg = op.agg
    identity = AGG_IDENTITY.get(agg, 0.0)
    out_dtype = np.dtype(op.out_ref.dtype)
    acc_dtype = _acc_dtype(op.out_ref.dtype)

    def fn(arrays: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        acc = None
        for combo in itertools.product(*[range(s) for s in wsizes]):
            wenv = dict(zip(wvars, combo))
            ops, labels = [], []
            for leaf in leaves:
                arr = arrays[leaf.ref.from_buf].astype(acc_dtype)
                mat, axes = _materialize(arr, leaf.ref.offsets, op.ranges, wenv)
                ops.append(mat)
                labels.append(axes)
            var_letter: Dict[str, str] = {}
            for axes in labels + [op.out_vars]:
                for v in axes:
                    var_letter.setdefault(v, _EINSUM_LETTERS[len(var_letter)])
            eq = ",".join("".join(var_letter[v] for v in axes) for axes in labels)
            eq += "->" + "".join(var_letter[v] for v in op.out_vars)
            term = jnp.einsum(eq, *ops) if leaves else jnp.full(out_shape, 1.0, acc_dtype)
            if scale != 1.0:
                term = term * jnp.asarray(scale, acc_dtype)
            mask = _mask_on_grid(op.block.constraints, op.out_vars, op.ranges, wenv)
            if mask is not None:
                term = jnp.where(mask, term, jnp.asarray(identity, acc_dtype))
            if acc is None:
                acc = term
            else:
                acc = _AGG_JNP[agg](acc, term) if agg != "assign" else term
        return acc.astype(out_dtype)

    return fn


def _eval_dag(n: _Node, arrays, op: FlatOp, cache) -> Tuple[jnp.ndarray, List[str]]:
    key = id(n)
    if key in cache:
        return cache[key]
    if n.kind == "load":
        arr = arrays[n.ref.from_buf]
        mat, axes = _materialize(arr, n.ref.offsets, op.ranges, {})
        res = (mat, axes)
    elif n.kind == "const":
        res = (jnp.asarray(n.value), [])
    else:
        vals = [_eval_dag(a, arrays, op, cache) for a in n.args]
        # broadcast all args onto the union var order (output order first)
        union: List[str] = [v for v in op.out_vars]
        for _, axes in vals:
            for v in axes:
                if v not in union:
                    union.append(v)
        used = [v for v in union if any(v in axes for _, axes in vals)]
        bargs = []
        for val, axes in vals:
            if not axes:
                bargs.append(val)
                continue
            perm = [axes.index(v) for v in used if v in axes]
            a = jnp.transpose(val, perm)
            shape = [op.ranges[v] if v in axes else 1 for v in used]
            bargs.append(a.reshape(shape))
        fn = _J_UNARY[n.op] if len(bargs) == 1 and n.op in _J_UNARY else _J_BINARY[n.op]
        res = (fn(*bargs), used)
    cache[key] = res
    return res


def lower_general(op: FlatOp) -> Callable:
    """Elementwise DAGs (assign) and reductions of general DAGs."""
    out_shape = tuple(op.ranges[v] for v in op.out_vars)
    out_dtype = np.dtype(op.out_ref.dtype)
    red_vars = [v for v in sorted(op.ranges) if v not in op.out_vars and op.ranges[v] > 1]
    identity = AGG_IDENTITY.get(op.agg, 0.0)

    def fn(arrays: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        cache: Dict[int, Tuple[jnp.ndarray, List[str]]] = {}
        val, axes = _eval_dag(op.root, arrays, op, cache)
        grid = [v for v in (*op.out_vars, *red_vars)]
        # expand to full grid order
        if axes:
            perm = [axes.index(v) for v in grid if v in axes]
            val = jnp.transpose(val, perm)
            val = val.reshape([op.ranges[v] if v in axes else 1 for v in grid])
            val = jnp.broadcast_to(val, [op.ranges[v] for v in grid])
        else:
            val = jnp.broadcast_to(val, [op.ranges[v] for v in grid])
        mask = _mask_on_grid(op.block.constraints, grid, op.ranges, {})
        if mask is not None:
            val = jnp.where(mask, val, jnp.asarray(identity, val.dtype))
        if red_vars:
            axis = tuple(range(len(op.out_vars), len(grid)))
            red = {"add": jnp.sum, "max": jnp.max, "min": jnp.min, "mul": jnp.prod}[op.agg]
            val = red(val, axis=axis)
        return val.astype(out_dtype)

    return fn


def lower_block_jnp(block: Block) -> Callable[[Mapping[str, jnp.ndarray]], jnp.ndarray]:
    op = analyze_flat(block)
    prod = _product_leaves(op.root)
    if op.agg != "assign" and prod is not None:
        leaves, scale = prod
        return lower_contraction(op, leaves, scale)
    if op.agg != "assign":
        return lower_general(op)
    # assign: no reduction vars allowed (would be a nondeterministic race)
    return lower_general(op)


def _out_region(op: FlatOp, buf_shape: Tuple[int, ...]) -> Tuple[Tuple[int, int], ...]:
    region = []
    vi = 0
    for e in op.out_ref.offsets:
        if e.is_const():
            region.append((e.const, e.const + 1))
        else:
            v = op.out_vars[vi]
            vi += 1
            region.append((0, op.ranges[v]))
    return tuple(region)


class _LazyZeros(dict):
    """Array environment that materializes a zero buffer on first read —
    a fully-overwritten buffer never pays an init dispatch."""

    def __init__(self, base: Mapping, buffers: Mapping):
        super().__init__(base)
        self._buffers = buffers

    def __missing__(self, key):
        d = self._buffers[key]
        v = jnp.zeros(d.shape, np.dtype(d.dtype))
        self[key] = v
        return v


def _group_executor(prog: Program, plans: Mapping[str, Tuple[Block, FlatOp, Callable]],
                    g: Sequence[str], internal: frozenset) -> Callable:
    """One fusion group as an executable unit: fn(arrays) -> updates dict.
    Group-internal intermediates never leave the unit."""

    def group_fn(arrays, g=tuple(g), internal=frozenset(internal)):
        local = _LazyZeros(arrays, prog.buffers)
        updates: Dict[str, jnp.ndarray] = {}
        for name in g:
            blk, op, fn = plans[name]
            val = fn(local)
            if op.agg != "assign" and len(g) > 1 and jax.default_backend() == "cpu":
                # Keep XLA CPU's library gemm: loop-fusing an expensive
                # elementwise epilogue (erf/gelu) into a dot consumer
                # drops the contraction off the fast gemm runtime.  The
                # barrier pins the dot, while the group's elementwise
                # members still fuse with each other.
                val = jax.lax.optimization_barrier(val)
            buf = op.out_ref.from_buf
            full = local.get(buf)
            decl_shape = prog.buffers[buf].shape
            region = _out_region(op, decl_shape)
            out_shape_full = tuple(hi - lo for lo, hi in region)
            val = val.reshape(out_shape_full)
            if out_shape_full == decl_shape:
                if op.agg != "assign" and full is not None:
                    # a previous writer's contribution is in the buffer:
                    # aggregate with it (each lowering computes its own
                    # complete reduction from the identity, so combining
                    # results with the agg op matches the reference's
                    # single accumulating buffer)
                    new = _AGG_JNP[op.agg](full, val.astype(full.dtype))
                else:
                    new = val
            else:
                if full is None:  # partially-written buffer: zero base
                    full = jnp.zeros(decl_shape,
                                     np.dtype(prog.buffers[buf].dtype))
                starts = tuple(lo for lo, _ in region)
                if op.agg != "assign":
                    cur = jax.lax.dynamic_slice(full, starts, out_shape_full)
                    val = _AGG_JNP[op.agg](cur, val.astype(full.dtype))
                new = jax.lax.dynamic_update_slice(
                    full, val.astype(full.dtype), starts)
            local[buf] = new
            if buf not in internal:
                updates[buf] = new
        return updates

    return group_fn


def _group_needed(plans, g: Sequence[str]) -> frozenset:
    """Buffers a group's jit signature must cover: everything it reads or
    writes — passing the whole program environment would add O(total
    buffers) pytree flattening per dispatch."""
    needed = set()
    for n in g:
        blk, op, _fn = plans[n]
        needed.add(op.out_ref.from_buf)
        for r in blk.refs:
            if r.dir in (RefDir.IN, RefDir.INOUT):
                needed.add(r.from_buf)
    return frozenset(needed)


def lower_group_jnp(prog: Program, names: Sequence[str],
                    jit_scope: Optional[str] = "group") -> Callable:
    """Lower the named semantic (frontend-shaped) op blocks as ONE jnp
    compile unit: fn(arrays) -> {buffer: full array} updates.

    This is the per-unit fallback of the hybrid Pallas composer
    (``lower_pallas.lower_program_hybrid``): when one fusion group cannot
    lower to a kernel, only its member ops take the jnp path, jitted as a
    single dispatch, while the rest of the program keeps its kernels."""
    plans: Dict[str, Tuple[Block, FlatOp, Callable]] = {}
    want = set(names)
    for s in prog.entry.stmts:
        if isinstance(s, Block) and s.name in want:
            plans[s.name] = (s, analyze_flat(s), lower_block_jnp(s))
    missing = [n for n in names if n not in plans]
    if missing:
        raise KeyError(f"op blocks {missing} not in program")
    fn = _group_executor(prog, plans, list(names), frozenset())
    if jit_scope in ("op", "group"):
        fn = jax.jit(fn)
    needed = _group_needed(plans, list(names))

    def run(arrays: Mapping[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        return fn({b: arrays[b] for b in needed if b in arrays})

    run.needed = needed
    return run


def lower_program_jnp(prog: Program, groups: Optional[List[List[str]]] = None,
                      jit_scope: Optional[str] = None,
                      profile: bool = False
                      ) -> Callable[[Mapping[str, jnp.ndarray]], Dict[str, jnp.ndarray]]:
    """Lower every op block; returns fn(inputs)->outputs dict.

    ``groups`` switches to **per-group lowering** (fusion groups from the
    pass pipeline): each group of semantic op-block names becomes one
    compiled unit, its internal intermediates stay local to the group
    (never entering the program-level array environment or the returned
    dict), and — with ``jit_scope="group"`` (or ``"op"`` for per-op
    units) — each unit is wrapped in its own ``jax.jit``, so the group is
    the dispatch granularity, mirroring the Pallas backend's
    one-kernel-per-group contract.

    ``profile=True`` wall-times each group dispatch (synchronizing on its
    updates), keeping the best observation per unit in ``run.unit_times``
    keyed by the "+"-joined group member names; callers wanting
    meaningful per-unit times should pair it with ``jit_scope="group"``
    and no outer jit, so dispatch boundaries survive.
    """
    plans: Dict[str, Tuple[Block, FlatOp, Callable]] = {}
    order: List[str] = []
    for s in prog.entry.stmts:
        if not isinstance(s, Block):
            continue
        op = analyze_flat(s)
        fn = lower_block_jnp(s)
        plans[s.name] = (s, op, fn)
        order.append(s.name)

    if groups is None or sorted(n for g in groups for n in g) != sorted(order):
        groups = [[n] for n in order]

    # who reads each buffer, by op-block name (for internal-buffer elision)
    readers: Dict[str, set] = {}
    for name in order:
        for r in plans[name][0].refs:
            if r.dir in (RefDir.IN, RefDir.INOUT):
                readers.setdefault(r.from_buf, set()).add(name)

    elided: set = set()
    group_fns = []
    for g in groups:
        written = {plans[n][1].out_ref.from_buf for n in g}
        internal = {b for b in written
                    if b not in prog.outputs
                    and readers.get(b, set()) <= set(g)
                    and b != plans[g[-1]][1].out_ref.from_buf}
        elided |= internal
        # the group's jit signature covers only what it touches
        needed = _group_needed(plans, g) | set(written)
        group_fn = _group_executor(prog, plans, g, frozenset(internal))
        if jit_scope in ("op", "group"):
            group_fn = jax.jit(group_fn)
        group_fns.append(("+".join(g), group_fn, frozenset(needed)))

    unit_times: Dict[str, float] = {}

    def run(inputs: Mapping[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        # Buffers are materialized lazily: a fully-overwriting producer
        # needs no zero-init dispatch; partially-written buffers start
        # from zeros inside their group.
        arrays: Dict[str, jnp.ndarray] = {
            name: jnp.asarray(inputs[name]) for name in prog.inputs}
        for gname, gfn, needed in group_fns:
            if profile:
                t0 = time.perf_counter()
            updates = gfn({b: arrays[b] for b in needed if b in arrays})
            arrays.update(updates)
            if profile:
                jax.block_until_ready(list(updates.values()))
                dt = time.perf_counter() - t0
                prev = unit_times.get(gname)
                unit_times[gname] = dt if prev is None or dt < prev else prev
        for name, d in prog.buffers.items():
            if name not in arrays and name not in prog.inputs and name not in elided:
                arrays[name] = jnp.zeros(d.shape, np.dtype(d.dtype))
        return {n: arrays[n] for n in prog.buffers
                if n not in prog.inputs and n not in elided}

    run.n_kernels = len(group_fns)
    run.unit_times = unit_times
    return run
