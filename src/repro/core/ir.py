"""The Stripe IR (paper §3.2).

A ``Block`` is a parallel polyhedral block: a polyhedral iteration space
(named indices with ranges + affine constraints), a *single* statement list
(identical across iterations), explicitly declared I/O via ``Refinement``\\ s
(views of parent buffers with per-dimension affine offsets, shapes, strides,
an aggregation op for outputs, and an optional hardware ``Location``), and
free-form ``tags`` carrying pass-to-pass metadata with no semantic meaning.

Statements are: nested ``Block``\\ s, scalar ``Load``/``Store``/``Intrinsic``/
``Constant`` ops, or ``Special`` tensor functions (gather/scatter-like ops
that are inappropriate to express as scalar blocks).

Offsets in a refinement are expressed in the *parent view's* element
coordinates; chains of refinements therefore compose by addition, which is
what makes aliasing analysis tractable (§3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple, Union

from .affine import Affine, aff
from .poly import Constraint, Index, Polyhedron

# --------------------------------------------------------------------------
# Aggregation operations (Def. 2's associative+commutative A_D, plus assign)
# --------------------------------------------------------------------------
AGG_OPS = ("assign", "add", "max", "min", "mul")

AGG_IDENTITY = {"add": 0.0, "max": float("-inf"), "min": float("inf"), "mul": 1.0}


class RefDir:
    NONE = "none"  # allocation only (temporary defined at this level)
    IN = "in"
    OUT = "out"
    INOUT = "inout"


@dataclasses.dataclass(frozen=True)
class Location:
    """Hardware placement of a buffer: memory unit name, optional bank
    (affine in the block indices) and address."""

    unit: str = ""
    bank: Optional[Affine] = None
    addr: Optional[int] = None

    def __str__(self) -> str:
        s = self.unit
        if self.bank is not None:
            s += f"[{self.bank}]"
        if self.addr is not None:
            s += f"@{self.addr:#x}"
        return s


@dataclasses.dataclass
class Refinement:
    dir: str  # RefDir
    from_buf: str  # name in the parent scope ("" => top-level/external)
    into: str  # name visible inside this block
    offsets: Tuple[Affine, ...]  # per-dim start, affine in parent+own idxs
    shape: Tuple[int, ...]  # view extent per dim
    dtype: str = "float32"
    strides: Optional[Tuple[int, ...]] = None  # element strides (layout)
    agg: Optional[str] = None  # aggregation for OUT refinements
    location: Optional[Location] = None
    tags: set = dataclasses.field(default_factory=set)

    def __post_init__(self):
        self.offsets = tuple(aff(o) for o in self.offsets)
        self.shape = tuple(int(s) for s in self.shape)
        if len(self.offsets) != len(self.shape):
            raise ValueError(f"refinement {self.into}: rank mismatch")
        if self.agg is not None and self.agg not in AGG_OPS:
            raise ValueError(f"unknown aggregation '{self.agg}'")

    @property
    def rank(self) -> int:
        return len(self.shape)

    def is_scalar_view(self) -> bool:
        return all(s == 1 for s in self.shape)

    def clone(self, **kw) -> "Refinement":
        out = dataclasses.replace(self)
        out.tags = set(self.tags)
        for k, v in kw.items():
            setattr(out, k, v)
        return out

    def __str__(self) -> str:
        off = ", ".join(str(o) for o in self.offsets)
        shp = ", ".join(str(s) for s in self.shape)
        s = f"{self.dir} {self.into}[{off}] {self.dtype}({shp})"
        if self.strides:
            s += ":(" + ", ".join(str(x) for x in self.strides) + ")"
        if self.agg:
            s += f":{self.agg}"
        if self.location:
            s += f" @{self.location}"
        if self.from_buf and self.from_buf != self.into:
            s += f" <- {self.from_buf}"
        return s


# --------------------------------------------------------------------------
# Statements
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Load:
    """``into = load(buf)`` — reads the element the refinement points at
    (requires a scalar view) into a block-local scalar."""

    buf: str
    into: str

    def __str__(self) -> str:
        return f"${self.into} = load({self.buf})"


@dataclasses.dataclass
class Store:
    """``store(buf, scalar)`` — writes/aggregates a scalar into the element
    the refinement points at."""

    buf: str
    scalar: str

    def __str__(self) -> str:
        return f"{self.buf} = store(${self.scalar})"


@dataclasses.dataclass
class Intrinsic:
    """Scalar computation: ``into = op(args...)``."""

    op: str
    args: Tuple[str, ...]
    into: str

    def __str__(self) -> str:
        return f"${self.into} = {self.op}(" + ", ".join(f"${a}" for a in self.args) + ")"


@dataclasses.dataclass
class Constant:
    value: float
    into: str

    def __str__(self) -> str:
        return f"${self.into} = {self.value}"


@dataclasses.dataclass
class Special:
    """Complex tensor op on whole refinements (gather/scatter/...)."""

    op: str
    ins: Tuple[str, ...]
    outs: Tuple[str, ...]
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    def __str__(self) -> str:
        return f"{','.join(self.outs)} = special.{self.op}({', '.join(self.ins)})"


Statement = Union["Block", Load, Store, Intrinsic, Constant, Special]


# --------------------------------------------------------------------------
# Block
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Block:
    name: str
    idxs: List[Index] = dataclasses.field(default_factory=list)
    constraints: List[Constraint] = dataclasses.field(default_factory=list)
    refs: List[Refinement] = dataclasses.field(default_factory=list)
    stmts: List[Statement] = dataclasses.field(default_factory=list)
    tags: set = dataclasses.field(default_factory=set)
    comments: str = ""
    # Parent indices explicitly passed into this block (paper §3.2:
    # "requiring any parent index used to be explicitly passed").
    passed: List[str] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------- queries
    @property
    def poly(self) -> Polyhedron:
        return Polyhedron(self.idxs, self.constraints)

    def ref(self, name: str) -> Refinement:
        for r in self.refs:
            if r.into == name:
                return r
        raise KeyError(f"block {self.name}: no refinement '{name}'")

    def has_ref(self, name: str) -> bool:
        return any(r.into == name for r in self.refs)

    def idx(self, name: str) -> Index:
        for i in self.idxs:
            if i.name == name:
                return i
        raise KeyError(f"block {self.name}: no index '{name}'")

    def idx_ranges(self) -> Dict[str, int]:
        return {i.name: i.range for i in self.idxs if not i.is_passthrough()}

    def sub_blocks(self) -> List["Block"]:
        return [s for s in self.stmts if isinstance(s, Block)]

    def walk(self) -> Iterator["Block"]:
        yield self
        for s in self.stmts:
            if isinstance(s, Block):
                yield from s.walk()

    def depth(self) -> int:
        subs = self.sub_blocks()
        return 1 + (max(b.depth() for b in subs) if subs else 0)

    # ----------------------------------------------------------- mutation
    def clone(self, deep: bool = True) -> "Block":
        import copy

        return copy.deepcopy(self) if deep else dataclasses.replace(self)

    def add_tag(self, *tags: str) -> "Block":
        self.tags.update(tags)
        return self

    # ------------------------------------------------------------ display
    def pretty(self, indent: int = 0) -> str:
        pad = "  " * indent
        hdr = f"{pad}block"
        if self.name:
            hdr += f" <{self.name}>"
        if self.tags:
            hdr += " #" + " #".join(sorted(self.tags))
        hdr += " [" + ", ".join(str(i) for i in self.idxs) + "]"
        lines = [hdr + " ("]
        for c in self.constraints:
            lines.append(f"{pad}    {c}")
        for r in self.refs:
            lines.append(f"{pad}    {r}")
        lines.append(f"{pad}) {{")
        for n, s in enumerate(self.stmts):
            if isinstance(s, Block):
                body = s.pretty(indent + 1)
                body = body[: len(pad) + 2] + f"{n}: " + body[len(pad) + 2 :]
                lines.append(body)
            else:
                lines.append(f"{pad}  {n}: {s}")
        lines.append(pad + "}")
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.pretty()


# --------------------------------------------------------------------------
# Program: top-level buffer declarations + entry block
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TensorDecl:
    name: str
    shape: Tuple[int, ...]
    dtype: str = "float32"

    @property
    def rank(self) -> int:
        return len(self.shape)

    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass
class Program:
    """A list of top-level parallel polyhedral blocks over declared buffers
    (the paper: 'a network can be represented as a list of polyhedra')."""

    buffers: Dict[str, TensorDecl]
    entry: Block  # entry.stmts is the top-level list of op blocks
    inputs: List[str] = dataclasses.field(default_factory=list)
    outputs: List[str] = dataclasses.field(default_factory=list)
    # Pristine pre-optimization program (kept by the pass manager): the jnp
    # reference backend lowers from this semantic form, the Pallas backend
    # from the optimized form.
    source: Optional["Program"] = None

    def decl(self, name: str) -> TensorDecl:
        return self.buffers[name]

    def pretty(self) -> str:
        lines = [
            f"program (in: {', '.join(self.inputs)}; out: {', '.join(self.outputs)})"
        ]
        for b in self.buffers.values():
            lines.append(f"  buffer {b.name} {b.dtype}({', '.join(map(str, b.shape))})")
        lines.append(self.entry.pretty())
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.pretty()


# --------------------------------------------------------------------------
# Access composition
# --------------------------------------------------------------------------
def compose_access(chain: Sequence[Refinement]) -> Tuple[Affine, ...]:
    """Absolute per-dim offsets of the innermost refinement w.r.t. the root
    buffer: refinement offsets compose by addition (same rank throughout)."""
    if not chain:
        raise ValueError("empty refinement chain")
    rank = chain[0].rank
    total = [aff(0)] * rank
    for r in chain:
        if r.rank != rank:
            raise ValueError("rank change along refinement chain")
        total = [t + o for t, o in zip(total, r.offsets)]
    return tuple(total)


def row_major_strides(shape: Sequence[int]) -> Tuple[int, ...]:
    strides = [1] * len(shape)
    for d in range(len(shape) - 2, -1, -1):
        strides[d] = strides[d + 1] * shape[d + 1]
    return tuple(strides)


DTYPE_BYTES = {
    "float32": 4, "bfloat16": 2, "float16": 2, "float64": 8,
    "int8": 1, "uint8": 1, "int16": 2, "int32": 4, "int64": 8, "bool": 1,
}


def dtype_bytes(dtype: str) -> int:
    return DTYPE_BYTES[dtype]


# --------------------------------------------------------------------------
# Stable content hashing (compilation-cache keys)
# --------------------------------------------------------------------------
def _canon_ref(r: Refinement):
    return [
        "ref", r.dir, r.from_buf, r.into,
        [str(o) for o in r.offsets], list(r.shape), r.dtype,
        list(r.strides) if r.strides else None, r.agg,
        str(r.location) if r.location else None, sorted(r.tags),
    ]


def _canon_stmt(s: Statement):
    if isinstance(s, Block):
        return _canon_block(s)
    if isinstance(s, Load):
        return ["load", s.buf, s.into]
    if isinstance(s, Store):
        return ["store", s.buf, s.scalar]
    if isinstance(s, Intrinsic):
        return ["intr", s.op, list(s.args), s.into]
    if isinstance(s, Constant):
        return ["const", repr(s.value), s.into]
    if isinstance(s, Special):
        return ["special", s.op, list(s.ins), list(s.outs),
                sorted((k, str(v)) for k, v in s.attrs.items())]
    raise TypeError(f"unknown statement {s!r}")


def _canon_block(b: Block):
    # ``comments`` is excluded: free-form notes carry no semantics.
    return [
        "block", b.name,
        [[i.name, i.range, str(i.affine) if i.affine is not None else None] for i in b.idxs],
        [str(c.expr) for c in b.constraints],
        [_canon_ref(r) for r in b.refs],
        sorted(b.tags), list(b.passed),
        [_canon_stmt(s) for s in b.stmts],
    ]


def canonical_ir(obj: Union[Program, Block]):
    """Canonical (JSON-able) form of a program or block: deterministic
    across processes and insensitive to non-semantic state — tag/set
    insertion order, buffer-dict insertion order, comments, and the
    pristine ``source`` back-pointer."""
    if isinstance(obj, Block):
        return _canon_block(obj)
    return [
        "program",
        sorted([d.name, list(d.shape), d.dtype] for d in obj.buffers.values()),
        list(obj.inputs), list(obj.outputs),
        _canon_block(obj.entry),
    ]


def ir_fingerprint(obj: Union[Program, Block]) -> str:
    """sha256 content hash of :func:`canonical_ir` — the IR component of a
    compilation-cache key."""
    from .cache import stable_hash

    return stable_hash(canonical_ir(obj))
