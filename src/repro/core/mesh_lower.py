"""Multi-device emission: play a :class:`~repro.core.shardplan.ShardPlan`
inside ``shard_map``.

The driver compiles each of the plan's segments with the ordinary
single-device ``stripe_jit`` pipeline (per-block hybrid Pallas/jnp
composer, cache, tuning DB — everything), then :func:`emit` stitches
the compiled segments together with the plan's explicit collectives:

* ``halo`` — a ``ppermute`` pair moving each shard's boundary slabs to
  its neighbors, concatenated as padding.  The permutation is
  deliberately *not* cyclic: ranks that receive nothing are zero-filled
  by ``ppermute``, which is exactly the boundary masking the dropped
  frontend constraints used to provide.
* ``psum`` / ``all_gather`` — reduction-split partials and sharded
  program outputs.
* ``slice`` — localize a replicated buffer to this shard (no traffic).
* ``ring`` — ``parallel.collective_matmul``'s reduce-scatter matmul,
  the overlap primitive the cost model chose over a plain psum.

Execution always runs on a **flat 1-D mesh** (one ring axis over all
devices); a multi-dim mesh *shape* changes only the cost model's link
bandwidth, not the emitted program.  ``count_collectives`` /
``expected_primitive_counts`` close the loop: tests and the bench leg
assert that the collectives the plan predicted are the collectives the
jaxpr actually contains.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Tuple

from .shardplan import Segment, ShardPlan

_COLLECTIVE_PRIMS = ("psum", "all_gather", "ppermute", "all_to_all",
                     "reduce_scatter")


def resolve_mesh(mesh):
    """Normalize a ``mesh=`` argument (device count, mesh shape tuple,
    or ``jax.sharding.Mesh``) to ``(flat 1-D Mesh, axis name, model
    shape)``.  Returns ``None`` for a trivial (size-1 or ``None``)
    mesh — the caller should compile single-device."""
    if mesh is None:
        return None
    import jax
    import numpy as np
    from jax.sharding import Mesh

    if isinstance(mesh, Mesh):
        shape = tuple(int(s) for s in mesh.devices.shape)
        devs = np.asarray(mesh.devices).reshape(-1)
        if devs.size <= 1:
            return None
        axis = mesh.axis_names[0] if len(mesh.axis_names) == 1 else "x"
        return Mesh(devs, (axis,)), str(axis), shape
    shape = (int(mesh),) if isinstance(mesh, int) else tuple(int(s) for s in mesh)
    n = 1
    for s in shape:
        n *= s
    if n <= 1:
        return None
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(
            f"mesh {shape} needs {n} devices; only {len(devs)} available "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N for "
            "emulated host devices)")
    return Mesh(np.array(devs[:n]), ("x",)), "x", shape


def _halo_pad(x, dim: int, lo: int, hi: int, axis: str, n: int):
    import jax
    import jax.numpy as jnp

    parts = []
    if lo:
        tail = jax.lax.slice_in_dim(x, x.shape[dim] - lo, x.shape[dim],
                                    axis=dim)
        parts.append(jax.lax.ppermute(
            tail, axis, [(i, i + 1) for i in range(n - 1)]))
    parts.append(x)
    if hi:
        head = jax.lax.slice_in_dim(x, 0, hi, axis=dim)
        parts.append(jax.lax.ppermute(
            head, axis, [(i + 1, i) for i in range(n - 1)]))
    return jnp.concatenate(parts, axis=dim)


def emit(prog, plan: ShardPlan, segments: List[Segment], compiled: List,
         jmesh, axis: str, jit: bool = True):
    """Build the whole-program callable: ``shard_map`` over the plan's
    emission script, inner segments already compiled.  Takes and returns
    global (unsharded) arrays keyed like the single-device driver."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n = plan.n
    in_order = list(prog.inputs)
    out_order = list(prog.outputs)
    in_specs = []
    for name in in_order:
        d = plan.in_specs.get(name, -1)
        rank = len(prog.buffers[name].shape)
        in_specs.append(
            P(*[axis if i == d else None for i in range(rank)])
            if d >= 0 else P())

    def body(*args):
        env = dict(zip(in_order, args))
        for step in plan.steps:
            kind = step[0]
            if kind == "segment":
                seg = segments[step[1]]
                outs = compiled[step[1]]({k: env[k] for k in seg.inputs})
                env.update(outs)
            elif kind == "halo":
                _, buf, dim, lo, hi = step
                env[buf] = _halo_pad(env[buf], dim, lo, hi, axis, n)
            elif kind == "gather":
                _, buf, dim = step
                env[buf] = jax.lax.all_gather(env[buf], axis, axis=dim,
                                              tiled=True)
            elif kind == "slice":
                _, buf, dim, size = step
                i = jax.lax.axis_index(axis)
                env[buf] = jax.lax.dynamic_slice_in_dim(
                    env[buf], i * size, size, axis=dim)
            elif kind == "psum":
                env[step[1]] = jax.lax.psum(env[step[1]], axis)
            elif kind == "ring":
                from ..parallel.collective_matmul import (
                    ring_matmul_reduce_scatter,
                )

                info = step[2]
                acc = ring_matmul_reduce_scatter(
                    env[info["x"]], env[info["w"]], axis)
                full = jax.lax.all_gather(acc, axis, axis=1, tiled=True)
                env[info["out"]] = full.astype(info["out_dtype"])
            else:
                raise ValueError(f"unknown plan step {step!r}")
        return tuple(env[o] for o in out_order)

    sharded = shard_map(body, mesh=jmesh, in_specs=tuple(in_specs),
                        out_specs=tuple(P() for _ in out_order),
                        check_rep=False)
    if jit:
        sharded = jax.jit(sharded)

    def call(arrays: Mapping[str, Any]) -> Dict[str, Any]:
        outs = sharded(*[jnp.asarray(arrays[k]) for k in in_order])
        return dict(zip(out_order, outs))

    call._sharded = sharded
    call._in_order = in_order
    return call


# --------------------------------------------------------------------------
# predicted-vs-emitted collective accounting
# --------------------------------------------------------------------------
def _count_jaxpr(jaxpr, counts: Dict[str, int]) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if any(name.startswith(p) for p in _COLLECTIVE_PRIMS):
            counts[name] = counts.get(name, 0) + 1
        for v in eqn.params.values():
            _walk(v, counts)


def _walk(v, counts: Dict[str, int]) -> None:
    if hasattr(v, "eqns"):           # raw Jaxpr (e.g. shard_map's param)
        _count_jaxpr(v, counts)
    elif hasattr(v, "jaxpr"):        # ClosedJaxpr
        _count_jaxpr(v.jaxpr, counts)
    elif isinstance(v, (list, tuple)):
        for x in v:
            _walk(x, counts)


def count_collectives(fn, arrays: Mapping[str, Any]) -> Dict[str, int]:
    """Static collective-primitive counts in ``fn``'s jaxpr (recursing
    through shard_map / scan / jit sub-jaxprs).  ``fn`` may be the
    dict-calling convention returned by :func:`emit` (or the driver) or
    any positional callable."""
    import jax
    import jax.numpy as jnp

    fn = getattr(fn, "_fn", fn)  # unwrap the driver's CompiledProgram
    target = getattr(fn, "_sharded", None)
    if target is not None:
        order = fn._in_order
        jaxpr = jax.make_jaxpr(target)(
            *[jnp.asarray(arrays[k]) for k in order])
    else:
        jaxpr = jax.make_jaxpr(fn)(*arrays.values())
    counts: Dict[str, int] = {}
    _count_jaxpr(jaxpr.jaxpr, counts)
    return counts


def expected_primitive_counts(plan: ShardPlan) -> Dict[str, int]:
    """The static primitive counts :func:`emit` produces for ``plan`` —
    what :func:`count_collectives` must report back.  A halo step is one
    ppermute per nonzero margin; a ring step is one ppermute (inside the
    fori_loop body — static count, n dynamic trips) plus the epilogue
    all-gather."""
    counts: Dict[str, int] = {}

    def add(k: str, m: int = 1):
        if m:
            counts[k] = counts.get(k, 0) + m

    for step in plan.steps:
        kind = step[0]
        if kind == "halo":
            _, _, _, lo, hi = step
            add("ppermute", (1 if lo else 0) + (1 if hi else 0))
        elif kind == "gather":
            add("all_gather")
        elif kind == "psum":
            add("psum")
        elif kind == "ring":
            add("ppermute")
            add("all_gather")
    return counts


def expected_primitive_counts_from_record(mesh_info: Mapping[str, Any]) -> Dict[str, int]:
    """Same accounting as :func:`expected_primitive_counts`, but from the
    ``CompileRecord.mesh`` provenance dict (JSON round-trippable) — so a
    cached or persisted record can still be checked against a jaxpr."""
    counts: Dict[str, int] = {}

    def add(k: str, m: int = 1):
        if m:
            counts[k] = counts.get(k, 0) + m

    for c in mesh_info.get("collectives", ()):
        op = c["collective"]
        if op == "halo":
            add("ppermute", (1 if c.get("lo") else 0) + (1 if c.get("hi") else 0))
        elif op == "ring_matmul":
            add("ppermute")
            add("all_gather")
        else:
            add(op)
    return counts
