"""Tile-like frontend: mathematical tensor expressions -> Stripe blocks.

PlaidML lowers its Tile language ("Einstein notation with aggregations")
into flat Stripe blocks; optimization passes then restructure them.  This
module provides the same entry point:

    tp = TileProgram("conv")
    tp.input("I", (12, 16, 8), "int8")
    tp.input("F", (3, 3, 8, 16), "int8")
    tp.output("O", (12, 16, 16), "int8")
    tp.op("O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]")
    prog = tp.build()

Index ranges are inferred from tensor shapes where an index appears alone
(Tile-style); remaining ranges are given explicitly.  Accesses that can
step out of bounds get boundary ("halo") constraints, exactly as in the
paper's Fig. 5.

Aggregations: ``+=`` (add), ``max=``, ``min=``, ``*=`` (mul) over a product
of tensor accesses; ``=`` defines an elementwise/assign op whose right-hand
side may be any expression DAG of accesses, scalars, and intrinsics.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .affine import Affine, aff
from .ir import (
    Block,
    Constant,
    Constraint,
    Intrinsic,
    Load,
    Program,
    RefDir,
    Refinement,
    Store,
    TensorDecl,
    row_major_strides,
)
from .poly import Index

_AGG_TOKEN = {"+=": "add", "max=": "max", "min=": "min", "*=": "mul", "=": "assign"}

INTRINSICS = {
    "add", "sub", "mul", "div", "neg", "exp", "log", "tanh", "sqrt", "rsqrt",
    "sigmoid", "relu", "abs", "max", "min", "square", "cast", "erf", "gelu",
    "silu", "sign", "floor",
}


# --------------------------------------------------------------------------
# Access parsing
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Access:
    tensor: str
    exprs: Tuple[Affine, ...]


def _parse_affine(node: ast.expr) -> Affine:
    if isinstance(node, ast.Name):
        return Affine.var(node.id)
    if isinstance(node, ast.Constant):
        if not isinstance(node.value, int):
            raise ValueError(f"non-integer constant in index expr: {node.value!r}")
        return aff(node.value)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -_parse_affine(node.operand)
    if isinstance(node, ast.BinOp):
        if isinstance(node.op, ast.Add):
            return _parse_affine(node.left) + _parse_affine(node.right)
        if isinstance(node.op, ast.Sub):
            return _parse_affine(node.left) - _parse_affine(node.right)
        if isinstance(node.op, ast.Mult):
            l, r = _parse_affine(node.left), _parse_affine(node.right)
            if l.is_const():
                return r * l.const
            if r.is_const():
                return l * r.const
            raise ValueError("non-affine index expression (var*var)")
        if isinstance(node.op, ast.FloorDiv):
            raise ValueError("floor division is not affine in Stripe accesses")
    raise ValueError(f"unsupported index expression: {ast.dump(node)}")


def _parse_access(node: ast.Subscript) -> Access:
    if not isinstance(node.value, ast.Name):
        raise ValueError("access base must be a tensor name")
    sl = node.slice
    elts = sl.elts if isinstance(sl, ast.Tuple) else [sl]
    return Access(node.value.id, tuple(_parse_affine(e) for e in elts))


# --------------------------------------------------------------------------
# Expression DAG (for elementwise ops)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ENode:
    kind: str  # 'access' | 'const' | 'op'
    access: Optional[Access] = None
    value: Optional[float] = None
    op: Optional[str] = None
    args: Tuple["ENode", ...] = ()


_BINOP = {ast.Add: "add", ast.Sub: "sub", ast.Mult: "mul", ast.Div: "div", ast.Pow: "pow"}


def _parse_enode(node: ast.expr) -> ENode:
    if isinstance(node, ast.Subscript):
        return ENode("access", access=_parse_access(node))
    if isinstance(node, ast.Constant):
        return ENode("const", value=float(node.value))
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return ENode("op", op="neg", args=(_parse_enode(node.operand),))
    if isinstance(node, ast.BinOp) and type(node.op) in _BINOP:
        return ENode("op", op=_BINOP[type(node.op)], args=(_parse_enode(node.left), _parse_enode(node.right)))
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        fn = node.func.id
        if fn not in INTRINSICS:
            raise ValueError(f"unknown intrinsic '{fn}'")
        return ENode("op", op=fn, args=tuple(_parse_enode(a) for a in node.args))
    raise ValueError(f"unsupported expression: {ast.dump(node)}")


def _flatten_product(n: ENode) -> Optional[List[Access]]:
    """If the DAG is a pure product of accesses, return them; else None."""
    if n.kind == "access":
        return [n.access]
    if n.kind == "op" and n.op == "mul":
        parts = []
        for a in n.args:
            sub = _flatten_product(a)
            if sub is None:
                return None
            parts.extend(sub)
        return parts
    return None


def _walk_accesses(n: ENode):
    if n.kind == "access":
        yield n.access
    for a in n.args:
        yield from _walk_accesses(a)


# --------------------------------------------------------------------------
# Op statement
# --------------------------------------------------------------------------
@dataclasses.dataclass
class OpSpec:
    name: str
    out: Access
    agg: str
    rhs: ENode
    ranges: Dict[str, int]  # resolved index ranges
    constraints: List[Constraint]


def _infer(op_text: str, decls: Mapping[str, TensorDecl], explicit: Mapping[str, int]) -> OpSpec:
    m = re.match(r"^(.*?)\s*(\+=|max=|min=|\*=|=)\s*(.*)$", op_text.strip(), re.S)
    if not m:
        raise ValueError(f"cannot parse op: {op_text!r}")
    lhs_text, agg_tok, rhs_text = m.groups()
    agg = _AGG_TOKEN[agg_tok]
    lhs = ast.parse(lhs_text.strip(), mode="eval").body
    if not isinstance(lhs, ast.Subscript):
        raise ValueError("left-hand side must be a tensor access")
    out = _parse_access(lhs)
    rhs = _parse_enode(ast.parse(rhs_text.strip(), mode="eval").body)

    for a in (out, *(list(_walk_accesses(rhs)))):
        if a.tensor not in decls:
            raise ValueError(f"unknown tensor '{a.tensor}' in {op_text!r}")
        if len(a.exprs) != decls[a.tensor].rank:
            raise ValueError(f"rank mismatch accessing '{a.tensor}'")

    # Output accesses must be plain distinct indices (frontend restriction).
    out_vars: List[str] = []
    for e in out.exprs:
        if len(e.terms) != 1 or e.const != 0 or e.terms[0][1] != 1:
            raise ValueError(f"output access must be a plain index, got {e}")
        out_vars.append(e.terms[0][0])
    if len(set(out_vars)) != len(out_vars):
        raise ValueError("repeated index in output access")

    # ---- range inference: idx alone in a dim => bounded by that dim ------
    ranges: Dict[str, int] = dict(explicit)
    all_accesses = [out] + list(_walk_accesses(rhs))
    for acc in all_accesses:
        shape = decls[acc.tensor].shape
        for e, size in zip(acc.exprs, shape):
            if len(e.terms) == 1:
                (n, c), k = e.terms[0], e.const
                if n in explicit:
                    continue
                if c > 0:
                    bound = (size - 1 - k) // c + 1
                    ranges[n] = min(ranges.get(n, bound), bound)
    missing = set()
    for acc in all_accesses:
        for e in acc.exprs:
            for n in e.names():
                if n not in ranges:
                    missing.add(n)
    if missing:
        raise ValueError(f"cannot infer ranges for {sorted(missing)}; pass ranges=")

    # ---- halo constraints for accesses that can step out of bounds -------
    from .poly import Polyhedron

    poly = Polyhedron([Index(n, r) for n, r in ranges.items()])
    constraints: List[Constraint] = []
    seen = set()
    for acc in all_accesses:
        shape = decls[acc.tensor].shape
        for e, size in zip(acc.exprs, shape):
            if e.is_const():
                if not (0 <= e.const < size):
                    raise ValueError(f"constant access {e} out of bounds for {acc.tensor}")
                continue
            lo, hi = poly.expr_bounds(e)
            if lo < 0 and (key := ("lo", str(e))) not in seen:
                seen.add(key)
                constraints.append(Constraint(e))
            if hi > size - 1 and (key := ("hi", str(e))) not in seen:
                seen.add(key)
                constraints.append(Constraint(aff(size - 1) - e))

    return OpSpec(name="", out=out, agg=agg, rhs=rhs, ranges=ranges, constraints=constraints)


# --------------------------------------------------------------------------
# Lowering an OpSpec to a flat Stripe block (paper Fig. 5a shape)
# --------------------------------------------------------------------------
def lower_op_to_block(spec: OpSpec, decls: Mapping[str, TensorDecl], name: str) -> Block:
    idxs = [Index(n, r) for n, r in sorted(spec.ranges.items())]
    blk = Block(name=name, idxs=idxs, constraints=list(spec.constraints), tags={"contraction" if spec.agg != "assign" else "elementwise", "frontend"})

    # Refinements: one scalar view per distinct access.
    scalars: Dict[int, str] = {}
    load_names: Dict[str, str] = {}  # key: tensor+exprs string -> local name
    counter = [0]

    def add_input(acc: Access) -> str:
        key = acc.tensor + "[" + ",".join(map(str, acc.exprs)) + "]"
        if key in load_names:
            return load_names[key]
        local = acc.tensor if not blk.has_ref(acc.tensor) else f"{acc.tensor}_{counter[0]}"
        counter[0] += 1
        d = decls[acc.tensor]
        blk.refs.append(
            Refinement(
                dir=RefDir.IN, from_buf=acc.tensor, into=local,
                offsets=acc.exprs, shape=(1,) * d.rank, dtype=d.dtype,
                strides=row_major_strides(d.shape),
            )
        )
        sc = f"s{len(load_names)}"
        blk.stmts.append(Load(local, sc))
        load_names[key] = sc
        return sc

    def emit(n: ENode) -> str:
        if n.kind == "access":
            return add_input(n.access)
        if n.kind == "const":
            sc = f"c{counter[0]}"
            counter[0] += 1
            blk.stmts.append(Constant(n.value, sc))
            return sc
        args = tuple(emit(a) for a in n.args)
        sc = f"t{counter[0]}"
        counter[0] += 1
        blk.stmts.append(Intrinsic(n.op, args, sc))
        return sc

    result = emit(spec.rhs)

    od = decls[spec.out.tensor]
    blk.refs.append(
        Refinement(
            dir=RefDir.OUT, from_buf=spec.out.tensor, into=spec.out.tensor + "_out",
            offsets=spec.out.exprs, shape=(1,) * od.rank, dtype=od.dtype,
            strides=row_major_strides(od.shape), agg=spec.agg,
        )
    )
    blk.stmts.append(Store(spec.out.tensor + "_out", result))
    return blk


# --------------------------------------------------------------------------
# TileProgram builder
# --------------------------------------------------------------------------
class TileProgram:
    def __init__(self, name: str = "main"):
        self.name = name
        self.decls: Dict[str, TensorDecl] = {}
        self.inputs: List[str] = []
        self.outputs: List[str] = []
        self.ops: List[Tuple[str, OpSpec]] = []

    def input(self, name: str, shape: Sequence[int], dtype: str = "float32") -> str:
        self.decls[name] = TensorDecl(name, tuple(shape), dtype)
        self.inputs.append(name)
        return name

    def output(self, name: str, shape: Sequence[int], dtype: str = "float32") -> str:
        self.decls[name] = TensorDecl(name, tuple(shape), dtype)
        self.outputs.append(name)
        return name

    def temp(self, name: str, shape: Sequence[int], dtype: str = "float32") -> str:
        self.decls[name] = TensorDecl(name, tuple(shape), dtype)
        return name

    def op(self, text: str, ranges: Mapping[str, int] | None = None, name: str = "") -> "TileProgram":
        spec = _infer(text, self.decls, ranges or {})
        self.ops.append((name or f"op{len(self.ops)}", spec))
        return self

    def build(self) -> Program:
        entry = Block(name=self.name, tags={"main"})
        for n, d in self.decls.items():
            # temps are INOUT at program scope: real storage shared between
            # the op blocks (iteration-local temporaries use RefDir.NONE)
            dir_ = RefDir.IN if n in self.inputs else (RefDir.OUT if n in self.outputs else RefDir.INOUT)
            entry.refs.append(
                Refinement(
                    dir=dir_,
                    from_buf=n, into=n, offsets=(aff(0),) * d.rank,
                    shape=d.shape, dtype=d.dtype, strides=row_major_strides(d.shape),
                )
            )
        for opname, spec in self.ops:
            entry.stmts.append(lower_op_to_block(spec, self.decls, opname))
        return Program(buffers=dict(self.decls), entry=entry, inputs=list(self.inputs), outputs=list(self.outputs))


def single_op_program(text: str, tensors: Mapping[str, Tuple[Sequence[int], str]], out: str, ranges: Mapping[str, int] | None = None, name: str = "op") -> Program:
    """Convenience: one-op program. ``tensors`` maps name->(shape,dtype)."""
    tp = TileProgram(name)
    for n, (shape, dtype) in tensors.items():
        if n == out:
            tp.output(n, shape, dtype)
        else:
            tp.input(n, shape, dtype)
    tp.op(text, ranges)
    return tp.build()
