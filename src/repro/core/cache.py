"""Two-level compilation cache.

Stripe's pitch is that *compilation* is the unit of reuse, which only
works if compiling is cheap enough for the serving hot path.  Following
Tensor Comprehensions' compilation-cache design, this module provides:

* an **in-memory LRU** holding live compiled artifacts (optimized
  programs, lowered callables) keyed by a content hash, and
* an **on-disk store** (``$STRIPE_CACHE_DIR`` or ``~/.cache/stripe-repro``)
  persisting the JSON-serializable part of a compile — chosen tilings and
  the pass trace — across processes, so a warm process skips the autotile
  search entirely.

Keys are content hashes (sha256 over a canonical JSON form), never object
identities, so equal programs hash equal across processes.  Disk entries
are versioned and self-identifying; corrupt or stale entries are deleted
and treated as misses.  All levels expose hit/miss/evict statistics.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import time
from collections import OrderedDict
from pathlib import Path
from typing import Any, Callable, Dict, Optional

from ..obs import metrics as obs_metrics
from ..reliability import faults

# v2: tiling-oracle entries are keyed by block name + group fingerprint
# (fusion-group tilings replay as a unit); v1 name-keyed payloads are
# invalidated wholesale by the version bump.
# v3: pass traces carry the memory-planner arenas (arena/arena_bump,
# wavefront levels) and pipelined per-block latencies — pre-planner
# payloads would score on the legacy model, so they are invalidated.
# v4: the roofline model charges halo materialization/refetch traffic
# (TileCost.halo_bytes), so tilings chosen for halo-windowed blocks
# under v3 can differ; payloads also carry per-unit hybrid backends.
# v5: measured-feedback autotuning — compile keys additionally fold in
# the tuned-entry candidate id and the active cost-model calibration
# fingerprint (tuned/calibrated artifacts must never collide with
# analytic ones), and payloads record the decision source.
CACHE_VERSION = 5

ENV_CACHE_DIR = "STRIPE_CACHE_DIR"
ENV_CACHE_DISABLE = "STRIPE_CACHE_DISABLE"


# --------------------------------------------------------------------------
# Content hashing
# --------------------------------------------------------------------------
def stable_json(obj: Any) -> str:
    """Deterministic JSON: sorted keys, no whitespace, tuples as lists.
    Non-JSON values fall back to ``str()`` (hashing, not round-tripping)."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"), default=str)


def stable_hash(obj: Any) -> str:
    return hashlib.sha256(stable_json(obj).encode()).hexdigest()


def content_key(*parts: Any) -> str:
    """Cache key from heterogeneous parts (fingerprints, params, names)."""
    return stable_hash(list(parts))


def default_cache_dir() -> Path:
    env = os.environ.get(ENV_CACHE_DIR)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "stripe-repro"


# --------------------------------------------------------------------------
# Stats
# --------------------------------------------------------------------------
class CacheStats:
    """Cache hit/miss/eviction statistics, backed by an
    :class:`repro.obs.metrics.Registry`.

    Keeps the original dataclass-of-ints surface (``stats.hits += 1``,
    ``as_dict()``) so every existing call site and test works unchanged,
    while each field is a ``cache.<field>`` counter series in a
    per-instance registry (``stats.registry.snapshot()``).  Increments
    are additionally mirrored into the process-default registry, so a
    global ``obs.metrics.snapshot()`` sees cumulative cache traffic
    across every cache in the process.
    """

    FIELDS = (
        "hits", "misses", "evictions", "puts",
        "disk_hits", "disk_misses", "disk_errors", "disk_puts",
        # negative-cache (quarantine) traffic: failures recorded, lookups
        # served degraded because an embargo was active, embargo expiries
        # (retry allowed again), and successful recoveries
        "quarantined", "quarantine_hits", "quarantine_expiries",
        "quarantine_clears",
        # measured-feedback tuning DB consultations by the driver: a hit
        # replays a measured-best tiling (decision source "tuned"), a
        # miss falls through to the analytic autotile search
        "tuned_hits", "tuned_misses",
    )

    def __init__(self, registry: Optional["obs_metrics.Registry"] = None, **initial):
        reg = registry if registry is not None else obs_metrics.Registry()
        object.__setattr__(self, "registry", reg)
        object.__setattr__(self, "_counters",
                           {f: reg.counter(f"cache.{f}") for f in self.FIELDS})
        for k, v in initial.items():
            setattr(self, k, v)

    def __getattr__(self, name):
        counters = self.__dict__.get("_counters") or {}
        if name in counters:
            return int(counters[name].value)
        raise AttributeError(name)

    def __setattr__(self, name, value):
        counters = self.__dict__.get("_counters")
        if counters is not None and name in counters:
            delta = int(value) - int(counters[name].value)
            counters[name].set(int(value))
            if delta:
                obs_metrics.counter(f"cache.{name}").inc(delta)
            return
        object.__setattr__(self, name, value)

    def as_dict(self) -> Dict[str, int]:
        return {f: int(self._counters[f].value) for f in self.FIELDS}

    def __repr__(self) -> str:
        inner = ", ".join(f"{k}={v}" for k, v in self.as_dict().items())
        return f"CacheStats({inner})"


# --------------------------------------------------------------------------
# Compile-failure quarantine (negative cache with exponential backoff)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class QuarantineEntry:
    """One quarantined compile key: why it failed, how often, and until
    when re-attempts are embargoed (``time.monotonic`` deadline)."""

    key: str
    reason: str
    fail_count: int
    backoff_s: float
    until: float
    expired: bool = False  # the embargo lapsed; a retry is permitted

    def as_dict(self) -> Dict[str, Any]:
        return {"key": self.key, "reason": self.reason,
                "fail_count": self.fail_count,
                "backoff_s": round(self.backoff_s, 4),
                "expired": self.expired}


class QuarantineStore:
    """Negative cache over compile keys: a (program, config) point whose
    compile crashed is embargoed with exponential backoff so the serving
    hot path does not re-attempt it every step; while embargoed, lookups
    take the degraded (jnp fallback) path.  Expiry permits exactly one
    retry: success clears the entry, failure doubles the backoff."""

    def __init__(self, base_backoff_s: float = 0.5, max_backoff_s: float = 30.0,
                 stats: Optional[CacheStats] = None):
        self.base_backoff_s = float(base_backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self.stats = stats if stats is not None else CacheStats()
        self._entries: Dict[str, QuarantineEntry] = {}

    def record_failure(self, key: str, reason: str) -> QuarantineEntry:
        prev = self._entries.get(key)
        backoff = (min(prev.backoff_s * 2.0, self.max_backoff_s)
                   if prev is not None else self.base_backoff_s)
        entry = QuarantineEntry(
            key=key, reason=str(reason)[:500],
            fail_count=(prev.fail_count + 1 if prev is not None else 1),
            backoff_s=backoff, until=time.monotonic() + backoff)
        self._entries[key] = entry
        self.stats.quarantined += 1
        return entry

    def active(self, key: str) -> bool:
        """True while the embargo holds.  The first observation after the
        deadline counts as an expiry (a retry is now permitted) and
        returns False — the entry stays, so a failed retry doubles the
        backoff instead of starting over."""
        entry = self._entries.get(key)
        if entry is None:
            return False
        if time.monotonic() < entry.until:
            self.stats.quarantine_hits += 1
            return True
        if not entry.expired:
            entry.expired = True
            self.stats.quarantine_expiries += 1
        return False

    def get(self, key: str) -> Optional[QuarantineEntry]:
        return self._entries.get(key)

    def clear(self, key: str) -> bool:
        if key in self._entries:
            del self._entries[key]
            self.stats.quarantine_clears += 1
            return True
        return False

    def entries(self) -> Dict[str, QuarantineEntry]:
        return dict(self._entries)


# --------------------------------------------------------------------------
# The cache
# --------------------------------------------------------------------------
class CompilationCache:
    """In-memory LRU of live objects + on-disk JSON artifact store.

    The two levels hold different things: memory holds whatever the caller
    puts (typically a ``CompiledProgram``); disk holds only the JSON
    ``payload`` passed to :meth:`put` (typically tilings + pass trace).
    ``get`` consults memory first, then disk, and reports which level hit
    by type: a disk hit returns the payload dict, a memory hit the live
    object.
    """

    def __init__(self, capacity: int = 128, disk_dir: Optional[os.PathLike] = None,
                 use_disk: bool = True):
        self.capacity = int(capacity)
        self.stats = CacheStats()
        self._mem: "OrderedDict[str, Any]" = OrderedDict()
        if os.environ.get(ENV_CACHE_DISABLE):
            use_disk = False
        self.disk_dir: Optional[Path] = None
        if use_disk:
            self.disk_dir = Path(disk_dir) if disk_dir is not None else default_cache_dir()
        # negative cache for crashed compiles (driver + serving engine);
        # shares this cache's stats so quarantine traffic shows up in
        # cache_stats() next to hit/miss counts
        self.quarantine = QuarantineStore(stats=self.stats)

    # ------------------------------------------------------------- memory
    def get_memory(self, key: str) -> Any:
        if key in self._mem:
            self._mem.move_to_end(key)
            self.stats.hits += 1
            return self._mem[key]
        self.stats.misses += 1
        return None

    def put_memory(self, key: str, value: Any) -> None:
        self._mem[key] = value
        self._mem.move_to_end(key)
        self.stats.puts += 1
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self.stats.evictions += 1

    # --------------------------------------------------------------- disk
    def _path(self, key: str) -> Optional[Path]:
        if self.disk_dir is None:
            return None
        return self.disk_dir / f"{key}.json"

    def get_disk(self, key: str) -> Optional[Dict[str, Any]]:
        path = self._path(key)
        if path is None:
            return None
        try:
            faults.check("cache.disk_read", key=key)
            raw = path.read_text()
        except faults.InjectedFault:
            # injected I/O failure: degrade to a miss, never propagate
            self.stats.disk_errors += 1
            return None
        except OSError:
            self.stats.disk_misses += 1
            return None
        try:
            entry = json.loads(raw)
            if not isinstance(entry, dict):
                raise ValueError("entry is not an object")
            if entry.get("version") != CACHE_VERSION or entry.get("key") != key:
                raise ValueError("stale or mismatched entry")
            payload = entry["payload"]
        except (ValueError, KeyError, TypeError):
            # corrupt/stale on-disk entry: delete it, treat as a miss
            self.stats.disk_errors += 1
            try:
                path.unlink()
            except OSError:
                pass
            return None
        self.stats.disk_hits += 1
        return payload

    def put_disk(self, key: str, payload: Dict[str, Any]) -> None:
        path = self._path(key)
        if path is None:
            return
        entry = {"version": CACHE_VERSION, "key": key, "payload": payload}
        try:
            data = json.dumps(entry, sort_keys=True)
        except (TypeError, ValueError):
            self.stats.disk_errors += 1
            return
        if faults.fires("cache.disk_write_torn", key=key):
            # simulate the torn write a non-atomic writer (or a crash mid
            # flush) would leave: a truncated entry at the final path.  The
            # read side must recover it as a miss (corrupt-entry deletion).
            try:
                path.parent.mkdir(parents=True, exist_ok=True)
                path.write_text(data[: max(1, len(data) // 2)])
            except OSError:
                pass
            self.stats.disk_errors += 1
            return
        try:
            faults.check("cache.disk_write", key=key)
            path.parent.mkdir(parents=True, exist_ok=True)
            # atomic publish: write the full entry to a temp file in the
            # same directory, then os.replace() — no reader ever sees a
            # half-written entry, regardless of where the writer dies
            fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(data)
                os.replace(tmp, path)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
        except faults.InjectedFault:
            # injected write failure: the entry is simply lost (next read
            # is a miss); the caller never sees the error
            self.stats.disk_errors += 1
            return
        except OSError:
            self.stats.disk_errors += 1
            return
        self.stats.disk_puts += 1

    # ----------------------------------------------------------- combined
    def get(self, key: str) -> Any:
        """Memory first, then disk.  A memory hit returns the live object;
        a disk hit returns the JSON payload dict."""
        val = self.get_memory(key)
        if val is not None:
            return val
        return self.get_disk(key)

    def put(self, key: str, value: Any, payload: Optional[Dict[str, Any]] = None) -> None:
        self.put_memory(key, value)
        if payload is not None:
            self.put_disk(key, payload)

    def clear(self, memory: bool = True, disk: bool = False) -> None:
        if memory:
            self._mem.clear()
        if disk and self.disk_dir is not None and self.disk_dir.exists():
            for p in self.disk_dir.glob("*.json"):
                try:
                    p.unlink()
                except OSError:
                    pass

    def __len__(self) -> int:
        return len(self._mem)


# --------------------------------------------------------------------------
# Process-wide default cache
# --------------------------------------------------------------------------
_DEFAULT: Optional[CompilationCache] = None


def get_default_cache() -> CompilationCache:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = CompilationCache()
    return _DEFAULT


def set_default_cache(cache: Optional[CompilationCache]) -> None:
    global _DEFAULT
    _DEFAULT = cache


def memoize(kind: str, parts: Any, compute: Callable[[], Any],
            cache: Optional[CompilationCache] = None) -> Any:
    """Memoize a JSON-serializable decision (e.g. a kernel block-size
    choice) through both cache levels, keyed by content."""
    c = cache if cache is not None else get_default_cache()
    key = content_key("memo", kind, parts)
    hit = c.get(key)
    if isinstance(hit, dict) and "value" in hit:
        return hit["value"]
    value = compute()
    c.put(key, {"value": value}, payload={"value": value})
    return value
