"""Fusion (paper §2.3): merge a contraction with its elementwise consumer
so both run tile-by-tile under one outer loop, eliminating the
intermediate tensor from outer memory.

The rewrite makes the contraction's output a *block-local scalar
accumulator* (an internally-scoped temporary in Def. 2's terms):

    O[i,j] = relu(T[i,j]),  T[i,j] += A[i,c]*B[c,j]
      ==>
    block [i, j] {                       # fused, one iteration per output
      acc: local (1,1) :add
      block [c] { acc += A[i,c]*B[c,j] } # reduction fully inside
      $t = load(acc); $r = relu($t); O = store($r)
    }

which autotiling then tiles like any other block.  This is also the
paper's "Scalarization and Memory Localization": T is never materialized.
"""
from __future__ import annotations

import copy
from typing import Dict, List, Mapping, Optional

from ..affine import Affine, aff
from ..hwconfig import HardwareConfig
from ..ir import Block, Intrinsic, Load, Program, RefDir, Refinement, Store, dtype_bytes
from ..lower_jnp import analyze_flat
from ..tiling import split_block
from . import register


def _buffer_usage(prog: Program) -> Dict[str, Dict[str, List[Block]]]:
    use: Dict[str, Dict[str, List[Block]]] = {}
    for s in prog.entry.stmts:
        if not isinstance(s, Block):
            continue
        for r in s.refs:
            d = use.setdefault(r.from_buf, {"r": [], "w": []})
            if r.dir in (RefDir.IN, RefDir.INOUT):
                d["r"].append(s)
            if r.dir in (RefDir.OUT, RefDir.INOUT):
                d["w"].append(s)
    return use


def _out_vars(block: Block) -> Optional[List[str]]:
    for r in block.refs:
        if r.dir == RefDir.OUT:
            vs = []
            for e in r.offsets:
                if len(e.terms) == 1 and e.const == 0 and e.terms[0][1] == 1:
                    vs.append(e.terms[0][0])
                else:
                    return None
            return vs
    return None


def try_fuse(p: Block, c: Block, prog: Program, hw: HardwareConfig, params: Mapping) -> Optional[Block]:
    try:
        pop = analyze_flat(p)
        cop = analyze_flat(c)
    except ValueError:
        return None
    if cop.agg != "assign" or pop.agg == "assign":
        return None
    t_buf = pop.out_ref.from_buf
    if t_buf in prog.outputs or t_buf in prog.inputs:
        return None
    pv = _out_vars(p)
    if pv is None:
        return None
    # the consumer must read T pointwise with plain indices, once
    t_reads = [r for r in c.refs if r.from_buf == t_buf]
    if len(t_reads) != 1:
        return None
    cv = []
    for e in t_reads[0].offsets:
        if len(e.terms) == 1 and e.const == 0 and e.terms[0][1] == 1:
            cv.append(e.terms[0][0])
        else:
            return None
    c_out = _out_vars(c)
    if c_out is None or set(c_out) != set(cv):
        return None
    # ranges must agree dim by dim
    pr, cr = p.idx_ranges(), c.idx_ranges()
    if any(pr[a] != cr[b] for a, b in zip(pv, cv)):
        return None

    # ---- feasibility: the reduction must fit the inner memory when tiled --
    red_elems = 0
    for r in p.refs:
        if r.dir != RefDir.IN:
            continue
        span = 1
        for e in r.offsets:
            for n, coef in e.terms:
                if n not in pv:
                    span *= abs(coef) * (pr[n] - 1) + 1
        red_elems += span * dtype_bytes(r.dtype)
    cap = hw.inner_mem().size_bytes * params.get("mem_cap_frac", 0.45)
    if red_elems * 2 > cap:
        return None

    rename = {b: a for a, b in zip(pv, cv)}

    # ---- build: per-output-point split of the producer --------------------
    f = split_block(p, {v: 1 for v in pv}, name_suffix="f")
    f.name = f"{p.name}+{c.name}"
    f.tags = {"contraction", "fused"}

    # redirect T's outer refinement to a local scalar accumulator
    for i, r in enumerate(f.refs):
        if r.from_buf == t_buf and r.dir == RefDir.OUT:
            f.refs[i] = Refinement(
                dir=RefDir.NONE, from_buf=r.into, into=r.into,
                offsets=(aff(0),) * r.rank, shape=(1,) * r.rank,
                dtype=r.dtype, agg=pop.agg,
            )
            acc_name = r.into
            break
    else:
        return None

    # ---- epilogue: consumer statements at the outer level -----------------
    for r in c.refs:
        if r.from_buf == t_buf:
            continue
        nr = r.clone(offsets=tuple(o.rename(rename) for o in r.offsets))
        if nr.into == acc_name:
            nr.into = nr.into + "_c"
        f.refs.append(nr)
    for s in c.stmts:
        s = copy.deepcopy(s)
        if isinstance(s, Load):
            if s.buf == t_reads[0].into:
                s = Load(acc_name, s.into)
            elif s.buf == acc_name:
                s = Load(s.buf + "_c", s.into)
        f.stmts.append(s)
    return f


@register("fuse")
def fuse_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    changed = True
    while changed:
        changed = False
        use = _buffer_usage(prog)
        stmts = [s for s in prog.entry.stmts if isinstance(s, Block)]
        for p in stmts:
            ov = [r.from_buf for r in p.refs if r.dir == RefDir.OUT]
            if not ov:
                continue
            t = ov[0]
            u = use.get(t, {"r": [], "w": []})
            if len(u["w"]) != 1 or len(u["r"]) != 1:
                continue
            c = u["r"][0]
            if c is p:
                continue
            fused = try_fuse(p, c, prog, hw, params)
            if fused is not None:
                i = prog.entry.stmts.index(p)
                prog.entry.stmts[i] = fused
                prog.entry.stmts.remove(c)
                changed = True
                break
    return prog
