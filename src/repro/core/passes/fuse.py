"""Fusion groups (paper §2.3, "Scalarization and Memory Localization").

Generalizes the classic contraction+consumer rewrite into **fusion
groups over the whole program DAG**: each contraction acts as a group
*anchor* into which the pass merges

* **elementwise prologues** — an elementwise producer of a contraction
  input is inlined into the anchor's leaf, so the input is transformed
  tile-by-tile inside the kernel instead of materializing a transformed
  copy in outer memory;
* **chains of elementwise consumers** — bias/activation/scale chains
  hanging off the contraction output become the group's epilogue;
* **multi-consumer broadcasts** — a diamond where several elementwise
  consumers of the same intermediate rejoin into one result (e.g.
  ``O = relu(T) * sigmoid(T)``) is absorbed atomically when exactly one
  buffer escapes the closure.

Every candidate merge is **cost-arbitrated** (`cost.FusionDecision`):
HBM bytes saved by eliminating the intermediate (one write + one read)
against HBM bytes added by re-fetching fused inputs per revisiting grid
tile, subject to the VMEM arena pressure of a canonical tile priced with
``core/memplan``'s slot model (streamed views double-buffered to the
hardware's ``pipeline_depth``, reduction-resident views in one slot,
the output accumulator plus its f32 scratch) — the same arithmetic the
autotiler's feasibility check and the schedule-time allocator use.
Accepted and rejected merges are recorded in the pass trace
(``params["_report"]``), so a compile's fusion decisions are auditable
and persisted with the compilation cache payload.

The rewrite itself makes the group's internal tensors *block-local
scalar accumulators* (internally-scoped temporaries in Def. 2's terms):

    O[i,j] = gelu(T[i,j] + b[j]),  T[i,j] += A[i,c]*B[c,j]
      ==>
    block [i, j] {                       # fused, one iteration per output
      acc: local (1,1) :add
      block [c] { acc += A[i,c]*B[c,j] } # reduction fully inside
      $t = load(acc); $b = load(b[j]); $s = add($t,$b)
      $r = gelu($s); O = store($r)
    }

which autotiling then tiles like any other block and the Pallas backend
lowers as **one kernel**: T (and every other group-internal buffer) is
never materialized.  The fused block carries a ``members:`` tag naming
the semantic op blocks it absorbed, which the driver uses for per-group
jnp lowering and cache bookkeeping.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple, Union

from ..affine import Affine, aff
from ..cost import FusionDecision, fusion_vmem_pressure, canonical_tile, refetch_bytes
from ..hwconfig import HardwareConfig
from ..ir import (
    Block,
    Constant,
    Intrinsic,
    Load,
    Program,
    RefDir,
    Refinement,
    Store,
    dtype_bytes,
)
from ..lower_jnp import analyze_flat
from ..tiling import split_block
from . import register

MEMBERS_TAG = "members:"


def _buffer_usage(prog: Program) -> Dict[str, Dict[str, List[Block]]]:
    use: Dict[str, Dict[str, List[Block]]] = {}
    for s in prog.entry.stmts:
        if not isinstance(s, Block):
            continue
        for r in s.refs:
            d = use.setdefault(r.from_buf, {"r": [], "w": []})
            if r.dir in (RefDir.IN, RefDir.INOUT):
                d["r"].append(s)
            if r.dir in (RefDir.OUT, RefDir.INOUT):
                d["w"].append(s)
    return use


def _out_vars(block: Block) -> Optional[List[str]]:
    """Per-dim plain index variables of the block's OUT access, or None."""
    for r in block.refs:
        if r.dir == RefDir.OUT:
            return _plain_vars(r.offsets)
    return None


def _plain_vars(offsets: Sequence[Affine]) -> Optional[List[str]]:
    """Each dim a distinct bare index (coef 1, const 0), else None."""
    vs: List[str] = []
    for e in offsets:
        if len(e.terms) == 1 and e.const == 0 and e.terms[0][1] == 1:
            vs.append(e.terms[0][0])
        else:
            return None
    return vs if len(set(vs)) == len(vs) else None


def _unique_name(base: str, used: Set[str]) -> str:
    if base not in used:
        return base
    n = 2
    while f"{base}_{n}" in used:
        n += 1
    return f"{base}_{n}"


def members_of(block: Block) -> List[str]:
    """Semantic op-block names a fused block absorbed (in program order);
    a non-fused block is its own single-member group."""
    for t in block.tags:
        if t.startswith(MEMBERS_TAG):
            return t[len(MEMBERS_TAG):].split(",")
    return [block.name.split(".")[0]]


def _set_members(block: Block, names: Sequence[str]) -> None:
    block.tags = {t for t in block.tags if not t.startswith(MEMBERS_TAG)}
    block.add_tag(MEMBERS_TAG + ",".join(names))


def _buf_bytes(prog: Program, name: str) -> int:
    d = prog.buffers[name]
    return d.size() * dtype_bytes(d.dtype)


def _interleaved_writer(blocks: List[Block], lo: int, hi: int,
                        skip: Set[int], reads: Set[str]) -> Optional[str]:
    """Name of a non-member block in (lo, hi] that writes a buffer the
    group reads (a WAR hazard for moving the reads to position hi)."""
    for q in blocks[lo + 1 : hi + 1]:
        if id(q) in skip:
            continue
        writes = {r.from_buf for r in q.refs if r.dir in (RefDir.OUT, RefDir.INOUT)}
        if writes & reads:
            return q.name
    return None


# --------------------------------------------------------------------------
# Epilogue members
# --------------------------------------------------------------------------
class _Member:
    """An elementwise consumer absorbed into a group's epilogue."""

    def __init__(self, block: Block, rename: Dict[str, str], out_buf: str,
                 out_axes: Tuple[str, ...]):
        self.block = block
        self.rename = rename      # member index var -> group output var
        self.out_buf = out_buf
        self.out_axes = out_axes  # group var addressing each out dim

    def external_refs(self, internal: Set[str]) -> List[Refinement]:
        return [r for r in self.block.refs
                if r.dir == RefDir.IN and r.from_buf not in internal]


def _member_compat(c: Block, internal_axes: Dict[str, Tuple[str, ...]],
                   group_ranges: Mapping[str, int],
                   anchor_axes: Tuple[str, ...]) -> Union[_Member, str]:
    """Check that ``c`` can join the epilogue; returns a _Member or a
    human-readable rejection reason."""
    if c.constraints:
        return "member has constraints"
    try:
        cop = analyze_flat(c)
    except ValueError as e:
        return f"not a flat elementwise block ({e})"
    if cop.agg != "assign":
        return "member aggregates (not elementwise)"
    rename: Dict[str, str] = {}
    n_internal = 0
    for r in c.refs:
        if r.dir == RefDir.NONE:
            return "member has local allocations"
        if r.dir != RefDir.IN or r.from_buf not in internal_axes:
            continue
        n_internal += 1
        vs = _plain_vars(r.offsets)
        axes = internal_axes[r.from_buf]
        if vs is None or len(vs) != len(axes):
            return f"non-pointwise read of {r.from_buf}"
        for var, want in zip(vs, axes):
            if rename.get(var, want) != want:
                return f"conflicting index mapping on {var}"
            rename[var] = want
    if n_internal == 0:
        return "reads no group intermediate"
    free = c.idx_ranges()
    for v, rng in free.items():
        if v not in rename:
            return f"member index {v} not driven by the group"
        if rng != group_ranges.get(rename[v]):
            return f"range mismatch on {v}"
    ov = _out_vars(c)
    if ov is None:
        return "member output access is not a plain index tuple"
    out_ref = next(r for r in c.refs if r.dir == RefDir.OUT)
    if any(s != 1 for s in out_ref.shape):
        return "member output is not a scalar view"
    out_axes = tuple(rename[v] for v in ov)
    if out_axes != anchor_axes:
        # A permuting member would need the accumulator tile transposed
        # before the store — the Pallas emitter stores the tile interior
        # as-is, so axis permutations are rejected (the op stays unfused).
        return "member output permutes the group axes"
    return _Member(c, rename, out_ref.from_buf, out_axes)


def _collect_closure(anchor: Block, t_buf: str, t_axes: Tuple[str, ...],
                     group_ranges: Mapping[str, int], blocks: List[Block],
                     use, prog: Program, limit: int = 16
                     ) -> Tuple[List[_Member], str]:
    """Grow the elementwise closure downstream of ``t_buf``.  Returns the
    members in topological order, or ([], reason).  Legal only when
    exactly one produced buffer escapes the closure."""
    internal_axes: Dict[str, Tuple[str, ...]] = {t_buf: t_axes}
    members: List[_Member] = []
    in_closure: Set[int] = {id(anchor)}
    first_reason = ""
    candidates = {id(b): b for b in blocks
                  if id(b) != id(anchor) and any(
                      r.dir == RefDir.IN for r in b.refs)}
    progressed = True
    while progressed and len(members) < limit:
        progressed = False
        for buf in list(internal_axes):
            for c in use.get(buf, {}).get("r", []):
                if id(c) in in_closure:
                    continue
                # Defer a member whose non-internal input is produced by a
                # block still adjacent to the closure (a diamond join must
                # wait for all its arms to be absorbed, so those inputs
                # resolve to scalars instead of external refs).
                deferred = False
                for r in c.refs:
                    if r.dir != RefDir.IN or r.from_buf in internal_axes:
                        continue
                    for w in use.get(r.from_buf, {}).get("w", []):
                        if id(w) in in_closure or id(w) not in candidates:
                            continue
                        if any(q.dir == RefDir.IN and q.from_buf in internal_axes
                               for q in w.refs):
                            deferred = True
                if deferred:
                    continue
                got = _member_compat(c, internal_axes, group_ranges, t_axes)
                if isinstance(got, str):
                    first_reason = first_reason or f"{c.name}: {got}"
                    continue
                members.append(got)
                in_closure.add(id(c))
                internal_axes[got.out_buf] = got.out_axes
                progressed = True
    if not members:
        return [], first_reason or "no elementwise consumer"
    # ---- escape analysis: exactly one produced buffer may leave ----------
    escaping = []
    for buf in internal_axes:
        if buf in prog.outputs:
            escaping.append(buf)
            continue
        outside_r = [b for b in use.get(buf, {}).get("r", []) if id(b) not in in_closure]
        outside_w = [b for b in use.get(buf, {}).get("w", []) if id(b) not in in_closure]
        if outside_r or outside_w:
            escaping.append(buf)
    if len(escaping) != 1:
        return [], f"{len(escaping)} buffers escape the closure ({', '.join(sorted(escaping))})"
    final = escaping[0]
    if final == t_buf:
        return [], "the contraction output itself escapes"
    note = f"member limit {limit} reached" if len(members) >= limit else ""
    # reorder so the final producer is last (collection is already topo;
    # just rotate the final member to the end if needed)
    fi = next(i for i, m in enumerate(members) if m.out_buf == final)
    if fi != len(members) - 1:
        # the final member must not feed any *other* member
        if any(final in (r.from_buf for r in m.block.refs if r.dir == RefDir.IN)
               for i, m in enumerate(members) if i != fi):
            return [], "the escaping buffer feeds other members"
        members.append(members.pop(fi))
    return members, note


def _chain_walk(anchor: Block, t_buf: str, t_axes: Tuple[str, ...],
                group_ranges: Mapping[str, int], use, prog: Program,
                limit: int = 16) -> Tuple[List[_Member], str]:
    """Fallback: follow single-reader links only (a pure consumer chain);
    every prefix of the result is a legal group."""
    members: List[_Member] = []
    buf, axes = t_buf, t_axes
    reason = ""
    while len(members) < limit:
        if buf != t_buf and buf in prog.outputs:
            break  # the chain head escapes here; stop extending
        readers = use.get(buf, {}).get("r", [])
        if len(readers) != 1 or readers[0] is anchor:
            reason = reason or f"{buf} has {len(readers)} readers"
            break
        got = _member_compat(readers[0], {buf: axes}, group_ranges, t_axes)
        if isinstance(got, str):
            reason = f"{readers[0].name}: {got}"
            break
        if len(use.get(got.out_buf, {}).get("w", [])) != 1:
            reason = f"{got.out_buf} has multiple writers"
            break
        members.append(got)
        buf, axes = got.out_buf, got.out_axes
    if len(members) >= limit and not reason:
        reason = f"member limit {limit} reached"
    return members, reason


# --------------------------------------------------------------------------
# Prologue inlining
# --------------------------------------------------------------------------
def _producer_compat(P: Block, read_vars: List[str],
                     anchor_ranges: Mapping[str, int]) -> Union[Dict[str, str], str]:
    """Check elementwise producer P can be inlined where the anchor reads
    its output with per-dim vars ``read_vars``; returns the index rename
    (P var -> anchor var) or a reason."""
    if P.constraints:
        return "producer has constraints"
    try:
        pop = analyze_flat(P)
    except ValueError as e:
        return f"producer not flat ({e})"
    if pop.agg != "assign":
        return "producer aggregates"
    pv = _out_vars(P)
    out_ref = next(r for r in P.refs if r.dir == RefDir.OUT)
    if pv is None or len(pv) != len(read_vars) or any(s != 1 for s in out_ref.shape):
        return "producer output access is not a plain index tuple"
    free = P.idx_ranges()
    if set(free) - set(pv):
        return "producer has free reduction indices"
    rename = dict(zip(pv, read_vars))
    for v in pv:
        if free.get(v) != anchor_ranges.get(rename[v]):
            return f"range mismatch on {v}"
    return rename


def _inline_producer(c: Block, u_ref: Refinement, P: Block,
                     rename: Dict[str, str], prefix: str) -> None:
    """Splice P's statement list into anchor ``c`` in place of its load of
    P's output, renaming indices into the anchor's space."""
    used = {r.into for r in c.refs}
    smap: Dict[str, str] = {}
    new_stmts: List = []
    stored: Optional[str] = None
    for s in P.stmts:
        if isinstance(s, Load):
            ref = P.ref(s.buf)
            into = _unique_name(ref.from_buf, used)
            used.add(into)
            c.refs.append(ref.clone(
                offsets=tuple(o.rename(rename) for o in ref.offsets), into=into))
            smap[s.into] = prefix + s.into
            new_stmts.append(Load(into, prefix + s.into))
        elif isinstance(s, Constant):
            smap[s.into] = prefix + s.into
            new_stmts.append(Constant(s.value, prefix + s.into))
        elif isinstance(s, Intrinsic):
            smap[s.into] = prefix + s.into
            new_stmts.append(Intrinsic(s.op, tuple(smap[a] for a in s.args),
                                       prefix + s.into))
        elif isinstance(s, Store):
            stored = smap[s.scalar]
    assert stored is not None
    # replace the anchor's load of the intermediate with P's body
    out: List = []
    alias: Dict[str, str] = {}
    for s in c.stmts:
        if isinstance(s, Load) and s.buf == u_ref.into:
            out.extend(new_stmts)
            alias[s.into] = stored
        elif isinstance(s, Intrinsic):
            out.append(Intrinsic(s.op, tuple(alias.get(a, a) for a in s.args), s.into))
        elif isinstance(s, Store):
            out.append(Store(s.buf, alias.get(s.scalar, s.scalar)))
        else:
            out.append(s)
    c.stmts = out
    c.refs = [r for r in c.refs if r is not u_ref]


def _inline_prologues(prog: Program, hw: HardwareConfig, params: Mapping,
                      decisions: List[FusionDecision], seen: Set[Tuple]) -> None:
    changed = True
    while changed:
        changed = False
        blocks = [s for s in prog.entry.stmts if isinstance(s, Block)]
        use = _buffer_usage(prog)
        for c in blocks:
            try:
                cop = analyze_flat(c)
            except ValueError:
                continue
            if cop.agg == "assign":
                continue
            anchor_ranges = c.idx_ranges()
            out_vars = _out_vars(c)
            if out_vars is None:
                continue
            for r in list(c.refs):
                if r.dir != RefDir.IN:
                    continue
                ubuf = r.from_buf
                if ubuf in prog.inputs or ubuf in prog.outputs:
                    continue
                uu = use.get(ubuf, {"r": [], "w": []})
                if len(uu["w"]) != 1 or uu["w"][0] is c or uu["r"] != [c]:
                    continue
                P = uu["w"][0]
                if sum(1 for q in c.refs if q.from_buf == ubuf) != 1:
                    continue
                key = (c.name, P.name, "prologue")
                if key in seen:
                    continue
                vs = _plain_vars(r.offsets)
                if vs is None:
                    continue
                rename = _producer_compat(P, vs, anchor_ranges)
                if isinstance(rename, str):
                    continue  # legality, not cost: no decision recorded
                hazard = _interleaved_writer(
                    blocks, blocks.index(P), blocks.index(c), {id(P), id(c)},
                    {q.from_buf for q in P.refs if q.dir == RefDir.IN})
                if hazard:
                    continue
                # ---- cost arbitration -------------------------------------
                seen.add(key)
                saved = 2 * _buf_bytes(prog, ubuf)
                tile = canonical_tile(anchor_ranges, params, set(out_vars))
                added = 0
                p_in_refs = [q.clone(offsets=tuple(o.rename(rename) for o in q.offsets))
                             for q in P.refs if q.dir == RefDir.IN]
                for q in p_in_refs:
                    q_vars = {n for e in q.offsets for n in e.names()}
                    added += refetch_bytes(q_vars, anchor_ranges, out_vars, tile,
                                           _buf_bytes(prog, q.from_buf))
                trial = [q for q in c.refs if q.dir != RefDir.NONE and q is not r] + p_in_refs
                vmem, cap, fits = fusion_vmem_pressure(
                    trial, anchor_ranges, hw, params, set(out_vars))
                ok = fits and saved >= added
                why = "" if ok else (
                    f"arena {vmem}B > cap {cap}B" if not fits
                    else f"refetch {added}B > saved {saved}B")
                decisions.append(FusionDecision(
                    group=c.name, member=P.name, kind="prologue", accepted=ok,
                    hbm_saved=saved, hbm_added=added, vmem_bytes=vmem,
                    vmem_cap=cap, reason=why))
                if not ok:
                    continue
                _inline_producer(c, r, P, rename, f"p{len(members_of(c))}_")
                _set_members(c, [P.name.split(".")[0]] + members_of(c))
                c.add_tag("fused_prologue")
                prog.entry.stmts.remove(P)
                changed = True
                break
            if changed:
                break


# --------------------------------------------------------------------------
# Group materialization
# --------------------------------------------------------------------------
def _materialize_group(anchor: Block, members: List[_Member],
                       prog: Program) -> Optional[Block]:
    pop = analyze_flat(anchor)
    pv = _out_vars(anchor)
    t_buf = pop.out_ref.from_buf
    f = split_block(anchor, {v: 1 for v in pv}, name_suffix="f")
    base = members_of(anchor)
    names = [m.block.name.split(".")[0] for m in members]
    f.name = "+".join([anchor.name] + names)
    # partition annotations ride along so the mesh split decision stays
    # visible on the fused block
    f.tags = {"contraction", "fused"} | {
        t for m in [anchor] + [m.block for m in members]
        for t in m.tags if t == "partitioned" or t.startswith("partition:")}
    _set_members(f, base + names)

    acc_name = None
    for i, r in enumerate(f.refs):
        if r.from_buf == t_buf and r.dir in (RefDir.OUT, RefDir.INOUT):
            f.refs[i] = Refinement(
                dir=RefDir.NONE, from_buf=r.into, into=r.into,
                offsets=(aff(0),) * r.rank, shape=(1,) * r.rank,
                dtype=r.dtype, agg=pop.agg,
            )
            acc_name = r.into
            break
    if acc_name is None:
        return None

    used = {r.into for r in f.refs}
    acc_scalar = "acc0"
    stmts: List = [Load(acc_name, acc_scalar)]
    scalar_of: Dict[str, str] = {t_buf: acc_scalar}
    ext_into: Dict[Tuple, str] = {}
    for mi, m in enumerate(members):
        pref = f"e{mi}_"
        last = mi == len(members) - 1
        smap: Dict[str, str] = {}
        for s in m.block.stmts:
            if isinstance(s, Load):
                ref = m.block.ref(s.buf)
                if ref.from_buf in scalar_of:
                    smap[s.into] = scalar_of[ref.from_buf]
                    continue
                offs = tuple(o.rename(m.rename) for o in ref.offsets)
                key = (ref.from_buf, tuple(str(o) for o in offs))
                into = ext_into.get(key)
                if into is None:
                    into = _unique_name(ref.from_buf, used)
                    used.add(into)
                    f.refs.append(ref.clone(offsets=offs, into=into))
                    ext_into[key] = into
                smap[s.into] = pref + s.into
                stmts.append(Load(into, pref + s.into))
            elif isinstance(s, Constant):
                smap[s.into] = pref + s.into
                stmts.append(Constant(s.value, pref + s.into))
            elif isinstance(s, Intrinsic):
                smap[s.into] = pref + s.into
                stmts.append(Intrinsic(s.op, tuple(smap[a] for a in s.args),
                                       pref + s.into))
            elif isinstance(s, Store):
                out_ref = m.block.ref(s.buf)
                if last:
                    into = _unique_name(out_ref.from_buf + "_out", used)
                    used.add(into)
                    f.refs.append(out_ref.clone(
                        offsets=tuple(o.rename(m.rename) for o in out_ref.offsets),
                        into=into))
                    stmts.append(Store(into, smap[s.scalar]))
                else:
                    scalar_of[out_ref.from_buf] = smap[s.scalar]
            else:
                return None
    f.stmts.extend(stmts)
    return f


# --------------------------------------------------------------------------
# Group formation
# --------------------------------------------------------------------------
def _form_groups(prog: Program, hw: HardwareConfig, params: Mapping,
                 decisions: List[FusionDecision], seen: Set[Tuple]) -> None:
    changed = True
    while changed:
        changed = False
        blocks = [s for s in prog.entry.stmts if isinstance(s, Block)]
        use = _buffer_usage(prog)
        for p in blocks:
            if "fused" in p.tags:
                continue
            try:
                pop = analyze_flat(p)
            except ValueError:
                continue
            if pop.agg == "assign":
                continue
            t_buf = pop.out_ref.from_buf
            if t_buf in prog.outputs or t_buf in prog.inputs:
                continue
            pv = _out_vars(p)
            if pv is None:
                continue
            u = use.get(t_buf, {"r": [], "w": []})
            if u["w"] != [p] or not u["r"]:
                continue
            ranges = p.idx_ranges()
            axes = tuple(pv)
            limit = int(params.get("member_limit", 16))
            members, why = _collect_closure(p, t_buf, axes, ranges, blocks, use,
                                            prog, limit=limit)
            chain = bool(members) and all(
                len(use.get(b_, {}).get("r", [])) == 1
                for b_ in [t_buf] + [m.out_buf for m in members[:-1]])
            if not members:
                members, why2 = _chain_walk(p, t_buf, axes, ranges, use, prog,
                                            limit=limit)
                chain = True
                why = why2
                if not members:
                    key = (p.name, "", "closure")
                    if key not in seen:
                        seen.add(key)
                        decisions.append(FusionDecision(
                            group=p.name, member="", kind="epilogue",
                            accepted=False, reason=why2))
                    continue
            if members and "member limit" in why:
                # truncated growth is auditable too: record why the tail
                # of the consumer chain stays unfused
                key = (p.name, "", "limit")
                if key not in seen:
                    seen.add(key)
                    decisions.append(FusionDecision(
                        group=p.name, member="", kind="epilogue",
                        accepted=False, reason=why))

            accepted = _arbitrate(p, members, chain, ranges, pv, t_buf, prog,
                                  hw, params, decisions, seen)
            if not accepted:
                continue
            group_reads = {r.from_buf for r in p.refs if r.dir == RefDir.IN}
            internal = {t_buf} | {m.out_buf for m in accepted[:-1]}
            for m in accepted:
                group_reads |= {r.from_buf for r in m.external_refs(internal)}
            anchor_idx = blocks.index(p)
            place_idx = max([anchor_idx] + [blocks.index(m.block) for m in accepted])
            skip = {id(p)} | {id(m.block) for m in accepted}
            hazard = _interleaved_writer(blocks, anchor_idx, place_idx, skip, group_reads)
            if hazard:
                key = (p.name, hazard, "hazard")
                if key not in seen:
                    seen.add(key)
                    decisions.append(FusionDecision(
                        group=p.name, member=",".join(m.block.name for m in accepted),
                        kind="epilogue", accepted=False,
                        reason=f"interleaved writer {hazard} between anchor and members"))
                continue
            fused = _materialize_group(p, accepted, prog)
            if fused is None:
                continue
            # place the group where its last member ran; drop the rest
            new_stmts: List = []
            for s in prog.entry.stmts:
                if isinstance(s, Block) and id(s) in skip:
                    if s is blocks[place_idx]:
                        new_stmts.append(fused)
                    continue
                new_stmts.append(s)
            prog.entry.stmts = new_stmts
            changed = True
            break


def _arbitrate(p: Block, members: List[_Member], chain: bool,
               ranges: Mapping[str, int], out_vars: List[str], t_buf: str,
               prog: Program, hw: HardwareConfig, params: Mapping,
               decisions: List[FusionDecision], seen: Set[Tuple]) -> List[_Member]:
    """Cost-arbitrate the candidate members.  Chains accept the longest
    profitable prefix (one decision per member); diamonds are atomic."""
    tile = canonical_tile(ranges, params, set(out_vars))
    base_refs = [r for r in p.refs if r.dir in (RefDir.IN, RefDir.OUT, RefDir.INOUT)]
    internal = {t_buf} | {m.out_buf for m in members}

    def ext_refs(m: _Member) -> List[Refinement]:
        return [r.clone(offsets=tuple(o.rename(m.rename) for o in r.offsets))
                for r in m.external_refs(internal)]

    def added_for(refs: List[Refinement]) -> int:
        total = 0
        for q in refs:
            q_vars = {n for e in q.offsets for n in e.names()}
            total += refetch_bytes(q_vars, ranges, out_vars, tile,
                                   _buf_bytes(prog, q.from_buf))
        return total

    if not chain:
        all_ext: List[Refinement] = []
        for m in members:
            all_ext.extend(ext_refs(m))
        saved = 2 * sum(_buf_bytes(prog, b) for b in
                        [t_buf] + [m.out_buf for m in members[:-1]])
        added = added_for(all_ext)
        vmem, cap, fits = fusion_vmem_pressure(
            base_refs + all_ext, ranges, hw, params, set(out_vars))
        ok = fits and saved >= added
        why = "" if ok else (f"arena {vmem}B > cap {cap}B" if not fits
                             else f"refetch {added}B > saved {saved}B")
        key = (p.name, ",".join(m.block.name for m in members), "epilogue")
        if key not in seen:
            seen.add(key)
            decisions.append(FusionDecision(
                group=p.name, member=",".join(m.block.name for m in members),
                kind="epilogue", accepted=ok, hbm_saved=saved, hbm_added=added,
                vmem_bytes=vmem, vmem_cap=cap, reason=why))
        return members if ok else []

    accepted: List[_Member] = []
    cur_refs = list(base_refs)
    consumed = t_buf
    for m in members:
        refs_m = ext_refs(m)
        saved = 2 * _buf_bytes(prog, consumed)
        added = added_for(refs_m)
        vmem, cap, fits = fusion_vmem_pressure(
            cur_refs + refs_m, ranges, hw, params, set(out_vars))
        ok = fits and saved >= added
        why = "" if ok else (f"arena {vmem}B > cap {cap}B" if not fits
                             else f"refetch {added}B > saved {saved}B")
        key = (p.name, m.block.name, "epilogue")
        if key not in seen:
            seen.add(key)
            decisions.append(FusionDecision(
                group=p.name, member=m.block.name, kind="epilogue", accepted=ok,
                hbm_saved=saved, hbm_added=added, vmem_bytes=vmem, vmem_cap=cap,
                reason=why))
        if not ok:
            break
        accepted.append(m)
        cur_refs.extend(refs_m)
        consumed = m.out_buf
    return accepted


@register("fuse")
def fuse_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    decisions: List[FusionDecision] = []
    seen: Set[Tuple] = set()
    # Grouping preference is a hardware parameterization:
    # * "epilogue" (default) absorbs consumer chains into their producer —
    #   never recomputes, the right choice when the backend applies the
    #   epilogue on the accumulator tile (Pallas/TPU);
    # * "prologue" inlines elementwise producers into the *next*
    #   contraction first — elementwise work feeds the dot instead of
    #   trailing it, which keeps XLA:CPU's gemm + transcendental loops on
    #   their parallel library paths (a dot-terminated executable).
    if params.get("prefer", "epilogue") == "prologue":
        _inline_prologues(prog, hw, params, decisions, seen)
        _form_groups(prog, hw, params, decisions, seen)
    else:
        _form_groups(prog, hw, params, decisions, seen)
        _inline_prologues(prog, hw, params, decisions, seen)
    report = params.get("_report")
    if report is not None:
        report.extend(d.to_json() for d in decisions)
    return prog
