"""Banking & partitioning (paper §2.3).

Splits an outer parallel index across ``n_units`` compute units and tags
the tensors with a bank assignment (``Location.bank`` affine in the
partition index).  At the framework level this pass's decision is
consumed by ``repro.parallel.sharding``: the partitioned index maps to a
mesh axis and GSPMD performs the actual distribution — Stripe decides the
*logical* split; pjit/shard_map execute it.
"""
from __future__ import annotations

from typing import Mapping

from ..affine import Affine
from ..hwconfig import HardwareConfig
from ..ir import Block, Location, Program, RefDir
from ..tiling import split_block
from . import register


def partition_block(block: Block, n_units: int, unit: str = "core") -> Block:
    """Split the largest parallel (output) index across n_units banks."""
    from .stencil import _roles

    out_vars, _red = _roles(block)
    cands = [v for v in out_vars if block.idx(v).range % n_units == 0]
    if not cands:
        return block
    v = max(cands, key=lambda x: block.idx(x).range)
    per = block.idx(v).range // n_units
    outer = split_block(block, {v: per}, name_suffix="p")
    outer.tags = (outer.tags - {"grid"}) | {"partitioned"}
    outer.add_tag(f"partition:{v}:{n_units}")
    for r in outer.refs:
        if any(v in e.names() for e in r.offsets):
            r.location = Location(unit=unit, bank=Affine.var(v))
    return outer


def _annotate_mesh(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    """Mesh-annotation mode: the config carries a device mesh
    (``hw.with_mesh``), so run the shard planner and record its split /
    collective decisions into the pass report — ``cost.score_pass_trace``
    scales the per-block roofline by the split factor and charges the
    exposed communication time, which is how an ``explore`` sweep over
    mesh shapes scores points without touching any devices.  The blocks
    are tagged but **not** restructured (the driver's mesh path does the
    actual segment cutting at lowering time); a program the planner
    cannot shard reports the reason and passes through unchanged."""
    n = hw.mesh_devices()
    if n <= 1:
        return prog
    from ..shardplan import UnsupportedMesh, plan_program

    report = params.get("_report")
    try:
        plan = plan_program(prog.source or prog, n, hw, hw.mesh)
    except UnsupportedMesh as e:
        if report is not None:
            report.append({"mesh": list(hw.mesh), "fallback": str(e)})
        return prog
    splits = plan.splits()
    for s in prog.entry.stmts:
        if not isinstance(s, Block):
            continue
        for member in s.name.split("+"):
            base = member.split(".")[0]
            hit = splits.get(member) or splits.get(base) or splits.get(s.name)
            if hit:
                s.add_tag("partitioned")
                s.add_tag(f"partition:{hit}:{n}")
                break
    if report is not None:
        report.extend(plan.report(scale_compute=True))
    return prog


@register("partition")
def partition_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    n_units = params.get("n_units", 1)
    if n_units <= 1:
        return _annotate_mesh(prog, hw, params)
    new_stmts = []
    for s in prog.entry.stmts:
        if isinstance(s, Block) and "contraction" in s.tags and "grid" not in s.tags and "partitioned" not in s.tags:
            new_stmts.append(partition_block(s, n_units, params.get("unit", "core")))
        else:
            new_stmts.append(s)
    prog.entry.stmts = new_stmts
    return prog
