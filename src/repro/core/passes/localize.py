"""Scalarization & memory localization (paper §2.3) + location assignment.

* assigns hardware ``Location``\\ s level-by-level: refinements at program
  scope live in the outermost memory (HBM); views inside a grid block live
  in the inner memory (VMEM); scalar-shaped local accumulators live in
  registers,
* garbage-collects intermediate buffers that fusion scalarized away (no
  remaining readers/writers), removing their outer-memory allocation.
"""
from __future__ import annotations

from typing import Mapping

from ..hwconfig import HardwareConfig
from ..ir import Block, Location, Program, RefDir
from . import register


def _assign(block: Block, hw: HardwareConfig, level: int, inner_name: str) -> None:
    units = [m.name for m in hw.mem_units]
    for r in block.refs:
        if r.location is not None:
            continue
        if r.dir == RefDir.NONE and r.is_scalar_view():
            r.location = Location(unit=units[-1])  # register file
        elif level == 0:
            r.location = Location(unit=units[0])
        else:
            r.location = Location(unit=inner_name)
    for s in block.stmts:
        if isinstance(s, Block):
            nxt = level + (1 if "grid" in block.tags or "tile" in block.tags or level > 0 else 1)
            _assign(s, hw, nxt, inner_name)


@register("localize")
def localize_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    inner = params.get("inner", hw.inner_mem().name)
    for s in prog.entry.stmts:
        if isinstance(s, Block):
            _assign(s, hw, 0, inner)
    # GC buffers no block references anymore (scalarized intermediates)
    live = set(prog.inputs) | set(prog.outputs)
    for s in prog.entry.stmts:
        if isinstance(s, Block):
            for r in s.refs:
                if r.dir != RefDir.NONE:
                    live.add(r.from_buf)
    dead = [b for b in prog.buffers if b not in live]
    for b in dead:
        del prog.buffers[b]
        prog.entry.refs = [r for r in prog.entry.refs if r.from_buf != b]
    if dead:
        prog.entry.comments += f" localize: scalarized {dead}"
    return prog
