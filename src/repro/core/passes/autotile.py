"""Autotiling (paper §3.3).

Explores a space of tile shapes under memory-capacity and stencil-multiple
constraints with a cost function (cache-lines/MAC or TPU roofline, per the
hardware config) and rewrites the chosen tiling via ``split_block``.

Two additions over the plain exhaustive search:

* **Oracle replay** — when the pass manager injects a ``TilingOracle``
  (``params["_oracle"]``) with a known tiling for a block, the search is
  skipped and the recorded tiling replayed (warm compile path).
* **Parallel search** — ``params["workers"] > 1`` evaluates candidate
  chunks across a ``concurrent.futures`` process pool.  Tie-breaking is
  deterministic: candidates are globally indexed in serial enumeration
  order and the reduction takes the minimum of ``(cost, index)``, which is
  exactly the serial loop's first-best-wins rule — the parallel search
  always picks the identical tiling.
"""
from __future__ import annotations

import itertools
import os
from typing import Dict, List, Mapping, Optional, Tuple

from ..cost import TileCost, evaluate_tiling
from ..hwconfig import HardwareConfig
from ..ir import Block, Program
from ..poly import factors
from ..tiling import split_block
from . import register

ENV_WORKERS = "STRIPE_AUTOTUNE_WORKERS"

# below this many candidates, process spawn overhead dwarfs the search
PARALLEL_MIN_COMBOS = 2048


def _candidates(r: int, search: str) -> List[int]:
    if search == "divisors":
        return factors(r)
    if search == "exhaustive":
        return list(range(1, r + 1))
    # pow2 (default): powers of two up to r, plus r itself
    out = []
    t = 1
    while t < r:
        out.append(t)
        t *= 2
    out.append(r)
    return out


def _resolve_workers(params: Mapping) -> int:
    w = params.get("workers")
    if w is None:
        w = os.environ.get(ENV_WORKERS)
    if w == "auto":
        return os.cpu_count() or 1
    try:
        return max(int(w), 1)
    except (TypeError, ValueError):
        # unset, empty, or garbage: parallelism is optional — never fail
        # a compile over it
        return 1


def _search_chunk(block: Block, hw: HardwareConfig, params: Dict, names: List[str],
                  combos: List[Tuple[int, ...]], base: int,
                  macs_exact=()):
    """Best feasible candidate in one chunk: (cost, global index, tiles)."""
    if macs_exact != ():
        # The exact MAC count (an expensive polyhedron enumeration) is
        # memoized by IR fingerprint — seed the worker's LRU with the
        # parent's precomputed (key, value) so no worker re-enumerates,
        # and thread the key so candidates don't re-hash the IR.
        from ..cost import seed_macs_cache

        seed_macs_cache(*macs_exact)
        params = dict(params, _macs_key=macs_exact[0])
    best = None
    for j, combo in enumerate(combos):
        tiles = dict(zip(names, combo))
        c = evaluate_tiling(block, tiles, hw, params)
        if not c.feasible:
            continue
        if best is None or c.cost < best[0]:
            best = (c.cost, base + j, tiles, c)
    return best


def _search_serial(block, hw, params, names, cands):
    best: Optional[Tuple[Dict[str, int], TileCost]] = None
    for combo in itertools.product(*(cands[v] for v in names)):
        tiles = dict(zip(names, combo))
        c = evaluate_tiling(block, tiles, hw, params)
        if not c.feasible:
            continue
        if best is None or c.cost < best[1].cost:
            best = (tiles, c)
    return best


def _search_parallel(block, hw, params, names, cands, workers):
    import concurrent.futures
    import multiprocessing

    combos = list(itertools.product(*(cands[v] for v in names)))
    # strip private injected state (oracles etc.) before shipping to workers
    clean = {k: v for k, v in params.items() if not k.startswith("_")}
    macs_exact = ()
    if params.get("exact_macs"):
        from ..cost import count_macs_exact, macs_cache_key

        key = params.get("_macs_key") or macs_cache_key(block)
        macs_exact = (key, count_macs_exact(block, key=key))
    chunk = max(1, -(-len(combos) // (workers * 4)))
    try:
        # forkserver: children fork from a clean single-threaded server
        # process, never from this (jax-threaded) one; workers only import
        # the pure-python cost model, so startup stays cheap
        try:
            ctx = multiprocessing.get_context("forkserver")
        except ValueError:
            ctx = multiprocessing.get_context("fork")
        with concurrent.futures.ProcessPoolExecutor(max_workers=workers, mp_context=ctx) as ex:
            futs = [
                ex.submit(_search_chunk, block, hw, clean, names,
                          combos[i:i + chunk], i, macs_exact)
                for i in range(0, len(combos), chunk)
            ]
            results = [f.result() for f in futs]
    except (OSError, ValueError, RuntimeError):
        # no fork / pool failure: the serial path is always available
        return _search_serial(block, hw, params, names, cands)
    best = min((r for r in results if r is not None),
               key=lambda r: (r[0], r[1]), default=None)
    if best is None:
        return None
    return best[2], best[3]


def choose_tiling(block: Block, hw: HardwareConfig, params: Mapping) -> Tuple[Dict[str, int], TileCost]:
    free = {i.name: i.range for i in block.idxs if not i.is_passthrough()}
    if params.get("exact_macs") and "_macs_key" not in params:
        # hash the block once for the whole candidate sweep
        from ..cost import macs_cache_key

        params = dict(params, _macs_key=macs_cache_key(block))
    search = params.get("search", "pow2")
    names = sorted(free)
    cands = {v: _candidates(free[v], search) for v in names}
    # multiples of an existing stencil (tags like "stencil:v=8")
    for t in block.tags:
        if t.startswith("stencil:"):
            v, m = t.split(":")[1].split("=")
            m = int(m)
            cands[v] = [c for c in cands[v] if c % m == 0] or [m]

    n_combos = 1
    for v in names:
        n_combos *= len(cands[v])
    max_combos = params.get("max_combos", 200_000)
    if n_combos > max_combos:
        # coordinate-descent fallback: greedy per-dim refinement
        return _coordinate_descent(block, hw, params, free, cands)

    workers = _resolve_workers(params)
    min_combos = params.get("parallel_min_combos", PARALLEL_MIN_COMBOS)
    if workers > 1 and n_combos >= min_combos:
        best = _search_parallel(block, hw, params, names, cands, workers)
    else:
        best = _search_serial(block, hw, params, names, cands)
    if best is None:
        # nothing feasible: fall back to all-ones tiles (always fits)
        tiles = {v: 1 for v in names}
        return tiles, evaluate_tiling(block, tiles, hw, params)
    return best


def _coordinate_descent(block, hw, params, free, cands):
    tiles = {v: c[-1] for v, c in cands.items()}
    cost = evaluate_tiling(block, tiles, hw, params)
    if not cost.feasible:
        # a feasible anchor is required: one-dimensional moves from an
        # infeasible all-max start can be uniformly infeasible when the
        # memory cap needs several dims shrunk at once.  The smallest
        # candidate per dim is the conservative restart.
        tiles = {v: c[0] for v, c in cands.items()}
        cost = evaluate_tiling(block, tiles, hw, params)
    for _ in range(6):
        improved = False
        for v in sorted(free):
            best_t, best_c = tiles[v], cost
            for t in cands[v]:
                trial = dict(tiles)
                trial[v] = t
                c = evaluate_tiling(block, trial, hw, params)
                if c.feasible and (not best_c.feasible or c.cost < best_c.cost):
                    best_t, best_c = t, c
                    improved = True
            tiles[v] = best_t
            cost = best_c
        if not improved:
            break
    return tiles, cost


def _oracle_key(block: Block) -> str:
    """Tiling-oracle key: the block name qualified by the block's content
    fingerprint, so a recorded tiling replays for the *whole group* it was
    chosen for — a fused group whose membership changed (different fusion
    decisions on a warm compile of different source) never inherits a
    stale tiling."""
    from ..ir import ir_fingerprint

    return f"{block.name}#{ir_fingerprint(block)[:16]}"


@register("autotile")
def autotile_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    oracle = params.get("_oracle")
    report = params.get("_report")
    new_stmts = []
    for s in prog.entry.stmts:
        if not isinstance(s, Block) or not ({"contraction", "elementwise"} & s.tags) or "grid" in s.tags:
            new_stmts.append(s)
            continue
        free = {i.name: i.range for i in s.idxs if not i.is_passthrough()}
        key = _oracle_key(s) if oracle is not None else s.name
        known = oracle.lookup(key) if oracle is not None else None
        if known is not None:
            tiles = {v: t for v, t in known.items() if v in free}
            cost = evaluate_tiling(s, tiles, hw, params)
            oracle.replays += 1
        else:
            tiles, cost = choose_tiling(s, hw, params)
            if oracle is not None:
                oracle.searches += 1
        if oracle is not None:
            oracle.record(key, tiles)
        if report is not None:
            # per-block analytic record — cost.score_pass_trace aggregates
            # these into the explore subsystem's predicted-latency axis
            report.append({
                "block": s.name, "tiles": dict(tiles), "cost": cost.cost,
                "t_mem": cost.t_mem, "t_compute": cost.t_compute,
                "bytes_hbm": cost.bytes_hbm, "macs": cost.macs,
                "mem_bytes": cost.mem_bytes, "n_tiles": cost.n_tiles,
                "feasible": cost.feasible,
                "latency_s": cost.latency_s, "plan_bytes": cost.plan_bytes,
                "halo_bytes": cost.halo_bytes,
                "pipeline_depth": hw.pipeline_depth,
                # raw (uncalibrated) roofline terms: the calibration fit
                # regresses measured time on these, so an already
                # calibrated trace never feeds back into its own fit
                "t_mem_raw": cost.t_mem_raw, "t_compute_raw": cost.t_compute_raw,
                "calibrated": cost.calibrated,
            })
        if all(tiles.get(v, free[v]) >= free[v] for v in free) and cost.feasible:
            # whole op fits in one tile: keep flat, mark it
            s.add_tag("fits_inner")
            s.comments = f"autotile: single tile ({cost.why or 'fits'})"
            new_stmts.append(s)
            continue
        outer = split_block(s, tiles)
        outer.add_tag("autotiled")
        outer.comments = (
            f"autotile: tiles={tiles} cost={cost.cost:.3e} "
            f"(mem={cost.mem_bytes}B tiles={cost.n_tiles})"
        )
        new_stmts.append(outer)
    prog.entry.stmts = new_stmts
    return prog
