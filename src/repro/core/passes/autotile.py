"""Autotiling (paper §3.3).

Explores a space of tile shapes under memory-capacity and stencil-multiple
constraints with a cost function (cache-lines/MAC or TPU roofline, per the
hardware config) and rewrites the chosen tiling via ``split_block``.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Mapping, Optional, Tuple

from ..cost import TileCost, evaluate_tiling
from ..hwconfig import HardwareConfig
from ..ir import Block, Program
from ..poly import factors
from ..tiling import split_block
from . import register


def _candidates(r: int, search: str) -> List[int]:
    if search == "divisors":
        return factors(r)
    if search == "exhaustive":
        return list(range(1, r + 1))
    # pow2 (default): powers of two up to r, plus r itself
    out = []
    t = 1
    while t < r:
        out.append(t)
        t *= 2
    out.append(r)
    return out


def choose_tiling(block: Block, hw: HardwareConfig, params: Mapping) -> Tuple[Dict[str, int], TileCost]:
    free = {i.name: i.range for i in block.idxs if not i.is_passthrough()}
    search = params.get("search", "pow2")
    names = sorted(free)
    cands = {v: _candidates(free[v], search) for v in names}
    # multiples of an existing stencil (tags like "stencil:v=8")
    for t in block.tags:
        if t.startswith("stencil:"):
            v, m = t.split(":")[1].split("=")
            m = int(m)
            cands[v] = [c for c in cands[v] if c % m == 0] or [m]

    n_combos = 1
    for v in names:
        n_combos *= len(cands[v])
    max_combos = params.get("max_combos", 200_000)
    if n_combos > max_combos:
        # coordinate-descent fallback: greedy per-dim refinement
        return _coordinate_descent(block, hw, params, free, cands)

    best: Optional[Tuple[Dict[str, int], TileCost]] = None
    for combo in itertools.product(*(cands[v] for v in names)):
        tiles = dict(zip(names, combo))
        c = evaluate_tiling(block, tiles, hw, params)
        if not c.feasible:
            continue
        if best is None or c.cost < best[1].cost:
            best = (tiles, c)
    if best is None:
        # nothing feasible: fall back to all-ones tiles (always fits)
        tiles = {v: 1 for v in names}
        return tiles, evaluate_tiling(block, tiles, hw, params)
    return best


def _coordinate_descent(block, hw, params, free, cands):
    tiles = {v: c[-1] for v, c in cands.items()}
    cost = evaluate_tiling(block, tiles, hw, params)
    for _ in range(6):
        improved = False
        for v in sorted(free):
            best_t, best_c = tiles[v], cost
            for t in cands[v]:
                trial = dict(tiles)
                trial[v] = t
                c = evaluate_tiling(block, trial, hw, params)
                if c.feasible and (not best_c.feasible or c.cost < best_c.cost):
                    best_t, best_c = t, c
                    improved = True
            tiles[v] = best_t
            cost = best_c
        if not improved:
            break
    return tiles, cost


@register("autotile")
def autotile_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    new_stmts = []
    for s in prog.entry.stmts:
        if not isinstance(s, Block) or not ({"contraction", "elementwise"} & s.tags) or "grid" in s.tags:
            new_stmts.append(s)
            continue
        tiles, cost = choose_tiling(s, hw, params)
        free = {i.name: i.range for i in s.idxs if not i.is_passthrough()}
        if all(tiles.get(v, free[v]) >= free[v] for v in free) and cost.feasible:
            # whole op fits in one tile: keep flat, mark it
            s.add_tag("fits_inner")
            s.comments = f"autotile: single tile ({cost.why or 'fits'})"
            new_stmts.append(s)
            continue
        outer = split_block(s, tiles)
        outer.add_tag("autotiled")
        outer.comments = (
            f"autotile: tiles={tiles} cost={cost.cost:.3e} "
            f"(mem={cost.mem_bytes}B tiles={cost.n_tiles})"
        )
        new_stmts.append(outer)
    prog.entry.stmts = new_stmts
    return prog
