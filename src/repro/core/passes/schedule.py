"""Scheduling (paper §2.3 / §3.2): build the dependency DAG between block
statements from refinement aliasing, order them, mark independent groups
parallel, and assign inner-memory addresses to tile views (arena style).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, Set, Tuple

from ..hwconfig import HardwareConfig
from ..ir import Block, Program, RefDir, dtype_bytes
from . import register

ARENA_ALIGN = 512  # bytes; every inner-memory view starts on this boundary


def arena_bytes(sizes: Iterable[int]) -> int:
    """Total arena bytes the address assigner would consume for views of
    the given byte sizes (each allocation rounded up to ``ARENA_ALIGN``).
    The fusion cost model uses this to price a candidate group's VMEM
    pressure with exactly the allocator's arithmetic."""
    addr = 0
    for size in sizes:
        addr += (int(size) + ARENA_ALIGN - 1) & ~(ARENA_ALIGN - 1)
    return addr


def dependency_dag(blocks: List[Block]) -> List[Set[int]]:
    """deps[i] = set of j<i that statement i must wait for (RAW/WAR/WAW)."""
    deps: List[Set[int]] = [set() for _ in blocks]
    for i, b in enumerate(blocks):
        my_r = {r.from_buf for r in b.refs if r.dir in (RefDir.IN, RefDir.INOUT)}
        my_w = {r.from_buf for r in b.refs if r.dir in (RefDir.OUT, RefDir.INOUT)}
        for j in range(i):
            o = blocks[j]
            o_r = {r.from_buf for r in o.refs if r.dir in (RefDir.IN, RefDir.INOUT)}
            o_w = {r.from_buf for r in o.refs if r.dir in (RefDir.OUT, RefDir.INOUT)}
            if (my_r & o_w) or (my_w & o_r) or (my_w & o_w):
                deps[i].add(j)
    return deps


def wavefronts(deps: List[Set[int]]) -> List[int]:
    """Earliest-start level per statement (independent stmts share levels)."""
    level = [0] * len(deps)
    for i in range(len(deps)):
        level[i] = 1 + max((level[j] for j in deps[i]), default=-1)
    return level


def program_arena_peak(prog: Program) -> int:
    """Largest scheduled arena (bytes) across the program's grid blocks,
    read back from the ``arena:<bytes>`` tags the pass leaves — the VMEM
    pressure axis of the explore subsystem's Pareto report."""
    peak = 0
    for s in prog.entry.stmts:
        if not isinstance(s, Block):
            continue
        for g in s.walk():
            for t in g.tags:
                if t.startswith("arena:"):
                    peak = max(peak, int(t.split(":", 1)[1]))
    return peak


@register("schedule")
def schedule_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    report = params.get("_report")
    blocks = [s for s in prog.entry.stmts if isinstance(s, Block)]
    deps = dependency_dag(blocks)
    levels = wavefronts(deps)
    for b, lvl in zip(blocks, levels):
        b.add_tag(f"sched:{lvl}")

    # arena address assignment for inner-memory views inside each grid block
    unit = params.get("unit", hw.inner_mem().name)
    for b in blocks:
        for g in b.walk():
            if "grid" not in g.tags:
                continue
            addr = 0
            for inner in g.sub_blocks():
                for r in inner.refs:
                    if r.location is not None and r.location.unit == unit and r.location.addr is None:
                        size = dtype_bytes(r.dtype)
                        for s in r.shape:
                            size *= s
                        from ..ir import Location

                        r.location = Location(unit=r.location.unit, bank=r.location.bank, addr=addr)
                        addr += arena_bytes([size])
            if addr > 0:
                g.add_tag(f"arena:{addr}")
                if report is not None:
                    report.append({"block": b.name, "arena_bytes": addr})
    return prog
