"""Scheduling (paper §2.3 / §3.2): build the dependency DAG between block
statements from refinement aliasing, order them, mark independent groups
parallel, and run the **liveness-driven memory planner** (core/memplan.py)
over the wavefront-scheduled statement order — per-block VMEM arenas with
interval-graph best-fit slot allocation (streamed views double-buffered to
the hardware's ``pipeline_depth``, grid-invariant views resident in one
slot, revisited outputs one slot plus their f32 partial-sum scratch), plus
a program-level arena packed across wavefront levels.

Every planned block is tagged ``arena:<bytes>`` (the planner's peak) and
``arena_bump:<bytes>`` (the same views under the legacy no-reuse,
blanket-double-buffer model) so reports and benchmarks can show the
before/after; the pass report carries per-block wavefront levels, both
arena figures, and the packed program plan — the inputs of
``cost.score_pass_trace``'s pipelined wavefront latency model.

``params["memplan"] = False`` restores the legacy bump assignment.
"""
from __future__ import annotations

from typing import Iterable, List, Mapping, Set

from .. import memplan
from ..hwconfig import HardwareConfig
from ..ir import Block, Program, RefDir, dtype_bytes
from . import register

ARENA_ALIGN = memplan.ARENA_ALIGN


def arena_bytes(sizes: Iterable[int]) -> int:
    """Total arena bytes a no-reuse bump assigner consumes for views of
    the given byte sizes (each allocation rounded up to ``ARENA_ALIGN``).
    Kept as the legacy pricing primitive (``memplan=False`` paths)."""
    addr = 0
    for size in sizes:
        addr += memplan.align_up(size)
    return addr


def dependency_dag(blocks: List[Block]) -> List[Set[int]]:
    """deps[i] = set of j<i that statement i must wait for (RAW/WAR/WAW)."""
    deps: List[Set[int]] = [set() for _ in blocks]
    for i, b in enumerate(blocks):
        my_r = {r.from_buf for r in b.refs if r.dir in (RefDir.IN, RefDir.INOUT)}
        my_w = {r.from_buf for r in b.refs if r.dir in (RefDir.OUT, RefDir.INOUT)}
        for j in range(i):
            o = blocks[j]
            o_r = {r.from_buf for r in o.refs if r.dir in (RefDir.IN, RefDir.INOUT)}
            o_w = {r.from_buf for r in o.refs if r.dir in (RefDir.OUT, RefDir.INOUT)}
            if (my_r & o_w) or (my_w & o_r) or (my_w & o_w):
                deps[i].add(j)
    return deps


def wavefronts(deps: List[Set[int]]) -> List[int]:
    """Earliest-start level per statement (independent stmts share levels)."""
    level = [0] * len(deps)
    for i in range(len(deps)):
        level[i] = 1 + max((level[j] for j in deps[i]), default=-1)
    return level


def program_arena_peak(prog: Program) -> int:
    """Largest planned arena (bytes) across the program's blocks, read
    back from the ``arena:<bytes>`` tags the pass leaves — the VMEM
    pressure axis of the explore subsystem's Pareto report."""
    peak = 0
    for s in prog.entry.stmts:
        if not isinstance(s, Block):
            continue
        for g in s.walk():
            for t in g.tags:
                if t.startswith("arena:"):
                    peak = max(peak, int(t.split(":", 1)[1]))
    return peak


def _legacy_bump_assign(b: Block, unit: str, report) -> None:
    """The pre-planner behavior: walk grid blocks and bump-assign inner
    view addresses with zero reuse."""
    from ..ir import Location

    for g in b.walk():
        if "grid" not in g.tags:
            continue
        addr = 0
        for inner in g.sub_blocks():
            for r in inner.refs:
                if r.location is not None and r.location.unit == unit and r.location.addr is None:
                    size = dtype_bytes(r.dtype)
                    for s in r.shape:
                        size *= s
                    r.location = Location(unit=r.location.unit, bank=r.location.bank, addr=addr)
                    addr += arena_bytes([size])
        if addr > 0:
            g.add_tag(f"arena:{addr}")
            if report is not None:
                report.append({"block": b.name, "arena_bytes": addr})


@register("schedule")
def schedule_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    report = params.get("_report")
    blocks = [s for s in prog.entry.stmts if isinstance(s, Block)]
    deps = dependency_dag(blocks)
    levels = wavefronts(deps)
    for b, lvl in zip(blocks, levels):
        b.add_tag(f"sched:{lvl}")

    unit = params.get("unit", hw.inner_mem().name)
    if not params.get("memplan", True):
        for b in blocks:
            _legacy_bump_assign(b, unit, report)
        return prog

    # liveness-driven memory planning over the wavefront-scheduled order
    plan = memplan.plan_program(list(zip(blocks, levels)), depth=hw.pipeline_depth)
    for b, lvl in zip(blocks, levels):
        bp = plan.block_plans.get(b.name)
        if bp is None:
            continue
        memplan.assign_addresses(b, bp, unit)
        if bp.peak_bytes > 0:
            b.add_tag(f"arena:{bp.peak_bytes}", f"arena_bump:{bp.bump_bytes}")
        if report is not None:
            rec = {"block": b.name, "level": lvl,
                   "arena_bytes": bp.peak_bytes,
                   "arena_bump_bytes": bp.bump_bytes,
                   "acc_bytes": bp.acc_bytes,
                   "depth": bp.depth}
            if bp.halo_bytes:
                # halo-windowed streamed slots: margin bytes the pipeline
                # re-fetches each grid step (slot = tile + this margin)
                rec["halo_bytes"] = bp.halo_bytes
            report.append(rec)
    if report is not None:
        report.append({"program_plan": plan.to_json()})
    return prog
