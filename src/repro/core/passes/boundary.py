"""Separating interior and boundary tiles (paper §2.3).

For a tiled ("grid") block whose constraints only bite at the edges of an
outer index (overflow tiles from non-dividing tile sizes, or conv halos),
split that index range into interior/boundary pieces and drop every
constraint that the interior piece provably satisfies — the interior
block becomes constraint-free (dense, vectorizable), and irregularity is
confined to the boundary blocks.
"""
from __future__ import annotations

from typing import List, Mapping

from ..hwconfig import HardwareConfig
from ..ir import Block, Program
from ..tiling import outer_bounds_of, prune_constraints, shift_index
from . import register


def _n_constraints(blk: Block) -> int:
    n = len(blk.constraints)
    for s in blk.stmts:
        if isinstance(s, Block):
            n += _n_constraints(s)
    return n


def split_boundary(outer: Block, mode: str = "remainder", max_splits: int = 2) -> List[Block]:
    """Returns a list of blocks that partition ``outer``'s iteration space."""
    pieces = [outer]
    splits_done = 0
    for idx in list(outer.idxs):
        if idx.is_passthrough() or idx.range < 2 or splits_done >= max_splits:
            continue
        v, n = idx.name, idx.range
        cut_points = [n - 1] if mode == "remainder" else sorted({1, n - 1})
        new_pieces: List[Block] = []
        for p in pieces:
            if not any(i.name == v and i.range == n for i in p.idxs):
                new_pieces.append(p)
                continue
            base = _n_constraints(p)
            # try splitting at the last tile (remainder) and optionally first
            segs = []
            prev = 0
            for c in cut_points:
                if c > prev:
                    segs.append((prev, c))
                prev = c
            segs.append((prev, n))
            cand = []
            for lo, hi in segs:
                piece = shift_index(p, v, hi - lo, lo)
                prune_constraints(piece, outer_bounds_of(piece))
                cand.append(piece)
            if sum(_n_constraints(c) for c in cand) < base * len(cand) and any(
                _n_constraints(c) < base for c in cand
            ):
                for k, c in enumerate(cand):
                    c.name = f"{p.name}.{v}{k}"
                    c.add_tag("boundary_split")
                new_pieces.extend(cand)
                splits_done += 1
            else:
                new_pieces.append(p)
        pieces = new_pieces
    return pieces


@register("boundary")
def boundary_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    mode = params.get("mode", "remainder")
    new_stmts = []
    for s in prog.entry.stmts:
        if isinstance(s, Block) and "grid" in s.tags and _n_constraints(s) > 0:
            new_stmts.extend(split_boundary(s, mode=mode, max_splits=params.get("max_splits", 2)))
        else:
            new_stmts.append(s)
    prog.entry.stmts = new_stmts
    return prog
