"""Separating interior and boundary tiles (paper §2.3).

For a tiled ("grid") block whose constraints only bite at the edges of an
outer index (overflow tiles from non-dividing tile sizes, or conv halos),
split that index range into interior/boundary pieces and drop every
constraint that the interior piece provably satisfies — the interior
block becomes constraint-free (dense, vectorizable), and irregularity is
confined to the boundary blocks.
"""
from __future__ import annotations

from typing import List, Mapping

from ..hwconfig import HardwareConfig
from ..ir import Block, Program
from ..tiling import outer_bounds_of, prune_constraints, shift_index
from . import register


def _n_constraints(blk: Block) -> int:
    n = len(blk.constraints)
    for s in blk.stmts:
        if isinstance(s, Block):
            n += _n_constraints(s)
    return n


def split_boundary(outer: Block, mode: str = "remainder", max_splits: int = 2) -> List[Block]:
    """Returns a list of blocks that partition ``outer``'s iteration space.

    ``max_splits`` is a **per-index** budget: each index may cut at most
    that many pieces, and one index's splits never consume another's
    budget (a 2-D conv splits both spatial axes; the old global budget
    left the second axis unsplit and constraint-carrying).

    Pieces are named deterministically by the *segment start* of every
    split index (``<name>.<idx><lo>``), so a piece covering the same
    sub-range always gets the same name regardless of how many sibling
    segments the mode produced — stable keys for the tiling oracle, the
    memory-plan tags, and the pass trace.  Pieces the constraint pruning
    proved constraint-free are tagged ``interior`` (the Pallas emitter
    trusts the proof and lowers them densely, without re-deriving the
    constraints); the rest are tagged ``boundary`` (masked-store path)."""
    pieces = [outer]
    for idx in list(outer.idxs):
        if idx.is_passthrough() or idx.range < 2:
            continue
        v, n = idx.name, idx.range
        cut_points = [n - 1] if mode == "remainder" else sorted({1, n - 1})
        splits_this_idx = 0
        new_pieces: List[Block] = []
        for p in pieces:
            if splits_this_idx >= max_splits or not any(
                i.name == v and i.range == n for i in p.idxs
            ):
                new_pieces.append(p)
                continue
            base = _n_constraints(p)
            # try splitting at the last tile (remainder) and optionally first
            segs = []
            prev = 0
            for c in cut_points:
                if c > prev:
                    segs.append((prev, c))
                prev = c
            segs.append((prev, n))
            cand = []
            for lo, hi in segs:
                piece = shift_index(p, v, hi - lo, lo)
                prune_constraints(piece, outer_bounds_of(piece))
                cand.append(piece)
            if sum(_n_constraints(c) for c in cand) < base * len(cand) and any(
                _n_constraints(c) < base for c in cand
            ):
                for (lo, _hi), c in zip(segs, cand):
                    c.name = f"{p.name}.{v}{lo}"
                    c.add_tag("boundary_split")
                new_pieces.extend(cand)
                splits_this_idx += 1
            else:
                new_pieces.append(p)
        pieces = new_pieces
    for p in pieces:
        if "boundary_split" in p.tags:
            p.add_tag("interior" if _n_constraints(p) == 0 else "boundary")
    return pieces


@register("boundary")
def boundary_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    mode = params.get("mode", "remainder")
    new_stmts = []
    for s in prog.entry.stmts:
        if isinstance(s, Block) and "grid" in s.tags and _n_constraints(s) > 0:
            new_stmts.extend(split_boundary(s, mode=mode, max_splits=params.get("max_splits", 2)))
        else:
            new_stmts.append(s)
    prog.entry.stmts = new_stmts
    return prog
