"""Microarchitectural transposition (paper §2.3).

Contractions bound for the MXU want each operand's contiguous (stride-1)
dimension to carry either the reduction index or the output's contiguous
index.  Operands violating this (e.g. ``A[c, i]`` in ``O[i,j] += A[c,i] *
B[c,j]`` read column-major) are relaid: the pass inserts an explicit
transpose-copy op producing a permuted temporary and rewrites the
contraction to read it.
"""
from __future__ import annotations

from typing import Mapping

from ..affine import Affine
from ..hwconfig import HardwareConfig
from ..ir import Block, Load, Program, RefDir, Refinement, Store, TensorDecl, row_major_strides
from ..lower_jnp import analyze_flat, _product_leaves
from . import register


def _single_var(e) -> str | None:
    if len(e.terms) == 1 and e.const == 0 and e.terms[0][1] == 1:
        return e.terms[0][0]
    return None


@register("transpose")
def transpose_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    new_stmts = []
    n_tr = 0
    for s in prog.entry.stmts:
        if not (isinstance(s, Block) and "contraction" in s.tags and "grid" not in s.tags):
            new_stmts.append(s)
            continue
        try:
            op = analyze_flat(s)
            prod = _product_leaves(op.root)
        except ValueError:
            new_stmts.append(s)
            continue
        if prod is None or not op.out_vars:
            new_stmts.append(s)
            continue
        leaves, _ = prod
        n_var = op.out_vars[-1]
        red_vars = {v for v in op.ranges if v not in op.out_vars}
        for leaf in leaves:
            ref = leaf.ref
            if ref.rank != 2 or ref.dir != RefDir.IN:
                continue
            last = _single_var(ref.offsets[-1])
            first = _single_var(ref.offsets[0])
            if last is None or first is None:
                continue
            # bad layout: contiguous dim carries a non-contiguous output var
            if last not in red_vars and last != n_var and (first in red_vars or first == n_var):
                src = ref.from_buf
                decl = prog.buffers[src]
                t_name = f"{src}_T{n_tr}"
                n_tr += 1
                tshape = (decl.shape[1], decl.shape[0])
                prog.buffers[t_name] = TensorDecl(t_name, tshape, decl.dtype)
                prog.entry.refs.append(
                    Refinement(dir=RefDir.INOUT, from_buf=t_name, into=t_name,
                               offsets=(Affine.var("a") * 0, Affine.var("a") * 0),
                               shape=tshape, dtype=decl.dtype,
                               strides=row_major_strides(tshape)))
                # transpose copy block: T[a,b] = S[b,a]
                tb = Block(name=f"transpose_{src}", tags={"elementwise", "transpose"})
                from ..poly import Index

                tb.idxs = [Index("a", tshape[0]), Index("b", tshape[1])]
                tb.refs = [
                    Refinement(dir=RefDir.IN, from_buf=src, into="S",
                               offsets=(Affine.var("b"), Affine.var("a")),
                               shape=(1, 1), dtype=decl.dtype,
                               strides=row_major_strides(decl.shape)),
                    Refinement(dir=RefDir.OUT, from_buf=t_name, into="T",
                               offsets=(Affine.var("a"), Affine.var("b")),
                               shape=(1, 1), dtype=decl.dtype, agg="assign",
                               strides=row_major_strides(tshape)),
                ]
                tb.stmts = [Load("S", "v"), Store("T", "v")]
                new_stmts.append(tb)
                # rewrite the contraction operand
                ref.from_buf = t_name
                ref.offsets = (ref.offsets[1], ref.offsets[0])
                ref.strides = row_major_strides(tshape)
                s.add_tag("transposed_operand")
        new_stmts.append(s)
    prog.entry.stmts = new_stmts
    return prog
