"""Microarchitectural stenciling (paper §2.3).

Finds tiled contraction blocks whose inner tile can be reshaped to the
hardware stencil (e.g. the MXU's 128x128x128 systolic matmul) and splits
them again so the innermost block matches the stencil exactly, tagging it
with the compute-unit name for the lowerer.
"""
from __future__ import annotations

from typing import Mapping

from ..hwconfig import HardwareConfig
from ..ir import Block, Program, RefDir
from ..tiling import split_block
from . import register


def _roles(block: Block):
    """Classify free idxs of a flat contraction block into (out_vars,
    reduction_vars) from the OUT refinement's access."""
    out_ref = None
    for r in block.refs:
        if r.dir in (RefDir.OUT, RefDir.INOUT):
            out_ref = r
    if out_ref is None:
        return [], []
    out_vars = []
    for e in out_ref.offsets:
        for n in e.names():
            if n not in out_vars:
                out_vars.append(n)
    free = [i.name for i in block.idxs if not i.is_passthrough()]
    red = [v for v in free if v not in out_vars]
    return [v for v in out_vars if v in free], red


@register("stencil")
def stencil_pass(prog: Program, hw: HardwareConfig, params: Mapping) -> Program:
    sten = None
    for s in hw.stencils:
        if s.name == params.get("stencil", "mxu"):
            sten = s
    if sten is None:
        return prog
    min_dim = params.get("min_dim", 16)

    def visit(blk: Block) -> None:
        for i, s in enumerate(blk.stmts):
            if not isinstance(s, Block):
                continue
            flat = "contraction" in s.tags and not s.sub_blocks() and "stenciled" not in s.tags
            if flat and ("tile" in s.tags or "fits_inner" in s.tags or "grid" not in s.tags):
                out_vars, red = _roles(s)
                if not out_vars or not red:
                    continue
                n_var = out_vars[-1]
                k_var = max(red, key=lambda v: s.idx(v).range)
                m_var = out_vars[-2] if len(out_vars) >= 2 else None
                ranges = {x.name: x.range for x in s.idxs if not x.is_passthrough()}
                tiles = {}
                for var, mult in ((m_var, sten.dims[0]), (n_var, sten.dims[1]), (k_var, sten.dims[2])):
                    if var is None:
                        continue
                    r = ranges[var]
                    if r >= max(mult, min_dim) and r % mult == 0 and r > mult:
                        tiles[var] = mult
                if not tiles:
                    # already stencil-sized (or too small): just tag it
                    if all(ranges.get(v, 0) <= d for v, d in ((m_var, sten.dims[0]), (n_var, sten.dims[1]), (k_var, sten.dims[2])) if v):
                        s.add_tag(sten.name)
                        if not s.constraints:
                            # proof for the lowerer: the stencil fit was
                            # established on an unconstrained tile — no
                            # masking needed to feed the compute unit
                            s.add_tag("dense")
                    continue
                new = split_block(s, tiles, name_suffix="s")
                if "tile" in s.tags:
                    # splitting the inner tile of an existing grid: the new
                    # outer stays a tile of its parent grid
                    new.tags = (new.tags - {"grid"}) | {"tile", "stenciled"}
                else:
                    new.tags = new.tags | {"stenciled"}
                inner = new.stmts[0]
                assert isinstance(inner, Block)
                inner.add_tag(sten.name, "stenciled")
                if not inner.constraints:
                    inner.add_tag("dense")
                blk.stmts[i] = new
            else:
                visit(s)

    visit(prog.entry)
    return prog
