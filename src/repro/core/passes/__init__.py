"""Optimization pass framework (paper §1.3: "selects and parameterizes a
list of optimization passes from a common pool; these passes are then
iteratively applied to the IR").

Passes are generic and hardware-agnostic; the hardware config selects and
parameterizes them.  Each pass maps Program -> Program.

The pass manager threads two compilation-cache hooks through the pipeline
(both injected into pass params under private ``_``-prefixed keys, which
are never part of a pass's own parameterization):

* a ``TilingOracle`` that records the autotiler's chosen tilings on a cold
  compile and replays them on a warm one, skipping the search entirely;
* an ``autotune_workers`` override enabling the parallel candidate search.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Mapping, Optional, Tuple

from ...obs import trace as obs_trace
from ..hwconfig import HardwareConfig
from ..ir import Program

PassFn = Callable[[Program, HardwareConfig, Mapping], Program]

_REGISTRY: Dict[str, PassFn] = {}


def register(name: str):
    def deco(fn: PassFn) -> PassFn:
        _REGISTRY[name] = fn
        return fn
    return deco


def get_pass(name: str) -> PassFn:
    if name not in _REGISTRY:
        from . import autotile, boundary, fuse, localize, partition, schedule, stencil, transpose  # noqa: F401
    return _REGISTRY[name]


class TilingOracle:
    """Record/replay store for autotile decisions, keyed by block name +
    content fingerprint (``autotile._oracle_key``) so a fused group's
    tiling replays for exactly the group it was chosen for.

    Cold compile: every searched tiling is recorded into ``chosen``.
    Warm compile: construct with ``known`` (e.g. loaded from the on-disk
    cache) and the autotile pass replays those tilings instead of
    searching, re-evaluating only the (cheap) cost of the known choice.
    """

    def __init__(self, known: Optional[Mapping[str, Mapping[str, int]]] = None):
        self.known: Dict[str, Dict[str, int]] = {
            name: {v: int(t) for v, t in tiles.items()}
            for name, tiles in (known or {}).items()
        }
        self.chosen: Dict[str, Dict[str, int]] = {}
        self.replays = 0
        self.searches = 0

    def lookup(self, block_name: str) -> Optional[Dict[str, int]]:
        return self.known.get(block_name)

    def record(self, block_name: str, tiles: Mapping[str, int]) -> None:
        self.chosen[block_name] = dict(tiles)


class PassManager:
    def __init__(self, hw: HardwareConfig, oracle: Optional[TilingOracle] = None,
                 autotune_workers: Optional[int] = None):
        self.hw = hw
        self.oracle = oracle
        self.autotune_workers = autotune_workers
        # (pass name, public params[, report]) in application order —
        # JSON-able, so the driver can persist it as the compile's pass
        # trace.  A pass can append structured decision records (e.g. the
        # fusion pass's accepted/rejected merges) to the injected
        # ``params["_report"]`` list; non-empty reports become the trace
        # entry's third element.
        self.trace: List[Tuple] = []

    def run(self, prog: Program) -> Program:
        import copy

        from . import autotile, boundary, fuse, localize, partition, schedule, stencil, transpose  # noqa: F401

        source = prog.source or copy.deepcopy(prog)
        for name, params in self.hw.passes:
            fn = _REGISTRY[name]
            run_params = dict(params)
            if name == "autotile":
                if self.oracle is not None:
                    run_params["_oracle"] = self.oracle
                if self.autotune_workers is not None and "workers" not in run_params:
                    run_params["workers"] = self.autotune_workers
            report: List = []
            run_params["_report"] = report
            with obs_trace.span(f"pass.{name}", hw=self.hw.name) as sp:
                prog = fn(prog, self.hw, run_params)
                sp.set(report_entries=len(report))
            entry = (name, dict(params), report) if report else (name, dict(params))
            self.trace.append(entry)
        prog.source = source
        return prog


def compile_program(prog: Program, hw: HardwareConfig) -> Program:
    return PassManager(hw).run(prog)
