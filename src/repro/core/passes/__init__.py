"""Optimization pass framework (paper §1.3: "selects and parameterizes a
list of optimization passes from a common pool; these passes are then
iteratively applied to the IR").

Passes are generic and hardware-agnostic; the hardware config selects and
parameterizes them.  Each pass maps Program -> Program.
"""
from __future__ import annotations

from typing import Callable, Dict, Mapping

from ..hwconfig import HardwareConfig
from ..ir import Program

PassFn = Callable[[Program, HardwareConfig, Mapping], Program]

_REGISTRY: Dict[str, PassFn] = {}


def register(name: str):
    def deco(fn: PassFn) -> PassFn:
        _REGISTRY[name] = fn
        return fn
    return deco


def get_pass(name: str) -> PassFn:
    if name not in _REGISTRY:
        from . import autotile, boundary, fuse, localize, partition, schedule, stencil, transpose  # noqa: F401
    return _REGISTRY[name]


class PassManager:
    def __init__(self, hw: HardwareConfig):
        self.hw = hw
        self.trace: list = []

    def run(self, prog: Program) -> Program:
        import copy

        from . import autotile, boundary, fuse, localize, partition, schedule, stencil, transpose  # noqa: F401

        source = prog.source or copy.deepcopy(prog)
        for name, params in self.hw.passes:
            fn = _REGISTRY[name]
            prog = fn(prog, self.hw, params)
            self.trace.append(name)
        prog.source = source
        return prog


def compile_program(prog: Program, hw: HardwareConfig) -> Program:
    return PassManager(hw).run(prog)
