"""Block splitting — the rewrite at the heart of §3.3.

``split_block`` turns a flat parallel polyhedral block into an outer
"grid" block iterating over tiles and an inner block iterating within a
tile, exactly as in the paper's Fig. 5:

* index ``v`` (range R, tile T) becomes outer ``v``: ``ceil(R/T)`` and
  inner ``v_i``: ``T``;
* accesses are rewritten via the substitution ``v -> T*v + v_i``; each
  refinement splits into an outer view (offset = the outer-index part +
  the minimum inner contribution; shape = inner span + 1 — which is how
  the conv halo manifests as view size 5 with stride-3 steps in Fig. 5b)
  and an inner view relative to it;
* when T does not divide R the outer range is rounded up and an overflow
  constraint ``R-1 - (T*v + v_i) >= 0`` is added to the inner block,
  referencing the explicitly-passed parent index ``v``;
* pre-existing constraints are substituted and pulled into the inner
  block (paper: "the existing constraints can be pulled into the inner
  block").
"""
from __future__ import annotations

import copy
from typing import Dict, List, Mapping, Tuple

from .affine import Affine, aff
from .ir import Block, Refinement
from .poly import Constraint, Index, ceil_div


def _inner_min_span(expr: Affine, inner_ranges: Mapping[str, int]) -> Tuple[int, int]:
    """(min contribution, span) of the inner-variable part of ``expr``."""
    mn = 0
    span = 0
    for n, c in expr.terms:
        if n in inner_ranges:
            r = inner_ranges[n]
            mn += min(0, c * (r - 1))
            span += abs(c) * (r - 1)
    return mn, span


def split_block(block: Block, tiles: Mapping[str, int], name_suffix: str = "t",
                full_tiles: bool = False) -> Block:
    """Split ``block`` by per-index tile sizes.  Indices absent from
    ``tiles`` (or with tile >= range) stay fully inner.  Returns the new
    outer block containing the inner block.

    With ``full_tiles=True`` an index whose tile equals its range still
    becomes a (range-1) grid dimension instead of staying inner — the
    canonical grid shape the Pallas backend expects even when the whole
    op fits one tile."""
    free = {i.name: i.range for i in block.idxs if not i.is_passthrough()}
    limit = (lambda t, r: t <= r) if full_tiles else (lambda t, r: t < r)
    tiled = {v: t for v, t in tiles.items() if v in free and limit(t, free[v])}

    # substitution on original index names
    subst = {v: Affine.var(v, t) + Affine.var(f"{v}_{name_suffix}") for v, t in tiled.items()}
    inner_ranges = {f"{v}_{name_suffix}": t for v, t in tiled.items()}
    inner_ranges.update({u: r for u, r in free.items() if u not in tiled})

    outer = Block(
        name=f"{block.name}.grid",
        idxs=[Index(v, ceil_div(free[v], t)) for v, t in tiled.items()],
        tags=set(block.tags) | {"grid"},
        passed=list(block.passed),
    )
    inner = Block(
        name=f"{block.name}.tile",
        idxs=(
            [Index(f"{v}_{name_suffix}", t) for v, t in tiled.items()]
            + [Index(u, r) for u, r in free.items() if u not in tiled]
            + [i for i in block.idxs if i.is_passthrough()]
        ),
        tags=(set(block.tags) - {"grid"}) | {"tile"},
        passed=list(block.passed) + sorted(tiled),
    )

    # ---- refinements ------------------------------------------------------
    for r in block.refs:
        if r.dir == "none":
            # iteration-local temporaries move inward with the iteration
            # (Def. 2: temporaries are not shared between iterations).
            inner.refs.append(r.clone())
            continue
        out_offs: List[Affine] = []
        in_offs: List[Affine] = []
        shape: List[int] = []
        for e, orig_extent in zip(r.offsets, r.shape):
            es = e.substitute(subst)
            mn, span = _inner_min_span(es, inner_ranges)
            outer_part = Affine.make(
                {n: c for n, c in es.terms if n not in inner_ranges}, es.const + mn
            )
            inner_part = es - outer_part  # inner terms minus mn
            out_offs.append(outer_part)
            in_offs.append(inner_part)
            shape.append(span + orig_extent)  # orig_extent is 1 for scalar views
        outer.refs.append(r.clone(offsets=tuple(out_offs), shape=tuple(shape)))
        inner.refs.append(r.clone(offsets=tuple(in_offs), from_buf=r.into))

    # ---- constraints ------------------------------------------------------
    for c in block.constraints:
        inner.constraints.append(Constraint(c.expr.substitute(subst)))
    for v, t in tiled.items():
        if free[v] % t != 0:
            # overflow removal: R-1 - (T*v + v_i) >= 0
            expr = aff(free[v] - 1) - (Affine.var(v, t) + Affine.var(f"{v}_{name_suffix}"))
            inner.constraints.append(Constraint(expr))

    new_names = [f"{v}_{name_suffix}" for v in tiled] + list(tiled)
    inner.stmts = []
    for s in block.stmts:
        if isinstance(s, Block):
            sub = substitute_block(s, subst)
            sub.passed = list(dict.fromkeys(sub.passed + new_names))
            inner.stmts.append(sub)
        else:
            inner.stmts.append(copy.deepcopy(s))
    outer.stmts = [inner]
    return outer


def substitute_block(block: Block, subst: Mapping[str, Affine]) -> Block:
    """Deep substitution of (parent) index names through a block tree.
    Local indices shadow: a name redefined by this block is not replaced
    inside it."""
    local = {i.name for i in block.idxs if not i.is_passthrough()}
    live = {k: v for k, v in subst.items() if k not in local}
    if not live:
        return block
    out = Block(
        name=block.name,
        idxs=[
            i if i.affine is None else Index(i.name, i.range, i.affine.substitute(live))
            for i in block.idxs
        ],
        constraints=[Constraint(c.expr.substitute(live)) for c in block.constraints],
        refs=[r.clone(offsets=tuple(o.substitute(live) for o in r.offsets)) for r in block.refs],
        tags=set(block.tags),
        passed=list(block.passed),
        comments=block.comments,
    )
    out.stmts = [
        substitute_block(s, live) if isinstance(s, Block) else copy.deepcopy(s)
        for s in block.stmts
    ]
    return out


def shift_index(block: Block, idx_name: str, new_range: int, shift: int) -> Block:
    """Clone ``block`` with index ``idx_name`` restricted to
    ``[shift, shift+new_range)`` (re-based at 0).  Inner content referencing
    the index (through ``passed``) is substituted ``v -> v + shift``."""
    nb = block.clone()
    nb.idxs = [Index(i.name, new_range, i.affine) if i.name == idx_name else i for i in nb.idxs]
    if shift:
        subst = {idx_name: Affine.var(idx_name) + shift}
        # own refs/constraints reference the shifted var directly
        nb.refs = [r.clone(offsets=tuple(o.substitute(subst) for o in r.offsets)) for r in nb.refs]
        nb.constraints = [Constraint(c.expr.substitute(subst)) for c in nb.constraints]
        nb.stmts = [
            substitute_block(s, subst) if isinstance(s, Block) else copy.deepcopy(s)
            for s in nb.stmts
        ]
    return nb


def outer_bounds_of(block: Block, parent: Mapping[str, Tuple[int, int]] | None = None) -> Dict[str, Tuple[int, int]]:
    b = dict(parent or {})
    for i in block.idxs:
        if not i.is_passthrough():
            b[i.name] = (0, i.range - 1)
    return b


def prune_constraints(block: Block, bounds: Mapping[str, Tuple[int, int]]) -> None:
    """Drop constraints provably satisfied over ``bounds`` (recursively)."""
    from .poly import Polyhedron

    poly = Polyhedron(block.idxs, block.constraints)
    keep = []
    for c in block.constraints:
        lo, _ = poly.expr_bounds(c.expr, bounds)
        if lo < 0:
            keep.append(c)
    block.constraints = keep
    inner_bounds = outer_bounds_of(block, bounds)
    for s in block.stmts:
        if isinstance(s, Block):
            prune_constraints(s, inner_bounds)
