"""The unified compile driver: frontend -> passes -> lowering, cached.

``stripe_jit`` is the single entry point tying the pieces together behind
the two-level compilation cache (``cache.py``):

1. the input (a ``Program``, ``TileProgram``, Tile contraction string, or
   a callable producing one) is built into a Stripe ``Program``;
2. a content key is computed from the canonical IR, the hardware config
   fingerprint, and the backend;
3. **memory hit** — the live ``CompiledProgram`` is returned immediately;
   **disk hit** — the persisted tilings replay through the pass pipeline
   via a ``TilingOracle`` (no autotile search); **miss** — the full
   pipeline runs (optionally with the parallel autotuner) and both cache
   levels are populated;
4. the optimized program is lowered by the requested backend:
   ``jnp`` (XLA via the reference lowering, jit'd), ``pallas`` (the tiled
   TPU kernel, falling back to jnp when the block shape is unsupported),
   or ``reference`` (the exact numpy interpreter).
"""
from __future__ import annotations

import copy
import dataclasses
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple, Union

from ..obs import profile as obs_profile
from ..obs import trace as obs_trace
from ..reliability import faults
from . import cache as _cache
from .frontend import TileProgram, single_op_program
from .hwconfig import HardwareConfig
from .interp import execute_reference
from .ir import Block, Program, ir_fingerprint
from .lower_jnp import lower_program_jnp
from .passes import PassManager, TilingOracle

DRIVER_VERSION = 1

BACKENDS = ("jnp", "pallas", "reference")


@dataclasses.dataclass
class CompileRecord:
    """What happened during one ``stripe_jit`` call."""

    key: str
    backend: str  # backend actually used (may record a pallas->jnp fallback)
    hw_name: str
    cache_hit: bool = False  # in-memory (same-process) hit
    disk_hit: bool = False  # tilings replayed from the on-disk store
    compile_time_s: float = 0.0
    tilings: Dict[str, Dict[str, int]] = dataclasses.field(default_factory=dict)
    pass_trace: List = dataclasses.field(default_factory=list)
    fallback_reason: str = ""
    # Per-group lowering: the semantic op-block names each fusion group
    # absorbed, and the kernel count — for the pallas backend this is the
    # actual pallas_call count per invocation plus one dispatch per
    # jnp-fallback unit; for jnp it is the fusion-group (compile-unit)
    # count, though the driver still wraps the whole program in one outer
    # jax.jit (use lower_program_jnp(jit_scope="group") for per-group
    # dispatch, as the fusion bench does); the reference interpreter
    # launches no kernels and reports 0.
    n_kernels: int = 0
    groups: List[List[str]] = dataclasses.field(default_factory=list)
    # Per-block hybrid lowering (pallas backend): which backend each
    # lowering unit (fusion group / boundary-piece set, keyed by its
    # "+"-joined member names) actually took, and why the jnp units fell
    # back.  Empty for whole-program backends.
    block_backends: Dict[str, str] = dataclasses.field(default_factory=dict)
    block_fallbacks: Dict[str, str] = dataclasses.field(default_factory=dict)
    # Compile-failure quarantine: True when this compile served the jnp
    # fallback because the Pallas lowering *crashed* (not a legality
    # fallback) now or within the backoff embargo; ``quarantine`` carries
    # the negative-cache entry (reason, fail_count, backoff_s, expired).
    quarantined: bool = False
    quarantine: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # Kernel profiling (``stripe_jit(..., profile=True)``): per lowering
    # unit, the cost model's predicted latency (autotile roofline,
    # summed over the unit's blocks) and the best measured wall time
    # observed across dispatches.  ``measured_latency_s`` fills in as the
    # compiled program runs (the dict is shared across cache-hit records
    # of the same artifact); (predicted, measured) pairs are appended to
    # the residual JSONL under the cache dir on the first dispatch.
    profiled: bool = False
    ir_fingerprint: str = ""
    hw_fingerprint: str = ""
    predicted_latency_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    measured_latency_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    # Per-unit roofline terms behind predicted_latency_s (latency_s,
    # t_mem/t_compute and their raw uncalibrated counterparts) — the
    # residual log carries them so the calibration fit regresses on raw
    # terms even after the model is already calibrated.
    predicted_terms: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    # Where the tilings came from: "analytic" (the autotile search or a
    # plain disk replay of its choice), "tuned" (a measured-best entry
    # served by the tuning DB — ``tuned`` carries the entry's provenance:
    # candidate id, measured latency, measurement source/rounds/age), or
    # "replay" (caller-supplied tilings via ``compile_with_tilings``).
    decision_source: str = "analytic"
    tuned: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # Multi-device provenance (``stripe_jit(..., mesh=)``): mesh shape /
    # axis / device count, the shard plan's split decisions, the emitted
    # collectives with their modelled bytes and overlap choices, and a
    # per-segment summary (each segment is its own cached single-device
    # compile).  ``{"fallback": reason, ...}`` when the partitioner found
    # no legal split and the program compiled single-device instead.
    mesh: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def fusion_decisions(self) -> List[Dict]:
        """Accepted/rejected merges recorded by the fusion pass."""
        for entry in self.pass_trace:
            if entry[0] == "fuse" and len(entry) > 2:
                return list(entry[2])
        return []

    def fallback_reasons(self) -> Dict[str, str]:
        """Every recorded Pallas fallback: per-unit reasons from the
        hybrid lowering, plus the whole-program reason (key
        ``"<program>"``) when the backend fell back wholesale."""
        out = dict(self.block_fallbacks)
        if self.fallback_reason:
            out["<program>"] = self.fallback_reason
        return out

    def latency_residuals(self) -> List[Dict[str, Any]]:
        """Per-unit (predicted, measured) latency pairs of a profiled
        compile — empty until the compiled program has dispatched."""
        return [{"block": u,
                 "predicted_s": self.predicted_latency_s.get(u),
                 "measured_s": m}
                for u, m in sorted(self.measured_latency_s.items())]


class CompiledProgram:
    """A compiled Stripe program: callable on a dict of input arrays,
    returning a dict of output arrays."""

    def __init__(self, program: Program, fn: Callable[[Mapping[str, Any]], Dict[str, Any]],
                 hw: HardwareConfig, record: CompileRecord):
        self.program = program
        self.hw = hw
        self.record = record
        self._fn = fn

    @property
    def outputs(self) -> List[str]:
        return list(self.program.outputs)

    def __call__(self, arrays: Mapping[str, Any]) -> Dict[str, Any]:
        return self._fn(arrays)


# --------------------------------------------------------------------------
# Input normalization
# --------------------------------------------------------------------------
def _as_program(fn_or_contraction, tensors=None, out=None, ranges=None, name="op") -> Program:
    obj = fn_or_contraction
    if callable(obj) and not isinstance(obj, (Program, TileProgram)):
        obj = obj()
    if isinstance(obj, TileProgram):
        obj = obj.build()
    if isinstance(obj, str):
        if tensors is None or out is None:
            raise ValueError("contraction-string input needs tensors= and out=")
        obj = single_op_program(obj, tensors, out=out, ranges=ranges, name=name)
    if not isinstance(obj, Program):
        raise TypeError(f"cannot compile {type(obj).__name__}; "
                        "expected Program, TileProgram, contraction str, or a callable producing one")
    return obj


# --------------------------------------------------------------------------
# Lowering
# --------------------------------------------------------------------------
def _semantic_groups(opt: Program) -> Optional[List[List[str]]]:
    """Fusion groups of the optimized program as lists of *semantic*
    op-block names (from each block's ``members:`` tag), or None when the
    mapping does not cover the semantic program exactly (e.g. after
    transpose-pass block insertion the driver lowers per op)."""
    from .passes.fuse import members_of

    semantic = opt.source
    if semantic is None:
        return None
    sem_names = {s.name for s in semantic.entry.stmts if isinstance(s, Block)}
    groups: List[List[str]] = []
    seen: set = set()
    for s in opt.entry.stmts:
        if not isinstance(s, Block):
            continue
        g = [n for n in members_of(s) if n in sem_names and n not in seen]
        if g:
            groups.append(g)
            seen.update(g)
    if seen != sem_names:
        return None
    return groups


def _program_groups(opt: Program) -> List[List[str]]:
    """Fusion groups (semantic-op name lists) of an optimized program,
    falling back to one group per semantic op when the mapping is not
    exact — the dispatch-unit count without any backend lowering."""
    semantic = opt.source or opt
    return _semantic_groups(opt) or [
        [s.name] for s in semantic.entry.stmts if isinstance(s, Block)]


@dataclasses.dataclass
class _Lowered:
    """What one backend lowering produced, for the CompileRecord."""

    fn: Callable
    backend: str
    fallback: str = ""
    n_kernels: int = 0
    groups: List[List[str]] = dataclasses.field(default_factory=list)
    block_backends: Dict[str, str] = dataclasses.field(default_factory=dict)
    block_fallbacks: Dict[str, str] = dataclasses.field(default_factory=dict)
    quarantined: bool = False
    quarantine: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _lower(opt: Program, backend: str, interpret: bool, jit: bool,
           hw: Optional[HardwareConfig] = None,
           quarantine: Optional[_cache.QuarantineStore] = None,
           key: str = "", profile: bool = False,
           force_jnp_units: Optional[set] = None) -> _Lowered:
    """Lower the optimized program.  For the pallas backend, a *crash*
    during lowering (as opposed to a known-unsupported legality fallback)
    degrades to the jnp path and negative-caches the key in
    ``quarantine`` with exponential backoff, so a bad (config, program)
    point serves degraded instead of failing the caller — and is not
    re-attempted until the embargo lapses."""
    semantic = opt.source or opt
    groups = _program_groups(opt)
    if backend == "reference":
        # the interpreter launches no kernels and ignores grouping
        fn = lambda arrays: execute_reference(semantic, arrays)  # noqa: E731
        return _Lowered(fn, backend, groups=groups)
    fallback = ""
    blk_backends: Dict[str, str] = {}
    blk_falls: Dict[str, str] = {}
    quarantined = False
    quar_info: Dict[str, Any] = {}
    if backend == "pallas":
        from .lower_pallas import UnsupportedPallas, lower_program_hybrid

        if quarantine is not None and quarantine.active(key):
            entry = quarantine.get(key)
            backend = "jnp"
            fallback = f"quarantined: {entry.reason}"
            quarantined, quar_info = True, entry.as_dict()
        else:
            try:
                faults.check("compile.stripe_jit", key=key, backend="pallas")
                # per-block hybrid: each fusion group / boundary-piece unit
                # lowers to Pallas or falls back to jnp independently
                with obs_trace.span("lower.pallas", interpret=interpret,
                                    profile=profile):
                    fn = lower_program_hybrid(
                        opt, interpret=interpret,
                        pipeline_depth=hw.pipeline_depth if hw is not None else 2,
                        profile=profile, force_jnp_units=force_jnp_units)
            except UnsupportedPallas as e:
                # legality fallback: deterministic and known, no quarantine
                backend, fallback = "jnp", str(e)
            except Exception as e:  # crash-class failure: quarantine the key
                backend = "jnp"
                fallback = f"compile crashed: {e!r}"
                quarantined = True
                if quarantine is not None:
                    quar_info = quarantine.record_failure(key, repr(e)).as_dict()
            else:
                if quarantine is not None and quarantine.get(key) is not None:
                    # the embargo had lapsed and the retry succeeded
                    quarantine.clear(key)
                if fn.n_pallas > 0:
                    return _Lowered(fn, "pallas", "", fn.n_kernels, groups,
                                    dict(fn.block_backends), dict(fn.block_reasons))
                # every unit fell back: take the whole-program jnp path below
                # (one outer jax.jit beats N independently-jitted dispatches),
                # keeping the per-unit reasons on the record
                backend = "jnp"
                fallback = "; ".join(f"{k}: {v}"
                                     for k, v in fn.block_reasons.items())
                blk_backends = dict(fn.block_backends)
                blk_falls = dict(fn.block_reasons)
    with obs_trace.span("lower.jnp", profile=profile):
        # profiled jnp lowering keeps per-group dispatch boundaries
        # (no outer jit) so each unit can be wall-timed individually
        fn = lower_program_jnp(semantic, groups=groups,
                               jit_scope="group" if profile else None,
                               profile=profile)
        n_kernels = fn.n_kernels
        if jit and not profile:
            import jax

            fn = jax.jit(fn)
    return _Lowered(fn, backend, fallback, n_kernels, groups,
                    blk_backends, blk_falls, quarantined, quar_info)


def _attach_profiling(low: _Lowered, record: CompileRecord,
                      cache: _cache.CompilationCache, interpret: bool,
                      tune_db=None, requested_backend: str = "") -> Callable:
    """Wrap a lowered callable so each dispatch folds the lowering's
    per-unit wall times into ``record.measured_latency_s`` (best
    observation wins; the dict is shared with cache-hit records of the
    same artifact) and the first dispatch appends (predicted, measured)
    rows to the residual JSONL under the cache dir — and, when a tuning
    DB is attached, records the program's measured latency under its
    compile identity, so profiled serving traffic *populates* the DB."""
    inner = low.fn
    unit_times = getattr(inner, "unit_times", None)
    state = {"logged": False}

    def wrapper(arrays):
        t0 = time.perf_counter()
        out = inner(arrays)
        if unit_times is not None:
            record.measured_latency_s.update(unit_times)
        else:
            # whole-program dispatch (reference interpreter): one unit
            try:
                import jax

                jax.block_until_ready(out)
            except Exception:
                pass
            dt = time.perf_counter() - t0
            prev = record.measured_latency_s.get("<program>")
            if prev is None or dt < prev:
                record.measured_latency_s["<program>"] = dt
        if not state["logged"] and record.measured_latency_s:
            state["logged"] = True
            obs_profile.append_residuals(
                obs_profile.residual_rows(record, interpret),
                obs_profile.residual_log_path(cache), db=tune_db)
            if tune_db is not None and record.tilings and record.ir_fingerprint:
                try:
                    tune_db.record(
                        record.ir_fingerprint, record.hw_fingerprint,
                        requested_backend or record.backend, interpret,
                        tilings=record.tilings,
                        measured_s=sum(record.measured_latency_s.values()),
                        predicted_s=(sum(record.predicted_latency_s.values())
                                     or None),
                        block_backends=record.block_backends,
                        source="profile")
                except Exception:
                    pass  # measurement feedback must never fail a dispatch
        return out

    return wrapper


# --------------------------------------------------------------------------
# Measured-feedback tuning support
# --------------------------------------------------------------------------
def _resolve_tune(tune, cache: _cache.CompilationCache):
    """Normalize the ``tune=`` argument: None/False disables, True opens
    the :class:`~repro.tune.db.TuningDB` next to the cache's disk store
    (or the default cache dir), and a ``TuningDB`` instance is used as
    given."""
    if tune is None or tune is False:
        return None
    from ..tune.db import TuningDB

    if isinstance(tune, TuningDB):
        return tune
    return TuningDB(dir=cache.disk_dir)


def _calibration_fp(hw_fp: str) -> str:
    """The active calibration's cache-key component for this hardware
    fingerprint ("" when the cost model is uncalibrated)."""
    from ..tune import calibrate

    return calibrate.active_fingerprint(hw_fp) if calibrate.any_active() else ""


# --------------------------------------------------------------------------
# Driver entry points
# --------------------------------------------------------------------------
def compile_cached(prog: Program, hw: HardwareConfig,
                   cache: Optional[_cache.CompilationCache] = None,
                   workers: Optional[int] = None,
                   use_disk: bool = True) -> Tuple[Program, CompileRecord]:
    """Run the pass pipeline under the compilation cache; no lowering.

    This is the sweep-friendly compile entry: no backend is built or
    executed, yet the record still carries the fusion groups / kernel
    count and the full pass trace, so the explore subsystem can score a
    point analytically (``cost.score_pass_trace``) straight from it — or,
    on a disk hit, from the persisted payload without recompiling.

    Returns a deep copy on memory hits so callers can mutate freely.
    """
    if cache is None:
        cache = _cache.get_default_cache()
    t0 = time.perf_counter()
    hw_fp = hw.fingerprint()
    ir_fp = ir_fingerprint(prog)
    key = _cache.content_key(
        "compile", DRIVER_VERSION, _cache.CACHE_VERSION,
        ir_fp, hw_fp,
        # tilings chosen under a calibrated cost model can differ, so
        # calibrated compiles never collide with uncalibrated ones
        _calibration_fp(hw_fp),
    )
    hit = cache.get_memory(key)
    if isinstance(hit, tuple) and len(hit) == 2 and isinstance(hit[0], Program):
        # the memory tier holds (optimized program, cold record): hit
        # records keep the cold compile's tilings/trace, so they stay
        # scorable (cost.score_pass_trace) even with the disk tier off
        prog0, rec0 = hit
        rec = dataclasses.replace(copy.deepcopy(rec0), cache_hit=True,
                                  disk_hit=False,
                                  compile_time_s=time.perf_counter() - t0)
        return copy.deepcopy(prog0), rec
    payload = cache.get_disk(key) if use_disk else None
    oracle = TilingOracle(known=(payload or {}).get("tilings"))
    pm = PassManager(hw, oracle=oracle, autotune_workers=workers)
    opt = pm.run(copy.deepcopy(prog))
    groups = _program_groups(opt)
    rec = CompileRecord(key=key, backend="", hw_name=hw.name,
                        disk_hit=payload is not None,
                        compile_time_s=time.perf_counter() - t0,
                        tilings=dict(oracle.chosen), pass_trace=list(pm.trace),
                        n_kernels=len(groups), groups=groups,
                        ir_fingerprint=ir_fp, hw_fingerprint=hw_fp)
    cache.put_memory(key, (opt, rec))
    if use_disk:
        cache.put_disk(key, {"tilings": oracle.chosen, "pass_trace": pm.trace,
                             "hw": hw.name, "compile_time_s": rec.compile_time_s,
                             "n_kernels": rec.n_kernels, "groups": groups})
    return copy.deepcopy(opt), rec


def stripe_jit(fn_or_contraction: Union[Program, TileProgram, str, Callable],
               hw: HardwareConfig, backend: str = "jnp", *,
               tensors: Optional[Mapping[str, Tuple]] = None,
               out: Optional[str] = None,
               ranges: Optional[Mapping[str, int]] = None,
               cache: Optional[_cache.CompilationCache] = None,
               workers: Optional[int] = None,
               interpret: bool = True,
               jit: bool = True,
               use_disk: bool = True,
               profile: bool = False,
               tune: Union[None, bool, Any] = None,
               mesh: Union[None, int, Tuple[int, ...], Any] = None) -> CompiledProgram:
    """Compile a tensor op end-to-end through the cached Stripe pipeline.

    ``workers`` enables the parallel autotune search on cold compiles;
    ``interpret`` selects Pallas interpret mode (CPU validation) for the
    pallas backend; ``cache`` defaults to the process-wide cache.
    ``profile=True`` wall-times each lowered unit on dispatch: the record
    carries per-unit measured latencies next to the cost model's
    predictions, and the first dispatch appends (predicted, measured)
    rows to ``residuals.jsonl`` under the cache dir (``profile`` is part
    of the cache key — profiled and unprofiled artifacts differ).
    ``tune`` consults the measured-feedback tuning DB before the analytic
    autotile search: ``True`` opens the DB next to the cache's disk store,
    or pass a :class:`repro.tune.TuningDB`.  A fresh-enough measured-best
    entry replays its tilings (and per-unit backend choices) instead of
    searching — ``record.decision_source == "tuned"`` — and the entry's
    candidate id is folded into the cache key, so a better measurement
    automatically re-keys the artifact.  With ``profile=True`` the first
    dispatch also records its measurement back into the DB.
    ``mesh`` routes the compile through the multi-device path: a device
    count, mesh shape tuple, or ``jax.sharding.Mesh`` — the partitioner
    shards the program over the mesh, each shard-local segment compiles
    through this same single-device pipeline, and the segments are
    stitched inside ``shard_map`` with explicit collectives.  A mesh the
    partitioner cannot shard falls back to a single-device compile with
    ``record.mesh["fallback"]`` carrying the reason.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if cache is None:
        cache = _cache.get_default_cache()
    if mesh is None and getattr(hw, "mesh_devices", lambda: 1)() > 1:
        mesh = hw.mesh  # the config carries a mesh spec: compile for it
    if mesh is not None:
        from . import mesh_lower

        resolved = mesh_lower.resolve_mesh(mesh)
        if resolved is not None:
            return _stripe_jit_mesh(
                fn_or_contraction, hw, backend, resolved,
                tensors=tensors, out=out, ranges=ranges, cache=cache,
                workers=workers, interpret=interpret, jit=jit,
                use_disk=use_disk, profile=profile, tune=tune)
    with obs_trace.span("compile.stripe_jit", backend=backend, hw=hw.name,
                        profile=profile) as csp:
        t0 = time.perf_counter()
        prog = _as_program(fn_or_contraction, tensors=tensors, out=out, ranges=ranges)
        ir_fp = ir_fingerprint(prog)
        hw_fp = hw.fingerprint()
        tune_db = _resolve_tune(tune, cache)
        tuned = None
        if tune_db is not None:
            # consulted *before* the memory probe: the tuned entry's
            # candidate id is part of the key, so a DB update naturally
            # misses the stale artifact and recompiles with the winner
            with obs_trace.span("tune.lookup", backend=backend) as sp:
                tuned = tune_db.lookup(ir_fp, hw_fp, backend, interpret)
                sp.set(hit=tuned is not None)
            if tuned is not None:
                cache.stats.tuned_hits += 1
            else:
                cache.stats.tuned_misses += 1
        key = _cache.content_key(
            "stripe_jit", DRIVER_VERSION, _cache.CACHE_VERSION,
            ir_fp, hw_fp, backend, bool(interpret), bool(jit), bool(profile),
            tuned.fingerprint if tuned is not None else "",
            _calibration_fp(hw_fp),
        )
        with obs_trace.span("cache.probe", level="memory") as sp:
            hit = cache.get_memory(key)
            sp.set(hit=hit is not None)
        if isinstance(hit, CompiledProgram):
            if hit.record.quarantined and not cache.quarantine.active(key):
                # the cached artifact is a quarantine fallback and the backoff
                # embargo has lapsed: drop through and re-attempt the real
                # backend (success clears the entry, failure doubles backoff)
                hit = None
            else:
                # fresh record per call: never mutate the cached one (the cold
                # caller holds it), and report this call's lookup time
                rec = dataclasses.replace(hit.record, cache_hit=True, disk_hit=False,
                                          compile_time_s=time.perf_counter() - t0)
                if rec.quarantined:
                    entry = cache.quarantine.get(key)
                    rec.quarantine = entry.as_dict() if entry is not None else dict(rec.quarantine)
                csp.set(cache="memory", backend_used=rec.backend)
                return CompiledProgram(hit.program, hit._fn, hit.hw, rec)

        with obs_trace.span("cache.probe", level="disk") as sp:
            payload = cache.get_disk(key) if use_disk else None
            sp.set(hit=payload is not None)
        # the tuned entry's tilings take precedence over the disk replay
        # (the disk payload under a tuned key holds the same tilings)
        known = (tuned.tilings if tuned is not None
                 else (payload or {}).get("tilings"))
        oracle = TilingOracle(known=known)
        pm = PassManager(hw, oracle=oracle, autotune_workers=workers)
        opt = pm.run(copy.deepcopy(prog))
        force_jnp = None
        if tuned is not None and backend == "pallas":
            force_jnp = {u for u, b in tuned.block_backends.items() if b == "jnp"}
        low = _lower(opt, backend, interpret, jit, hw,
                     quarantine=cache.quarantine, key=key, profile=profile,
                     force_jnp_units=force_jnp or None)
        record = CompileRecord(
            key=key, backend=low.backend, hw_name=hw.name,
            cache_hit=False, disk_hit=payload is not None,
            compile_time_s=time.perf_counter() - t0,
            tilings=dict(oracle.chosen), pass_trace=list(pm.trace),
            fallback_reason=low.fallback, n_kernels=low.n_kernels,
            groups=low.groups,
            block_backends=low.block_backends, block_fallbacks=low.block_fallbacks,
            quarantined=low.quarantined, quarantine=low.quarantine,
            profiled=bool(profile), ir_fingerprint=ir_fp, hw_fingerprint=hw_fp,
            decision_source="tuned" if tuned is not None else "analytic",
            tuned=({"candidate_id": tuned.candidate_id,
                    "measured_s": tuned.measured_s,
                    "predicted_s": tuned.predicted_s,
                    "source": tuned.source, "rounds": tuned.rounds,
                    "age_s": max(time.time() - tuned.ts, 0.0),
                    "n_candidates": tuned.n_candidates}
                   if tuned is not None else {}),
        )
        fn = low.fn
        if profile:
            record.predicted_terms = obs_profile.predicted_unit_terms(
                opt, record.pass_trace)
            record.predicted_latency_s = {
                u: t["latency_s"] for u, t in record.predicted_terms.items()}
            fn = _attach_profiling(low, record, cache, interpret,
                                   tune_db=tune_db, requested_backend=backend)
        compiled = CompiledProgram(opt, fn, hw, record)
        cache.put_memory(key, compiled)
        if use_disk:
            cache.put_disk(key, {
                "tilings": oracle.chosen, "pass_trace": pm.trace,
                "hw": hw.name, "backend": low.backend,
                "compile_time_s": record.compile_time_s,
                "n_kernels": low.n_kernels, "groups": low.groups,
                "block_backends": low.block_backends,
                "block_fallbacks": low.block_fallbacks,
                "decision_source": record.decision_source,
            })
        csp.set(cache="disk" if record.disk_hit else "miss",
                backend_used=low.backend, decision=record.decision_source)
        return compiled


def _single_device_hw(hw: HardwareConfig) -> HardwareConfig:
    """The per-shard view of a meshed config: same machine model, no
    mesh (so segment compiles never re-enter the mesh path) and no
    partition pass (segments are already shard-local)."""
    if not getattr(hw, "mesh", ()) and not any(
            name == "partition" for name, _ in hw.passes):
        return hw
    return dataclasses.replace(
        hw, mesh=(),
        passes=tuple((n, p) for n, p in hw.passes if n != "partition"))


def _stripe_jit_mesh(fn_or_contraction, hw: HardwareConfig, backend: str,
                     resolved, *, tensors=None, out=None, ranges=None,
                     cache: Optional[_cache.CompilationCache] = None,
                     workers: Optional[int] = None, interpret: bool = True,
                     jit: bool = True, use_disk: bool = True,
                     profile: bool = False,
                     tune: Union[None, bool, Any] = None) -> CompiledProgram:
    """The multi-device compile path behind ``stripe_jit(..., mesh=)``.

    The shard planner picks one split per block (output, reduction,
    halo, or ring-overlap — by modelled cost) and cuts the program into
    shard-local *segments*; each segment compiles through the ordinary
    cached single-device ``stripe_jit`` (per-block hybrid Pallas/jnp
    composer, tuning DB, quarantine — everything), and
    :func:`~repro.core.mesh_lower.emit` stitches the compiled segments
    inside ``shard_map`` with the plan's explicit collectives.  A
    program the planner cannot shard falls back to the single-device
    compile, recording the reason in ``record.mesh["fallback"]``.
    """
    from .mesh_lower import emit
    from .shardplan import UnsupportedMesh, plan_program

    jmesh, axis, shape = resolved
    n = int(jmesh.devices.size)
    hw_inner = _single_device_hw(hw)
    with obs_trace.span("compile.stripe_jit_mesh", backend=backend,
                        hw=hw.name, mesh="x".join(map(str, shape))) as csp:
        t0 = time.perf_counter()
        prog = _as_program(fn_or_contraction, tensors=tensors, out=out,
                           ranges=ranges)
        try:
            faults.check("compile.stripe_jit_mesh", backend=backend, n=n)
            plan = plan_program(prog, n, hw, shape)
        except Exception as e:
            if not isinstance(e, UnsupportedMesh):
                # planner crash / injected fault: degrade, don't fail
                e = UnsupportedMesh(f"mesh planning crashed: {e!r}")
            compiled = stripe_jit(prog, hw_inner, backend, cache=cache,
                                  workers=workers, interpret=interpret,
                                  jit=jit, use_disk=use_disk,
                                  profile=profile, tune=tune)
            rec = dataclasses.replace(
                compiled.record,
                mesh={"fallback": str(e), "shape": list(shape),
                      "axis": axis, "n_devices": n})
            csp.set(fallback=str(e)[:200])
            return CompiledProgram(compiled.program, compiled._fn,
                                   compiled.hw, rec)

        ir_fp = ir_fingerprint(prog)
        hw_fp = hw.fingerprint()
        tune_db = _resolve_tune(tune, cache)
        key = _cache.content_key(
            "stripe_jit_mesh", DRIVER_VERSION, _cache.CACHE_VERSION,
            ir_fp, hw_fp, backend, bool(interpret), bool(jit), bool(profile),
            list(shape), axis, n, _calibration_fp(hw_fp),
        )
        # the outer memory cache is bypassed under tuning: segment keys
        # fold in their tuned candidate ids, so a DB update must be able
        # to re-stitch fresh segment artifacts
        if tune_db is None:
            with obs_trace.span("cache.probe", level="memory") as sp:
                hit = cache.get_memory(key)
                sp.set(hit=hit is not None)
            if isinstance(hit, CompiledProgram):
                rec = dataclasses.replace(
                    hit.record, cache_hit=True, disk_hit=False,
                    compile_time_s=time.perf_counter() - t0)
                csp.set(cache="memory", backend_used=rec.backend)
                return CompiledProgram(hit.program, hit._fn, hit.hw, rec)

        segments = plan.build_segments(prog)
        compiled_segs = [
            stripe_jit(seg.program, hw_inner, backend, cache=cache,
                       workers=workers, interpret=interpret, jit=False,
                       use_disk=use_disk, profile=False, tune=tune)
            for seg in segments]
        fn = emit(prog, plan, segments, compiled_segs, jmesh, axis,
                  jit=jit and not profile)

        # merge segment provenance into the whole-program record
        pass_trace: List = []
        block_backends: Dict[str, str] = {}
        block_fallbacks: Dict[str, str] = {}
        tilings: Dict[str, Dict[str, int]] = {}
        groups: List[List[str]] = []
        n_kernels = 0
        backend_used = "reference"
        seg_summaries = []
        for seg, c in zip(segments, compiled_segs):
            r = c.record
            pass_trace.extend(r.pass_trace)
            block_backends.update(r.block_backends)
            block_fallbacks.update(r.block_fallbacks)
            tilings.update(r.tilings)
            groups.extend(r.groups)
            n_kernels += r.n_kernels
            if r.backend == "pallas" or (r.backend == "jnp"
                                         and backend_used != "pallas"):
                backend_used = r.backend
            seg_summaries.append({
                "name": seg.program.entry.name, "key": r.key,
                "backend": r.backend, "n_kernels": r.n_kernels,
                "cache_hit": r.cache_hit, "disk_hit": r.disk_hit,
                "decision_source": r.decision_source,
            })
        pass_trace.append(("partition", {"mesh": list(shape), "axis": axis},
                           plan.report(scale_compute=False)))
        mesh_info = {
            "shape": list(shape), "axis": axis, "n_devices": n,
            "seed": plan.seed, "splits": plan.splits(),
            "collectives": [c.to_json() for c in plan.collectives],
            "collective_bytes": plan.collective_bytes(),
            "comm_s": plan.comm_s, "compute_s": plan.compute_s,
            "overlapped": [c.buffer for c in plan.collectives if c.overlap],
            "segments": seg_summaries,
        }
        record = CompileRecord(
            key=key, backend=backend_used, hw_name=hw.name,
            cache_hit=False, disk_hit=False,
            compile_time_s=time.perf_counter() - t0,
            tilings=tilings, pass_trace=pass_trace,
            n_kernels=n_kernels, groups=groups,
            block_backends=block_backends, block_fallbacks=block_fallbacks,
            profiled=bool(profile), ir_fingerprint=ir_fp,
            hw_fingerprint=hw_fp,
            decision_source=("tuned" if any(
                s["decision_source"] == "tuned" for s in seg_summaries)
                else "analytic"),
            mesh=mesh_info,
        )
        if profile:
            record.predicted_latency_s = {"<program>": plan.cost_s}
            fn = _attach_profiling(
                _Lowered(fn, backend_used), record, cache, interpret,
                tune_db=tune_db, requested_backend=backend)
        compiled = CompiledProgram(prog, fn, hw, record)
        if tune_db is None:
            cache.put_memory(key, compiled)
        if use_disk:
            cache.put_disk(key, {
                "mesh": mesh_info, "tilings": tilings,
                "hw": hw.name, "backend": backend_used,
                "compile_time_s": record.compile_time_s,
                "n_kernels": n_kernels, "groups": groups,
                "segments": seg_summaries,
            })
        csp.set(cache="miss", backend_used=backend_used,
                n_segments=len(segments),
                collective_bytes=mesh_info["collective_bytes"])
        return compiled


def compile_with_tilings(fn_or_contraction: Union[Program, TileProgram, str, Callable],
                         hw: HardwareConfig,
                         tilings: Mapping[str, Mapping[str, int]],
                         backend: str = "jnp", *,
                         tensors: Optional[Mapping[str, Tuple]] = None,
                         out: Optional[str] = None,
                         ranges: Optional[Mapping[str, int]] = None,
                         interpret: bool = True,
                         jit: bool = True,
                         profile: bool = False) -> CompiledProgram:
    """Compile with a **fixed tiling assignment** — no cache, no search.

    ``tilings`` uses the tiling-oracle key form (``"<block>#<fp16>"`` ->
    {var: tile}); blocks absent from it fall back to the analytic search.
    This is the explore measure-mode's candidate-replay entry: a sweep
    candidate's tilings are forced through the pass pipeline on the
    *base* config so the only thing that differs between measured
    candidates is the tiling (and the backend), never the model."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    t0 = time.perf_counter()
    prog = _as_program(fn_or_contraction, tensors=tensors, out=out, ranges=ranges)
    ir_fp = ir_fingerprint(prog)
    oracle = TilingOracle(known=tilings)
    pm = PassManager(hw, oracle=oracle)
    opt = pm.run(copy.deepcopy(prog))
    low = _lower(opt, backend, interpret, jit, hw, quarantine=None, key="",
                 profile=profile)
    record = CompileRecord(
        key="", backend=low.backend, hw_name=hw.name,
        compile_time_s=time.perf_counter() - t0,
        tilings=dict(oracle.chosen), pass_trace=list(pm.trace),
        fallback_reason=low.fallback, n_kernels=low.n_kernels,
        groups=low.groups,
        block_backends=low.block_backends, block_fallbacks=low.block_fallbacks,
        profiled=bool(profile), ir_fingerprint=ir_fp,
        hw_fingerprint=hw.fingerprint(), decision_source="replay",
    )
    return CompiledProgram(opt, low.fn, hw, record)
