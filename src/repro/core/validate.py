"""Verification that Stripe blocks satisfy Definition 2 (parallel
polyhedral blocks).

Two flavours:

* ``validate_program`` — an *exact* oracle that enumerates iteration points
  and checks conditions (1)-(3) of Def. 2 directly.  Used by tests and by
  passes on small shapes to prove a rewrite preserved parallel semantics.
* ``affine_map_injective`` — a sound *structural* sufficient condition for
  write-map injectivity on spaces too large to enumerate (mixed-radix
  stride argument), used by the pass pipeline on production shapes.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Mapping, Sequence, Tuple

from .affine import Affine
from .ir import Block, Constant, Intrinsic, Load, Program, RefDir, Special, Store


class ValidationError(Exception):
    pass


# --------------------------------------------------------------------------
# Structural scoping checks (Def. 2 condition 1)
# --------------------------------------------------------------------------
def check_scoping(block: Block, parent_bufs: Sequence[str], errors: List[str], path: str = "") -> None:
    me = f"{path}/{block.name}"
    local = set()
    for r in block.refs:
        if r.dir != RefDir.NONE and r.from_buf not in parent_bufs:
            errors.append(f"{me}: refinement '{r.into}' refers to undeclared parent buffer '{r.from_buf}'")
        local.add(r.into)
    scalars = set()
    idx_names = {i.name for i in block.idxs} | set(block.passed)
    for c in block.constraints:
        for n in c.expr.names():
            if n not in idx_names:
                errors.append(f"{me}: constraint uses unknown index '{n}' (not local, not passed)")
    for s in block.stmts:
        if isinstance(s, Load):
            if s.buf not in local:
                errors.append(f"{me}: load from undeclared buffer '{s.buf}'")
            elif not block.ref(s.buf).is_scalar_view():
                errors.append(f"{me}: load({s.buf}) requires a scalar view")
            scalars.add(s.into)
        elif isinstance(s, Store):
            if s.buf not in local:
                errors.append(f"{me}: store to undeclared buffer '{s.buf}'")
            if s.scalar not in scalars:
                errors.append(f"{me}: store of undefined scalar '${s.scalar}'")
        elif isinstance(s, Intrinsic):
            for a in s.args:
                if a not in scalars:
                    errors.append(f"{me}: intrinsic '{s.op}' uses undefined scalar '${a}'")
            scalars.add(s.into)
        elif isinstance(s, Constant):
            scalars.add(s.into)
        elif isinstance(s, Special):
            for b in (*s.ins, *s.outs):
                if b not in local:
                    errors.append(f"{me}: special '{s.op}' uses undeclared buffer '{b}'")
        elif isinstance(s, Block):
            check_scoping(s, sorted(local), errors, me)
        else:  # pragma: no cover
            errors.append(f"{me}: unknown statement {type(s)}")


# --------------------------------------------------------------------------
# Exact footprint enumeration (oracle)
# --------------------------------------------------------------------------
Access = Tuple[str, Tuple[int, ...], str, str]  # (root buffer, element, kind, agg)


_ALLOC_UID = itertools.count()


def _enter_block(block: Block, env: Mapping[str, int], bases: Mapping[str, Tuple[str, Tuple[int, ...]]]):
    new = {}
    for r in block.refs:
        if r.dir == RefDir.NONE:
            # fresh local allocation per block *invocation*: unique root so
            # iteration-local temporaries never alias across iterations
            new[r.into] = (f"!local{next(_ALLOC_UID)}:{r.into}", tuple(0 for _ in r.shape))
        else:
            root, base = bases[r.from_buf]
            off = tuple(b + o.eval(env) for b, o in zip(base, r.offsets))
            new[r.into] = (root, off)
    return new


def _leaf_accesses(block: Block, env: Dict[str, int], bases, out: List[Access], limit: List[int]) -> None:
    if limit[0] <= 0:
        raise ValidationError("enumeration limit exceeded")
    my_bases = _enter_block(block, env, bases)
    for s in block.stmts:
        if isinstance(s, Load):
            root, base = my_bases[s.buf]
            out.append((root, base, "read", ""))
        elif isinstance(s, Store):
            root, base = my_bases[s.buf]
            out.append((root, base, "write", block.ref(s.buf).agg or "assign"))
        elif isinstance(s, Special):
            for b in s.ins:
                root, base = my_bases[b]
                out.append((root, base, "read_region", ""))
            for b in s.outs:
                root, base = my_bases[b]
                out.append((root, base, "write_region", block.ref(b).agg or "assign"))
        elif isinstance(s, Block):
            for sub_env in s.poly.points(env):
                limit[0] -= 1
                _leaf_accesses(s, dict(sub_env), my_bases, out, limit)


def iteration_footprints(block: Block, parent_env: Mapping[str, int], bases, limit: int = 200000):
    """Per-iteration (reads, writes) footprints of ``block`` under a parent
    environment.  writes maps element -> agg op."""
    result = []
    budget = [limit]
    if block.poly.rect_size() > limit:
        raise ValidationError("enumeration limit exceeded")
    for env in block.poly.points(parent_env):
        budget[0] -= 1
        if budget[0] <= 0:
            raise ValidationError("enumeration limit exceeded")
        acc: List[Access] = []
        _leaf_accesses(block, dict(env), bases, acc, budget)
        reads = set()
        writes: Dict[Tuple[str, Tuple[int, ...]], str] = {}
        for root, elem, kind, agg in acc:
            if kind.startswith("read"):
                reads.add((root, elem))
            else:
                writes[(root, elem)] = agg
        result.append((dict(env), reads, writes))
    return result


def check_block_parallel(block: Block, parent_env: Mapping[str, int], bases, errors: List[str], path: str, limit: int = 200000) -> None:
    """Exact Def. 2 conditions (2) and (3) for one block, then recurse."""
    me = f"{path}/{block.name}"
    try:
        foot = iteration_footprints(block, parent_env, bases, limit)
    except ValidationError:
        # too large to enumerate: sound structural check instead — assign
        # outputs must have provably injective write maps (mixed-radix)
        ranges = block.idx_ranges()
        for r in block.refs:
            if r.dir in (RefDir.OUT, RefDir.INOUT) and (r.agg or "assign") == "assign":
                if not affine_map_injective(list(r.offsets), ranges):
                    errors.append(
                        f"{me}: cannot prove injective writes to '{r.into}' (assign, too large to enumerate)")
        return

    all_writes: Dict[Tuple[str, Tuple[int, ...]], List[int]] = {}
    for it, (_env, _reads, writes) in enumerate(foot):
        for key, agg in writes.items():
            all_writes.setdefault(key, []).append(it)

    # (2) no iteration reads an element written by a *different* iteration
    for it, (_env, reads, writes) in enumerate(foot):
        for key in reads:
            writers = all_writes.get(key, [])
            if any(w != it for w in writers):
                errors.append(f"{me}: element {key} read by iter {it} but written by other iterations {writers}")
                return  # one witness is enough

    # (3) multi-written elements must have a real aggregation (not assign)
    for it, (_env, _reads, writes) in enumerate(foot):
        for key, agg in writes.items():
            if agg == "assign" and len(all_writes[key]) > 1:
                errors.append(f"{me}: element {key} written by {len(all_writes[key])} iterations with agg=assign")
                return

    # Recurse into children for one representative parent point.
    for env in block.poly.points(parent_env):
        my_bases = _enter_block(block, env, bases)
        for s in block.stmts:
            if isinstance(s, Block):
                check_block_parallel(s, env, my_bases, errors, me, limit)
        break


def validate_program(prog: Program, limit: int = 200000) -> List[str]:
    """Returns a list of violations; empty list means the program is a valid
    nested-polyhedral-model program (exact check; small shapes only)."""
    errors: List[str] = []
    check_scoping(prog.entry, list(prog.buffers), errors)
    if errors:
        return errors
    bases = {name: (name, tuple(0 for _ in d.shape)) for name, d in prog.buffers.items()}
    for s in prog.entry.stmts:
        if isinstance(s, Block):
            check_block_parallel(s, {}, bases, errors, prog.entry.name, limit)
    return errors


# --------------------------------------------------------------------------
# Structural (sound, incomplete) injectivity for large spaces
# --------------------------------------------------------------------------
def affine_map_injective(exprs: Sequence[Affine], ranges: Mapping[str, int]) -> bool:
    """Sufficient condition that the map ``i -> (e_0(i), ..)`` is injective
    over the rectangular domain: each variable feeds exactly one output
    dim, and within each dim the (|coef|, range) pairs satisfy the
    mixed-radix condition |c_{k+1}| >= |c_k| * r_k when sorted by |coef|."""
    used: Dict[str, int] = {}
    for d, e in enumerate(exprs):
        for n in e.names():
            if ranges.get(n, 1) <= 1:
                continue
            if n in used and used[n] != d:
                return False
            used[n] = d
    for d, e in enumerate(exprs):
        pairs = sorted(
            (abs(c), ranges[n]) for n, c in e.terms if ranges.get(n, 1) > 1
        )
        span = 1
        for c, r in pairs:
            if c < span:
                return False
            span = c * r  # smallest stride that the next var must clear
    return True
