"""Affine polynomials over integer index variables.

Stripe (§3.2) requires every buffer access and every iteration-space
constraint to be an affine function of index names (including parent-block
indices).  ``Affine`` is the single currency for offsets, strides applied to
indices, and constraint left-hand-sides.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Mapping, Tuple, Union

IntLike = Union[int, "Affine"]


@dataclasses.dataclass(frozen=True)
class Affine:
    """``sum(coef[name] * name) + const`` with integer coefficients."""

    terms: Tuple[Tuple[str, int], ...] = ()
    const: int = 0

    # ---------------------------------------------------------------- ctor
    @staticmethod
    def make(terms: Mapping[str, int] | Iterable[Tuple[str, int]] = (), const: int = 0) -> "Affine":
        if isinstance(terms, Mapping):
            items = terms.items()
        else:
            items = terms
        merged: Dict[str, int] = {}
        for name, coef in items:
            if coef:
                merged[name] = merged.get(name, 0) + coef
        merged = {k: v for k, v in merged.items() if v}
        return Affine(tuple(sorted(merged.items())), int(const))

    @staticmethod
    def var(name: str, coef: int = 1) -> "Affine":
        return Affine.make({name: coef})

    @staticmethod
    def lift(v: IntLike) -> "Affine":
        if isinstance(v, Affine):
            return v
        return Affine((), int(v))

    # ------------------------------------------------------------- algebra
    def __add__(self, other: IntLike) -> "Affine":
        o = Affine.lift(other)
        merged = dict(self.terms)
        for name, coef in o.terms:
            merged[name] = merged.get(name, 0) + coef
        return Affine.make(merged, self.const + o.const)

    __radd__ = __add__

    def __neg__(self) -> "Affine":
        return Affine.make({n: -c for n, c in self.terms}, -self.const)

    def __sub__(self, other: IntLike) -> "Affine":
        return self + (-Affine.lift(other))

    def __rsub__(self, other: IntLike) -> "Affine":
        return Affine.lift(other) + (-self)

    def __mul__(self, k: int) -> "Affine":
        if isinstance(k, Affine):
            if k.is_const():
                k = k.const
            else:  # pragma: no cover - guarded misuse
                raise TypeError("Affine*Affine is not affine")
        return Affine.make({n: c * k for n, c in self.terms}, self.const * k)

    __rmul__ = __mul__

    # ------------------------------------------------------------- queries
    def is_const(self) -> bool:
        return not self.terms

    def coef(self, name: str) -> int:
        for n, c in self.terms:
            if n == name:
                return c
        return 0

    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.terms)

    def eval(self, env: Mapping[str, int]) -> int:
        total = self.const
        for n, c in self.terms:
            total += c * env[n]
        return total

    def partial_eval(self, env: Mapping[str, int]) -> "Affine":
        """Substitute the names present in ``env``; keep the rest symbolic."""
        terms: Dict[str, int] = {}
        const = self.const
        for n, c in self.terms:
            if n in env:
                const += c * env[n]
            else:
                terms[n] = terms.get(n, 0) + c
        return Affine.make(terms, const)

    def substitute(self, subst: Mapping[str, "Affine"]) -> "Affine":
        """Substitute names by affine expressions (used when splitting an
        index ``i -> tile*i_outer + i_inner`` during tiling)."""
        out = Affine.lift(self.const)
        for n, c in self.terms:
            repl = subst.get(n)
            out = out + (repl * c if repl is not None else Affine.make({n: c}))
        return out

    def rename(self, mapping: Mapping[str, str]) -> "Affine":
        return Affine.make({mapping.get(n, n): c for n, c in self.terms}, self.const)

    # ------------------------------------------------------------- display
    def __str__(self) -> str:
        parts = []
        for n, c in self.terms:
            if c == 1:
                parts.append(n)
            elif c == -1:
                parts.append(f"-{n}")
            else:
                parts.append(f"{c}*{n}")
        if self.const or not parts:
            parts.append(str(self.const))
        s = " + ".join(parts)
        return s.replace("+ -", "- ")

    __repr__ = __str__


def aff(v: IntLike) -> Affine:
    return Affine.lift(v)
