"""Hardware configuration — the paper's ``create_stripe_config`` /
``set_config_params`` (Fig. 1).

A ``HardwareConfig`` is the *only* hardware-specific artifact in the
compiler: a description of the memory hierarchy, compute stencils, and a
parameterized list of optimization passes.  Operations (the frontend) never
reference it; passes are generic and read their parameters from here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MemoryUnit:
    name: str
    size_bytes: int
    bandwidth: float  # bytes/s to the next-outer level
    cache_line_elems: int = 1  # transaction granularity, in elements


@dataclasses.dataclass(frozen=True)
class ComputeStencil:
    """A hardware compute unit needing exact tile multiples (paper:
    'Microarchitectural Stenciling')."""

    name: str  # e.g. "mxu", "vpu"
    # (parallel_out0, parallel_out1, reduction) multiples for contractions
    dims: Tuple[int, int, int]
    flops: float  # peak FLOP/s when fed at this stencil


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    name: str
    mem_units: Tuple[MemoryUnit, ...]  # outermost -> innermost
    stencils: Tuple[ComputeStencil, ...] = ()
    peak_flops: float = 0.0
    # roofline link terms (framework-level; chips in a pod slice)
    ici_link_bw: float = 0.0
    # device-mesh shape for multi-device plans: () = single device.  A
    # non-trivial mesh activates the partition pass's annotation mode
    # (shard-plan analysis + collective predictions in the pass trace)
    # and the interconnect terms of the cost model; the backend mesh a
    # ``stripe_jit(..., mesh=)`` compile runs on is resolved separately
    # (the config's mesh is the *model*, the driver's mesh the machine).
    mesh: Tuple[int, ...] = ()
    # grid-pipeline depth: how many in-flight tile buffers the hardware's
    # DMA pipeline holds per streamed view (2 = classic double buffering;
    # 1 = no overlap — fetch and compute serialize).  Gates the pipelined
    # latency model in cost.py and sizes memplan's streamed-view slots.
    pipeline_depth: int = 2
    # pass pipeline: (pass_name, params) applied in order
    passes: Tuple[Tuple[str, Dict], ...] = ()

    def mem(self, name: str) -> MemoryUnit:
        for m in self.mem_units:
            if m.name == name:
                return m
        raise KeyError(
            f"no memory unit {name!r} in hardware config {self.name!r}; "
            f"available units: {[m.name for m in self.mem_units]}")

    def inner_mem(self) -> MemoryUnit:
        return self.mem_units[1] if len(self.mem_units) > 1 else self.mem_units[0]

    def fingerprint(self) -> str:
        """Stable content hash of everything that can change compilation
        output: memory hierarchy, stencils, roofline terms, and the pass
        pipeline with its parameters (order-sensitive; param-key order is
        not).  The config *name* is deliberately excluded — two configs
        that compile identically hash identically, so design-space sweeps
        dedupe renamed-but-equal points into one compilation-cache entry.
        Used as the hardware component of compilation-cache keys.

        Memoized per instance: configs are frozen (every mutation helper
        returns a fresh instance via ``dataclasses.replace``), and the
        calibration-aware cost model consults the fingerprint once per
        candidate tiling — hashing the config thousands of times per
        autotile search would dominate it."""
        cached = self.__dict__.get("_fingerprint_memo")
        if cached is not None:
            return cached
        from .cache import stable_hash

        fp = stable_hash([
            "hwconfig",
            [[m.name, m.size_bytes, m.bandwidth, m.cache_line_elems] for m in self.mem_units],
            [[s.name, list(s.dims), s.flops] for s in self.stencils],
            self.peak_flops, self.ici_link_bw, self.pipeline_depth,
            list(self.mesh),
            [[name, sorted(params.items())] for name, params in self.passes],
        ])
        object.__setattr__(self, "_fingerprint_memo", fp)
        return fp

    def with_params(self, **overrides) -> "HardwareConfig":
        """The paper's ``set_config_params``: per-HW-version tweak of pass
        parameters without rewriting the config."""
        new_passes = []
        for name, params in self.passes:
            p = dict(params)
            for k, v in overrides.items():
                pref = name + "."
                if k.startswith(pref):
                    p[k[len(pref):]] = v
            new_passes.append((name, p))
        return dataclasses.replace(self, passes=tuple(new_passes))

    # ---------------------------------------------------------------- sweeps
    # Space-mutation helpers for design-space exploration (repro.explore):
    # each returns a new config with one structural knob turned, leaving
    # everything else (including the pass pipeline) intact.
    def renamed(self, name: str) -> "HardwareConfig":
        return dataclasses.replace(self, name=name)

    def with_mem(self, unit: str, **overrides) -> "HardwareConfig":
        """Replace fields of one memory unit (e.g. ``with_mem("VMEM",
        size_bytes=64 << 20)``)."""
        self.mem(unit)  # raise the descriptive KeyError on a bad name
        units = tuple(
            dataclasses.replace(m, **overrides) if m.name == unit else m
            for m in self.mem_units)
        return dataclasses.replace(self, mem_units=units)

    def with_stencil(self, stencil: str, **overrides) -> "HardwareConfig":
        """Replace fields of one compute stencil (e.g. ``with_stencil(
        "mxu", dims=(256, 256, 128))``)."""
        if not any(s.name == stencil for s in self.stencils):
            raise KeyError(
                f"no stencil {stencil!r} in hardware config {self.name!r}; "
                f"available stencils: {[s.name for s in self.stencils]}")
        stencils = tuple(
            dataclasses.replace(s, **overrides) if s.name == stencil else s
            for s in self.stencils)
        return dataclasses.replace(self, stencils=stencils)

    def without_pass(self, name: str) -> "HardwareConfig":
        """Drop one pass from the pipeline (pipeline-variant sweeps)."""
        return dataclasses.replace(
            self, passes=tuple(p for p in self.passes if p[0] != name))

    def with_mesh(self, shape: Sequence[int]) -> "HardwareConfig":
        """Set the modeled device-mesh shape (mesh-shape sweeps).  The
        partition pass must see the *semantic* program, so it is
        prepended to the pipeline when a non-trivial mesh is set and the
        pipeline does not already run it."""
        shape = tuple(int(s) for s in shape)
        passes = self.passes
        n = 1
        for s in shape:
            n *= s
        if n <= 1:
            # a trivial mesh is *no* mesh: normalize so the config
            # fingerprints identically to the stock single-device one
            # (sweep dedupe relies on it)
            return dataclasses.replace(self, mesh=())
        if not any(name == "partition" for name, _ in passes):
            passes = (("partition", {}),) + passes
        return dataclasses.replace(self, mesh=shape, passes=passes)

    def mesh_devices(self) -> int:
        n = 1
        for s in self.mesh:
            n *= int(s)
        return n


# ---------------------------------------------------------------------------
# TPU v5e (the deployment target of this framework)
# ---------------------------------------------------------------------------
TPU_V5E = HardwareConfig(
    name="tpu_v5e",
    mem_units=(
        MemoryUnit("HBM", 16 * 2**30, 819e9, cache_line_elems=128),
        # VMEM: ~128 MiB; budget half for double-buffering headroom
        MemoryUnit("VMEM", 128 * 2**20, 2.7e12, cache_line_elems=128),
        MemoryUnit("VREG", 32 * 2**10, 1e14, cache_line_elems=8),
    ),
    stencils=(
        ComputeStencil("mxu", (128, 128, 128), 197e12),  # bf16 systolic
        ComputeStencil("vpu", (8, 128, 1), 4e12),
    ),
    peak_flops=197e12,
    ici_link_bw=50e9,
    pipeline_depth=2,  # double-buffered BlockSpec streaming
    passes=(
        # prefer is explicit (its implicit default) so a sweep point that
        # sets it to the stock value fingerprints identically to stock
        ("fuse", {"prefer": "epilogue"}),
        ("autotile", {
            "cost": "roofline",
            "search": "pow2",
            "mem_cap_frac": 0.45,   # of VMEM; leaves room for double buffering
            "count_untiled": True,
        }),
        ("stencil", {"stencil": "mxu", "min_dim": 16}),
        ("boundary", {"mode": "remainder"}),
        ("localize", {"inner": "VMEM"}),
        ("schedule", {"unit": "VMEM"}),
    ),
)

# ---------------------------------------------------------------------------
# The paper's Fig. 4 cost-model machine: a generic cached architecture with
# an 8-element cache line and a 512-element tile budget.
# ---------------------------------------------------------------------------
PAPER_FIG4 = HardwareConfig(
    name="paper_fig4",
    mem_units=(
        MemoryUnit("DRAM", 1 << 40, 100e9, cache_line_elems=8),
        MemoryUnit("CACHE", 512, 1e12, cache_line_elems=8),  # 512 *elements*
    ),
    peak_flops=1e12,
    pipeline_depth=1,  # the paper's cost-model machine has no DMA pipeline
    passes=(
        ("autotile", {
            "cost": "cache_lines",
            "search": "divisors",
            "mem_cap_elems": 512,
            "count_untiled": False,  # Fig 4 excludes the (untiled) weights
            "exact_macs": True,
        }),
    ),
)

# A host-CPU config used by tests: small tiles, no stencils.
CPU_TEST = HardwareConfig(
    name="cpu_test",
    mem_units=(
        MemoryUnit("RAM", 1 << 40, 50e9, cache_line_elems=16),
        MemoryUnit("L2", 1 << 20, 500e9, cache_line_elems=16),
    ),
    peak_flops=1e11,
    passes=(
        ("fuse", {}),
        ("autotile", {"cost": "cache_lines", "search": "pow2", "mem_cap_elems": 4096}),
        ("boundary", {"mode": "remainder"}),
        ("localize", {"inner": "L2"}),
        ("schedule", {"unit": "L2"}),
    ),
)

REGISTRY: Dict[str, HardwareConfig] = {
    c.name: c for c in (TPU_V5E, PAPER_FIG4, CPU_TEST)
}


def get_config(name: str) -> HardwareConfig:
    """The registry accessor — the one way the rest of the framework (and
    the ``repro.explore`` sweeps) should name a hardware config."""
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware config {name!r}; "
            f"available configs: {sorted(REGISTRY)}") from None
