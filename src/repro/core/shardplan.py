"""Shard planning: how a Stripe program runs on a device mesh.

The paper claims the nested polyhedral model "naturally models …
multiple compute units"; this module is that claim at the machine level.
Given a *semantic* program (the frontend's flat op blocks, before any
single-device restructuring), :func:`plan_program` picks one split
index per block and derives everything the multi-device lowering needs:

* a :class:`BufView` per buffer per era — sharded at one dim (possibly
  with halo margins) or replicated;
* the explicit collectives (``psum`` / ``all_gather`` / halo
  ``ppermute`` pairs / ring-overlapped matmul) that keep the sharded
  execution bit-equivalent to the single-device one, each priced with
  the interconnect model in :mod:`repro.core.cost`;
* an ordered emission script (``plan.steps``) of shard-local compute
  *segments* interleaved with those collectives — ``mesh_lower`` plays
  it inside ``shard_map``, compiling each segment with the ordinary
  single-device ``stripe_jit`` pipeline (hybrid Pallas/jnp composer and
  all);
* local per-segment :class:`~repro.core.ir.Program`\\ s with every
  buffer resized to its shard-local shape, halo accesses shifted into
  the padded coordinate frame, and the frontend's boundary constraints
  dropped where zero-filled halo margins implement them for free.

Split selection is cost-arbitrated, not positional: every index of a
splittable block whose range divides the mesh size seeds a candidate
plan, the split is propagated forward through use-def chains (readers
of a sharded buffer vote with the index that carries the sharded dim),
and the plan with the lowest ``compute/n + exposed communication``
wins.  Three split kinds emerge:

* **output split** — the classic data-parallel case; downstream
  elementwise ops follow the sharded dim and only program outputs are
  gathered;
* **reduction split** — each shard computes a full-shape partial and a
  ``psum`` combines them; when the block is an exact matmul the plan
  may instead choose the **ring overlap**
  (``parallel.collective_matmul``'s reduce-scatter interleave), hiding
  the collective behind the shard-local compute when the cost model
  says the hiding exceeds the per-step ring overhead;
* **halo split** — a spatial dim of a stencil/conv is split and the
  margins exchanged with ``ppermute`` pairs.  Edge devices receive
  zeros (ppermute's fill), which is exactly the masking the frontend's
  boundary constraints encode — legal only for add-aggregated product
  blocks, which the planner checks.

Programs with no divisible index (or with access patterns outside the
supported forms) raise :class:`UnsupportedMesh`; the driver falls back
to the single-device path and records why.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .affine import Affine, aff
from .cost import RING_STEP_OVERHEAD_S, collective_seconds, link_bandwidth
from .hwconfig import HardwareConfig
from .ir import (
    Block,
    Program,
    RefDir,
    Refinement,
    TensorDecl,
    dtype_bytes,
    row_major_strides,
)


class UnsupportedMesh(Exception):
    """No shard plan exists for this program on this mesh — the caller
    should compile single-device and record the reason."""


@dataclasses.dataclass(frozen=True)
class BufView:
    """One buffer's layout during one era of the shard body: sharded at
    ``dim`` with ``lo``/``hi`` halo margins of padding, or replicated
    (``dim == -1``)."""

    dim: int = -1
    lo: int = 0
    hi: int = 0

    @property
    def sharded(self) -> bool:
        return self.dim >= 0

    def local_shape(self, shape: Sequence[int], n: int) -> Tuple[int, ...]:
        if not self.sharded:
            return tuple(shape)
        s = list(shape)
        s[self.dim] = s[self.dim] // n + self.lo + self.hi
        return tuple(s)


@dataclasses.dataclass
class Collective:
    """One inter-shard data movement the plan emits.  ``nbytes`` is the
    predicted per-device bytes actually moved over the links (ring
    formulas — an all-gather moves ``(n-1)/n`` of its payload, a psum
    twice that, a halo exactly its margins); ``pos`` is the semantic-
    block index *before* which it runs (``len(blocks)`` = epilogue)."""

    op: str              # "psum" | "all_gather" | "halo" | "ring_matmul"
    buffer: str
    nbytes: float
    pos: int
    dim: int = -1
    lo: int = 0
    hi: int = 0
    block: str = ""      # the block this collective serves
    overlap: bool = False
    t_comm_s: float = 0.0
    t_hidden_s: float = 0.0

    def to_json(self) -> Dict:
        return {
            "collective": self.op, "buffer": self.buffer,
            "bytes": self.nbytes, "block": self.block, "dim": self.dim,
            "lo": self.lo, "hi": self.hi,
            "overlap": self.overlap, "t_comm_s": self.t_comm_s,
            "t_hidden_s": self.t_hidden_s,
        }


@dataclasses.dataclass
class BlockPlan:
    """Per-semantic-block shard decision."""

    name: str
    kind: str                      # "shard" | "kred" | "ring" | "replicated"
    split: str = ""                # the split index ("" for replicated)
    views: Dict[str, BufView] = dataclasses.field(default_factory=dict)
    ring: Optional[Dict] = None    # {"x","w","out","m","f",...} for "ring"


@dataclasses.dataclass
class Segment:
    """A run of consecutive blocks compiled as one shard-local program."""

    program: Program
    inputs: List[str]
    outputs: List[str]


@dataclasses.dataclass
class ShardPlan:
    n: int
    mesh_shape: Tuple[int, ...]
    seed: str                              # "block.var" that seeded the plan
    block_plans: List[BlockPlan]
    in_specs: Dict[str, int]               # program input -> sharded dim (-1 = replicated)
    collectives: List[Collective]
    steps: List[Tuple]                     # ordered emission script
    compute_s: float = 0.0
    comm_s: float = 0.0

    @property
    def cost_s(self) -> float:
        exposed = sum(
            max(c.t_comm_s - (c.t_hidden_s if c.overlap else 0.0), 0.0)
            for c in self.collectives)
        return self.compute_s + exposed

    def collective_bytes(self) -> float:
        return sum(c.nbytes for c in self.collectives)

    def splits(self) -> Dict[str, str]:
        return {bp.name: bp.split for bp in self.block_plans if bp.split}

    def report(self, scale_compute: bool = True) -> List[Dict]:
        """Pass-trace records for ``score_pass_trace``.  ``scale_compute``
        emits the per-block split records that divide autotile roofline
        terms by ``n`` — the annotation path (partition ran before
        autotile, which then priced global shapes) wants it; the
        driver's mesh path, whose segment traces are already
        local-sized, must not."""
        out: List[Dict] = [{
            "mesh": list(self.mesh_shape), "n": self.n, "seed": self.seed,
            "compute_s": self.compute_s, "comm_s": self.comm_s,
            "collective_bytes": self.collective_bytes(),
        }]
        if scale_compute:
            out.extend({"block": bp.name, "split": bp.split, "n": self.n}
                       for bp in self.block_plans
                       if bp.split and bp.kind in ("shard", "kred", "ring"))
        out.extend(c.to_json() for c in self.collectives)
        return out

    # -------------------------------------------------------------- segments
    def build_segments(self, prog: Program) -> List[Segment]:
        """Materialize the plan's compute segments as shard-local
        programs over the *semantic* blocks of ``prog``."""
        semantic = prog.source or prog
        by_name = {s.name: s for s in semantic.entry.stmts
                   if isinstance(s, Block)}
        plans = {bp.name: bp for bp in self.block_plans}
        segments: List[Segment] = []
        for step in self.steps:
            if step[0] != "segment":
                continue
            names = step[2]
            seg_blocks = [self._localize(by_name[nm], plans[nm], semantic)
                          for nm in names]
            segments.append(self._seg_program(
                semantic, seg_blocks, [plans[nm] for nm in names],
                f"{semantic.entry.name}.seg{len(segments)}"))
        return segments

    def _localize(self, block: Block, bp: BlockPlan, prog: Program) -> Block:
        """One semantic block rewritten into shard-local coordinates."""
        b = block.clone(deep=True)
        n = self.n
        if bp.split:
            from .poly import Index

            b.idxs = [Index(i.name, i.range // n, i.affine)
                      if i.name == bp.split else i for i in b.idxs]
        drop: set = set()
        for r in b.refs:
            view = bp.views.get(r.from_buf)
            if view is None or not view.sharded:
                continue
            decl = prog.buffers[r.from_buf]
            local = view.local_shape(decl.shape, n)
            if r.strides is not None:
                r.strides = row_major_strides(local)
            if view.lo or view.hi:
                e0 = r.offsets[view.dim]
                if len(e0.terms) > 1 or e0.const != 0:
                    # zero-filled margins implement the frontend's
                    # boundary clamp; the constraints would now mask
                    # real neighbor data
                    size = decl.shape[view.dim]
                    drop.add(str(e0))
                    drop.add(str(aff(size - 1) - e0))
                offs = list(r.offsets)
                offs[view.dim] = e0 + aff(view.lo)
                r.offsets = tuple(offs)
        if drop:
            b.constraints = [c for c in b.constraints
                             if str(c.expr) not in drop]
        return b

    def _seg_program(self, prog: Program, seg_blocks: List[Block],
                     plans: List[BlockPlan], name: str) -> Segment:
        n = self.n
        views: Dict[str, BufView] = {}
        for bp in plans:
            for buf, v in bp.views.items():
                prev = views.get(buf)
                if prev is not None and prev != v:
                    raise UnsupportedMesh(
                        f"inconsistent views of {buf!r} within one segment "
                        f"({prev} vs {v}) — planner failed to cut")
                views[buf] = v
        buffers: Dict[str, TensorDecl] = {}
        for buf, v in views.items():
            d = prog.buffers[buf]
            buffers[buf] = TensorDecl(buf, v.local_shape(d.shape, n), d.dtype)
        written: List[str] = []
        read: List[str] = []
        for b in seg_blocks:
            for r in b.refs:
                if r.dir in (RefDir.OUT, RefDir.INOUT):
                    if r.from_buf not in written:
                        written.append(r.from_buf)
                elif r.from_buf not in read:
                    read.append(r.from_buf)
        inputs = [b for b in read if b not in written]
        # everything written survives the segment: later segments, ring
        # steps or the program epilogue may consume it, and shard-local
        # dead stores are cheap at these sizes
        outputs = list(written)
        entry = Block(name=name, tags={"main"})
        for buf, decl in buffers.items():
            dir_ = (RefDir.IN if buf in inputs
                    else (RefDir.OUT if buf in outputs else RefDir.INOUT))
            entry.refs.append(Refinement(
                dir=dir_, from_buf=buf, into=buf,
                offsets=(aff(0),) * decl.rank, shape=decl.shape,
                dtype=decl.dtype, strides=row_major_strides(decl.shape)))
        entry.stmts.extend(seg_blocks)
        local = Program(buffers=buffers, entry=entry,
                        inputs=inputs, outputs=outputs)
        return Segment(program=local, inputs=inputs, outputs=outputs)


# --------------------------------------------------------------------------
# access decomposition and block classification
# --------------------------------------------------------------------------
def _split_access(e: Affine, ranges: Mapping[str, int]):
    """Decompose an access expression along a sharded dim into
    ``(carrier, lo, hi)``: the unit-coefficient index that carries the
    shard, plus the halo margins the residual terms sweep over the other
    indices' boxes.  Returns ``(None, 0, 0)`` when no index qualifies."""
    cands = [v for v, c in e.terms if c == 1 and v in ranges]
    if not cands:
        return None, 0, 0
    v = max(cands, key=lambda x: ranges[x])
    lo = hi = e.const
    for w, c in e.terms:
        if w == v:
            continue
        ext = ranges.get(w, 1) - 1
        if c >= 0:
            hi += c * ext
        else:
            lo += c * ext
    return v, max(-lo, 0), max(hi, 0)


def _store_depends_on(block: Block, ref_into: str) -> bool:
    """Does the stored scalar transitively depend on the load from
    ``ref_into``?  (Halo legality: the margin-zeroed operand must reach
    the aggregation multiplicatively, i.e. be part of the product.)"""
    from .ir import Constant, Intrinsic, Load, Store

    deps: Dict[str, List[str]] = {}
    loaded: Dict[str, str] = {}
    stored: Optional[str] = None
    for s in block.stmts:
        if isinstance(s, Load):
            loaded[s.into] = s.buf
        elif isinstance(s, Intrinsic):
            deps[s.into] = list(s.args)
        elif isinstance(s, Constant):
            deps[s.into] = []
        elif isinstance(s, Store):
            stored = s.scalar
    if stored is None:
        return False
    seen, todo = set(), [stored]
    while todo:
        x = todo.pop()
        if x in seen:
            continue
        seen.add(x)
        if loaded.get(x) == ref_into:
            return True
        todo.extend(deps.get(x, ()))
    return False


def _mul_chain(block: Block) -> bool:
    from .ir import Intrinsic

    return all(s.op == "mul" for s in block.stmts if isinstance(s, Intrinsic))


def _block_seconds(block: Block, hw: HardwareConfig,
                   decls: Mapping[str, TensorDecl]) -> float:
    """Roofline proxy for candidate arbitration (not the autotiler's
    model — just enough to rank split choices consistently)."""
    iters = 1
    for i in block.idxs:
        if not i.is_passthrough():
            iters *= i.range
    flops = 2.0 * iters if "contraction" in block.tags else float(iters)
    nbytes = sum(decls[r.from_buf].size() * dtype_bytes(r.dtype)
                 for r in block.refs if r.from_buf in decls)
    hbm_bw = hw.mem_units[0].bandwidth if hw.mem_units else 1e11
    return max(flops / max(hw.peak_flops, 1.0), nbytes / max(hbm_bw, 1.0))


def _buf_bytes(decl: TensorDecl) -> float:
    return float(decl.size() * dtype_bytes(decl.dtype))


def _match_ring_matmul(block: Block, out_ref: Refinement,
                       in_refs: List[Refinement], split: str,
                       ranges: Mapping[str, int], n: int) -> Optional[Dict]:
    """Recognize ``O[m,f] += x[m,split] * w[split,f]`` with ``F % n == 0``
    and a float dtype — the shape ``ring_matmul_reduce_scatter`` lowers."""
    if out_ref.agg != "add" or len(in_refs) != 2:
        return None
    offs = out_ref.offsets
    if len(offs) != 2 or any(len(e.terms) != 1 or e.const != 0 or
                             e.terms[0][1] != 1 for e in offs):
        return None
    m, f = offs[0].terms[0][0], offs[1].terms[0][0]
    if f not in ranges or ranges[f] % n != 0:
        return None
    if out_ref.dtype not in ("float32", "bfloat16", "float16"):
        return None
    x = w = None
    for r in in_refs:
        if len(r.offsets) != 2:
            return None
        if r.offsets == (Affine.var(m), Affine.var(split)):
            x = r
        elif r.offsets == (Affine.var(split), Affine.var(f)):
            w = r
    if x is None or w is None:
        return None
    return {"x": x.from_buf, "w": w.from_buf, "out": out_ref.from_buf,
            "m": m, "f": f}


# --------------------------------------------------------------------------
# plan construction
# --------------------------------------------------------------------------
_MAX_SEEDS = 8


def plan_program(prog: Program, n: int, hw: HardwareConfig,
                 mesh_shape: Sequence[int] = ()) -> ShardPlan:
    """Pick the cheapest shard plan for ``prog`` over ``n`` devices.

    Works on the program's *semantic* form (``prog.source`` when passes
    already ran).  Raises :class:`UnsupportedMesh` when no candidate
    split survives."""
    if n <= 1:
        raise UnsupportedMesh("mesh has a single device")
    semantic = prog.source or prog
    blocks = [s for s in semantic.entry.stmts if isinstance(s, Block)]
    if not blocks or any(not isinstance(s, Block) for s in semantic.entry.stmts):
        raise UnsupportedMesh("program is not a flat list of op blocks")
    mesh_shape = tuple(int(s) for s in mesh_shape) or (n,)

    seeds: List[Tuple[int, str]] = []
    for bi, b in enumerate(blocks):
        for i in b.idxs:
            if (not i.is_passthrough() and i.range % n == 0
                    and i.range >= n and len(seeds) < _MAX_SEEDS):
                seeds.append((bi, i.name))
    if not seeds:
        raise UnsupportedMesh(f"no block index divisible by mesh size {n}")

    plans: List[ShardPlan] = []
    errors: List[str] = []
    for bi, v in seeds:
        try:
            got = _propagate(semantic, blocks, bi, v, n, hw, mesh_shape, {})
            if not isinstance(got, ShardPlan):
                # halo margins are global (max over readers); a second
                # pass applies them uniformly from the first use
                got = _propagate(semantic, blocks, bi, v, n, hw,
                                 mesh_shape, got)
            plans.append(got)
        except UnsupportedMesh as e:
            errors.append(f"{blocks[bi].name}.{v}: {e}")
    if not plans:
        raise UnsupportedMesh("; ".join(errors) or "no feasible split")
    return min(plans, key=lambda p: p.cost_s)


def _propagate(prog: Program, blocks: List[Block], seed_idx: int,
               seed_var: str, n: int, hw: HardwareConfig,
               mesh_shape: Tuple[int, ...],
               pre_halos: Dict[str, Tuple[int, int, int]]):
    """One candidate plan: seed ``blocks[seed_idx]`` on ``seed_var`` and
    propagate forward.  The first call runs with empty ``pre_halos`` and
    returns either a finished plan or the discovered program-input halo
    margins (a dict) for the second pass."""
    decls = prog.buffers
    bw = link_bandwidth(hw, mesh_shape)
    state: Dict[str, Optional[BufView]] = {}
    defined: set = set()
    used_replicated: set = set()
    in_specs: Dict[str, int] = {b: -1 for b in prog.inputs}
    input_halos: Dict[str, Tuple[int, int, int]] = dict(pre_halos)
    need_rerun = False
    collectives: List[Collective] = []
    events: List[Tuple[int, Tuple]] = []   # (pos, emission step)
    block_plans: List[BlockPlan] = []
    compute_s = 0.0

    def decl_bytes(buf: str) -> float:
        return _buf_bytes(decls[buf])

    def emit(op: str, buf: str, pos: int, *, dim=-1, lo=0, hi=0, block="",
             payload: float = 0.0, overlap=False, t_hidden=0.0, step=None):
        if not payload:
            if op == "halo":
                d = decls[buf]
                slice_elems = d.size() // max(d.shape[dim], 1)
                payload = float((lo + hi) * slice_elems * dtype_bytes(d.dtype))
            else:
                payload = decl_bytes(buf)
        t = collective_seconds(op, payload, n, bw)
        moved = collective_seconds(op, payload, n, 1.0)
        collectives.append(Collective(
            op=op, buffer=buf, nbytes=moved, pos=pos, dim=dim, lo=lo, hi=hi,
            block=block, overlap=overlap, t_comm_s=t, t_hidden_s=t_hidden))
        if step is not None:
            events.append((pos, step))

    def widen_input_halo(buf: str, d: int, lo: int, hi: int):
        nonlocal need_rerun
        prev = input_halos.get(buf, (d, 0, 0))
        if prev[0] != d:
            raise UnsupportedMesh(f"{buf!r} halo'd at two different dims")
        merged = (d, max(prev[1], lo), max(prev[2], hi))
        if merged != input_halos.get(buf):
            input_halos[buf] = merged
            need_rerun = True

    for bi, b in enumerate(blocks):
        ranges = {i.name: i.range for i in b.idxs}
        free = {i.name: i.range for i in b.idxs if not i.is_passthrough()}
        out_refs = [r for r in b.refs if r.dir in (RefDir.OUT, RefDir.INOUT)]
        if len(out_refs) != 1:
            raise UnsupportedMesh(f"{b.name}: expected exactly one output ref")
        out_ref = out_refs[0]
        out_buf = out_ref.from_buf
        if out_buf in defined:
            raise UnsupportedMesh(f"{b.name}: multiple writers of {out_buf!r}")
        in_refs = [r for r in b.refs if r.dir == RefDir.IN]
        out_dim: Dict[str, int] = {}
        for d, e in enumerate(out_ref.offsets):
            if len(e.terms) == 1 and e.terms[0][1] == 1 and e.const == 0:
                out_dim[e.terms[0][0]] = d

        # ---- votes: each sharded input nominates the index carrying it
        votes: Dict[str, List[Refinement]] = {}
        gathers: List[Refinement] = []
        for r in in_refs:
            st = state.get(r.from_buf)
            if st is None or not st.sharded:
                continue
            v, _, _ = _split_access(r.offsets[st.dim], ranges)
            if v is None or v not in free:
                gathers.append(r)
            else:
                votes.setdefault(v, []).append(r)
        split: Optional[str] = None
        if votes:
            split = max(votes, key=lambda v: sum(
                decl_bytes(r.from_buf) for r in votes[v]))
            for v2, rs in votes.items():
                if v2 != split:
                    gathers.extend(rs)
        elif bi == seed_idx:
            split = seed_var
        if split is not None and free.get(split, 0) % n != 0:
            gathers.extend(votes.get(split, ()))
            split = None

        # ---- gathers make their buffers replicated before this block
        for r in gathers:
            buf = r.from_buf
            st = state.get(buf)
            if st is None or not st.sharded:
                continue
            if st.lo or st.hi:
                raise UnsupportedMesh(
                    f"{b.name}: cannot all-gather halo-padded {buf!r}")
            emit("all_gather", buf, bi, dim=st.dim, block=b.name,
                 step=("gather", buf, st.dim))
            state[buf] = BufView(-1)

        views: Dict[str, BufView] = {}
        kind = "replicated"
        ring = None
        add_mul = out_ref.agg == "add" and _mul_chain(b)

        def use_replicated(buf: str):
            views[buf] = BufView(-1)
            if buf in in_specs and state.get(buf) is None:
                used_replicated.add(buf)

        def slice_event(buf: str, d: int):
            events.append((bi, ("slice", buf, d, decls[buf].shape[d] // n)))

        if split is None:
            for r in in_refs:
                if r.from_buf not in views:
                    use_replicated(r.from_buf)
            views[out_buf] = BufView(-1)
            state[out_buf] = BufView(-1)
            compute_s += _block_seconds(b, hw, decls)
        elif split in out_dim:
            kind = "shard"
            halo_drop: set = set()
            for r in in_refs:
                buf = r.from_buf
                hits = [d for d, e in enumerate(r.offsets)
                        if split in e.names()]
                if not hits:
                    st = state.get(buf)
                    if st is not None and st.sharded:
                        raise UnsupportedMesh(
                            f"{b.name}: {buf!r} sharded off split {split}")
                    use_replicated(buf)
                    continue
                if len(hits) != 1:
                    raise UnsupportedMesh(
                        f"{b.name}: split {split} addresses two dims of {buf!r}")
                d = hits[0]
                v, lo, hi = _split_access(r.offsets[d], ranges)
                if v != split:
                    raise UnsupportedMesh(
                        f"{b.name}: access to {buf!r} not carried by {split}")
                if decls[buf].shape[d] != free[split]:
                    raise UnsupportedMesh(
                        f"{b.name}: {buf!r} dim {d} size "
                        f"{decls[buf].shape[d]} != range({split})")
                if lo or hi:
                    if not (add_mul and _store_depends_on(b, r.into)):
                        raise UnsupportedMesh(
                            f"{b.name}: halo access to {buf!r} outside "
                            "add-aggregated product form")
                    if max(lo, hi) > free[split] // n:
                        raise UnsupportedMesh(
                            f"{b.name}: halo margin exceeds local extent")
                    e0 = r.offsets[d]
                    size = decls[buf].shape[d]
                    halo_drop.add(str(e0))
                    halo_drop.add(str(aff(size - 1) - e0))
                st = state.get(buf)
                if st is None:  # first use of a program input
                    if buf in used_replicated:
                        if lo or hi or input_halos.get(buf):
                            raise UnsupportedMesh(
                                f"{b.name}: {buf!r} needs halo but was "
                                "already consumed replicated")
                        slice_event(buf, d)
                        state[buf] = BufView(d)
                    else:
                        in_specs[buf] = d
                        if lo or hi:
                            widen_input_halo(buf, d, lo, hi)
                        known = input_halos.get(buf)
                        if known and (known[1] or known[2]):
                            if known[0] != d:
                                raise UnsupportedMesh(
                                    f"{buf!r} halo'd at two different dims")
                            emit("halo", buf, 0, dim=d, lo=known[1],
                                 hi=known[2], block=b.name,
                                 step=("halo", buf, d, known[1], known[2]))
                            state[buf] = BufView(d, known[1], known[2])
                        else:
                            state[buf] = BufView(d)
                elif not st.sharded:  # replicated intermediate -> slice
                    if lo or hi:
                        raise UnsupportedMesh(
                            f"{b.name}: halo access to replicated "
                            f"intermediate {buf!r}")
                    slice_event(buf, d)
                    state[buf] = BufView(d)
                else:
                    if st.dim != d:
                        raise UnsupportedMesh(
                            f"{b.name}: {buf!r} sharded at dim {st.dim}, "
                            f"accessed sharded at dim {d}")
                    want = BufView(d, max(st.lo, lo), max(st.hi, hi))
                    if want != st:
                        if buf not in defined:  # program input: widen + rerun
                            widen_input_halo(buf, d, want.lo, want.hi)
                            k = input_halos[buf]
                            state[buf] = BufView(d, k[1], k[2])
                        elif st.lo or st.hi:
                            raise UnsupportedMesh(
                                f"{b.name}: {buf!r} needs re-padding over "
                                "existing halo margins")
                        else:  # sharded intermediate gains margins here
                            emit("halo", buf, bi, dim=d, lo=want.lo,
                                 hi=want.hi, block=b.name,
                                 step=("halo", buf, d, want.lo, want.hi))
                            state[buf] = want
                views[buf] = state[buf]
            for c in b.constraints:
                if split in c.expr.names() and str(c.expr) not in halo_drop:
                    raise UnsupportedMesh(
                        f"{b.name}: constraint {c} involves split {split}")
            d_out = out_dim[split]
            if decls[out_buf].shape[d_out] != free[split]:
                raise UnsupportedMesh(
                    f"{b.name}: output dim size mismatch on {split}")
            views[out_buf] = BufView(d_out)
            state[out_buf] = BufView(d_out)
            compute_s += _block_seconds(b, hw, decls) / n
        else:
            # ---- reduction split: full-shape partials + psum (or ring)
            kind = "kred"
            if not add_mul:
                raise UnsupportedMesh(
                    f"{b.name}: reduction split {split} needs an "
                    "add-aggregated product block")
            for c in b.constraints:
                if split in c.expr.names():
                    raise UnsupportedMesh(
                        f"{b.name}: constraint {c} involves reduction "
                        f"split {split}")
            for r in in_refs:
                buf = r.from_buf
                hits = [d for d, e in enumerate(r.offsets)
                        if split in e.names()]
                if not hits:
                    st = state.get(buf)
                    if st is not None and st.sharded:
                        raise UnsupportedMesh(
                            f"{b.name}: {buf!r} sharded off the reduction")
                    use_replicated(buf)
                    continue
                if len(hits) != 1:
                    raise UnsupportedMesh(
                        f"{b.name}: split {split} addresses two dims of {buf!r}")
                d = hits[0]
                v, lo, hi = _split_access(r.offsets[d], ranges)
                if v != split or lo or hi:
                    raise UnsupportedMesh(
                        f"{b.name}: reduction access to {buf!r} not a "
                        f"plain {split}")
                if decls[buf].shape[d] != free[split]:
                    raise UnsupportedMesh(
                        f"{b.name}: {buf!r} dim {d} size != range({split})")
                st = state.get(buf)
                if st is None:
                    if buf in used_replicated:
                        slice_event(buf, d)
                    else:
                        in_specs[buf] = d
                    state[buf] = BufView(d)
                elif not st.sharded:
                    slice_event(buf, d)
                    state[buf] = BufView(d)
                elif st.dim != d or st.lo or st.hi:
                    raise UnsupportedMesh(
                        f"{b.name}: {buf!r} view conflicts with the "
                        "reduction split")
                views[buf] = state[buf]
            out_bytes = decl_bytes(out_buf)
            ring_info = _match_ring_matmul(b, out_ref, in_refs, split, free, n)
            overlap = False
            t_hidden = 0.0
            if ring_info is not None:
                t_mm_local = (2.0 * free.get(ring_info["m"], 1)
                              * free[ring_info["f"]] * (free[split] // n)
                              / max(hw.peak_flops, 1.0))
                t_rs = collective_seconds("reduce_scatter", out_bytes, n, bw)
                t_hidden = min(t_rs, t_mm_local * (n - 1) / n)
                overlap = t_hidden > n * RING_STEP_OVERHEAD_S
            if overlap:
                kind = "ring"
                ring = dict(ring_info, split=split, out_dtype=out_ref.dtype)
                emit("ring_matmul", out_buf, bi + 1, block=b.name,
                     payload=out_bytes, overlap=True, t_hidden=t_hidden,
                     step=("ring", b.name, ring))
                compute_s += t_mm_local
            else:
                emit("psum", out_buf, bi + 1, block=b.name,
                     payload=out_bytes, step=("psum", out_buf))
                compute_s += _block_seconds(b, hw, decls) / n
            views[out_buf] = BufView(-1)
            state[out_buf] = BufView(-1)

        defined.add(out_buf)
        block_plans.append(BlockPlan(
            name=b.name, kind=kind, split=split or "", views=views, ring=ring))

    # ---- epilogue: program outputs must end up replicated (global)
    for o in prog.outputs:
        st = state.get(o)
        if st is None:
            raise UnsupportedMesh(f"program output {o!r} never produced")
        if st.sharded:
            if st.lo or st.hi:
                raise UnsupportedMesh(f"program output {o!r} halo-padded")
            emit("all_gather", o, len(blocks), dim=st.dim, block="<output>",
                 step=("gather", o, st.dim))

    if need_rerun and not pre_halos:
        return input_halos
    if need_rerun:
        raise UnsupportedMesh("halo margins failed to converge")

    # ---- assemble the emission script: segments cut at every event
    steps: List[Tuple] = []
    cur: List[str] = []
    n_segs = 0

    def flush():
        nonlocal cur, n_segs
        if cur:
            steps.append(("segment", n_segs, tuple(cur)))
            n_segs += 1
            cur = []

    for bi, bp in enumerate(block_plans):
        pre = [s for p, s in events
               if p == bi and s[0] in ("halo", "gather", "slice")]
        if pre:
            flush()
            steps.extend(pre)
        if bp.kind == "ring":
            flush()
            steps.extend(s for p, s in events
                         if p == bi + 1 and s[0] == "ring" and s[1] == bp.name)
        else:
            cur.append(bp.name)
            post = [s for p, s in events if p == bi + 1 and s[0] == "psum"]
            if post:
                flush()
                steps.extend(post)
    flush()
    steps.extend(s for p, s in events
                 if p == len(block_plans) and s[0] == "gather")

    return ShardPlan(
        n=n, mesh_shape=mesh_shape,
        seed=f"{blocks[seed_idx].name}.{seed_var}",
        block_plans=block_plans, in_specs=in_specs,
        collectives=collectives, steps=steps, compute_s=compute_s,
        comm_s=sum(c.t_comm_s for c in collectives))
