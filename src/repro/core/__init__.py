"""Stripe: the paper's contribution — a nested-polyhedral tensor IR with a
config-driven optimization pass pipeline and jnp/Pallas backends."""
from .affine import Affine, aff
from .poly import Constraint, Index, Polyhedron
from .ir import (
    AGG_IDENTITY,
    AGG_OPS,
    Block,
    Constant,
    Intrinsic,
    Load,
    Location,
    Program,
    RefDir,
    Refinement,
    Special,
    Store,
    TensorDecl,
)
from .ir import canonical_ir, ir_fingerprint
from .frontend import TileProgram, single_op_program
from .interp import execute_reference
from .lower_jnp import lower_block_jnp, lower_program_jnp
from .validate import validate_program
from .cache import CompilationCache, get_default_cache, set_default_cache
from .driver import CompiledProgram, compile_cached, stripe_jit

__all__ = [
    "Affine", "aff", "Constraint", "Index", "Polyhedron",
    "AGG_IDENTITY", "AGG_OPS", "Block", "Constant", "Intrinsic", "Load",
    "Location", "Program", "RefDir", "Refinement", "Special", "Store",
    "TensorDecl", "TileProgram", "single_op_program", "execute_reference",
    "lower_block_jnp", "lower_program_jnp", "validate_program",
    "canonical_ir", "ir_fingerprint",
    "CompilationCache", "get_default_cache", "set_default_cache",
    "CompiledProgram", "compile_cached", "stripe_jit",
]
