"""Pallas backend: lower optimized (tiled/stenciled/fused) Stripe blocks to
``pl.pallas_call`` with explicit ``BlockSpec`` VMEM tiling.

TPU adaptation of Stripe's hardware lowering (see DESIGN.md): Stripe's
refinement-with-location (explicit DMA between memory units) maps to the
declarative BlockSpec (block shape + index_map); the optimization passes
*choose* the BlockSpec parameters:

* the grid = the outer ("grid") block's iteration space, ordered so
  reduction indices vary fastest (output block revisiting => VMEM-resident
  accumulation in an accumulator-dtype scratch); parallel output dimensions
  are declared via ``dimension_semantics`` so Mosaic may reorder/parallelize
  them;
* each refinement of the grid block becomes one BlockSpec: a view whose
  per-dimension offsets step in whole blocks indexes the operand directly;
  a **halo window** (offset step < block dim, or a non-zero base — the
  conv views of paper Fig. 5b) is emitted over a *materialized* operand:
  the overlapping tiles are gathered once per input (pad + strided gather,
  halo rows duplicated by the margin/step ratio) and indexed with an
  aligned BlockSpec over the gathered array;
* a whole **fusion group** (fuse.py) executes inside a single
  ``pallas_call`` as a tile-compute graph: elementwise *prologue* DAGs
  transform the input tiles, the MXU contraction runs via
  ``jax.lax.dot_general`` with f32 accumulation kept in a VMEM scratch
  across reduction grid steps, and the *epilogue* DAG (bias/activation
  chains, diamond joins — second elementwise inputs become extra
  BlockSpecs) is applied when the final reduction step completes
  (``pl.when``);
* plain elementwise blocks lower to a map kernel (no scratch);
* **constraint-carrying blocks** (conv halos, boundary remainders from
  non-dividing tiles) take the *windowed* path: window vars (e.g. the
  3x3 filter taps) are enumerated as unrolled kernel steps, each step
  contracts a shifted slice of the input tile, and the block's
  constraints become masks over the output tile (+ ``pl.program_id`` for
  grid-var terms) — a **masked store** writes the aggregation identity at
  constrained-out points.  Blocks the ``boundary`` pass proved
  constraint-free (tag ``interior``) skip the masks and lower densely.

``lower_program_hybrid`` lowers every op block / fusion group to Pallas
**independently**: a unit that cannot lower falls back to the jnp backend
for just that unit (``lower_jnp.lower_group_jnp`` on its semantic member
blocks), and units are composed in wavefront order.  One bad block no
longer costs the whole program its kernels.  ``lower_program_pallas``
keeps the strict contract (any unsupported block raises
``UnsupportedPallas``).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import memplan
from .ir import (Block, Constant, Intrinsic, Load, Program, Refinement,
                 RefDir, Store, TensorDecl)
from .lower_jnp import _J_BINARY, _J_UNARY, _acc_dtype

MAX_WINDOW_STEPS = 512           # unrolled kernel steps per grid point
MAX_HALO_BYTES = 256 * 2**20     # materialized (gathered) operand budget


class UnsupportedPallas(Exception):
    pass


class _ProgramFallback(UnsupportedPallas):
    """A structural hazard no per-unit fallback can fix (e.g. two units
    accumulating into one buffer — composition by region placement would
    silently drop contributions, and the per-group jnp executor would
    clobber them the same way).  Propagates out of the hybrid composer so
    the driver falls back wholesale."""


# --------------------------------------------------------------------------
# Pattern extraction
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DimSpec:
    """One dimension of a grid-block refinement: ``base + step*var`` start,
    ``size`` extent.  ``step < size`` (or ``base != 0``) is a halo window."""

    var: Optional[str]
    step: int
    base: int
    size: int

    @property
    def is_halo(self) -> bool:
        if self.var is None:
            return self.base != 0
        return self.step != self.size or self.base != 0


@dataclasses.dataclass
class GridRef:
    ref: Refinement
    block_shape: Tuple[int, ...]
    dim_vars: Tuple[Optional[str], ...]  # grid var addressing each dim
    dims: Tuple[DimSpec, ...] = ()

    @property
    def base(self) -> Tuple[int, ...]:
        return tuple(d.base for d in self.dims)

    @property
    def halo(self) -> bool:
        return any(d.is_halo for d in self.dims)


def _grid_ref(ref: Refinement, grid_ranges: Mapping[str, int],
              allow_base: bool = False, allow_halo: bool = False) -> GridRef:
    """Parse a grid-block refinement into per-dim (var, step, base, size).

    Default (strict) mode accepts only block-aligned views (step == size,
    base == 0) — the shape a plain BlockSpec can index.  ``allow_base``
    admits a constant base (the composer places the kernel's output region
    into the buffer); ``allow_halo`` admits overlapping windows (emitted
    over a materialized operand by the windowed path)."""
    dim_vars: List[Optional[str]] = []
    dims: List[DimSpec] = []
    for e, size in zip(ref.offsets, ref.shape):
        if e.is_const():
            if e.const != 0 and not (allow_base or allow_halo):
                raise UnsupportedPallas(f"non-zero const offset {e}")
            dim_vars.append(None)
            dims.append(DimSpec(None, 0, e.const, size))
        elif len(e.terms) == 1:
            (v, c) = e.terms[0]
            if v not in grid_ranges:
                raise UnsupportedPallas(f"offset var {v} is not a grid index")
            if c <= 0:
                raise UnsupportedPallas(f"non-positive offset step in {e}")
            if not allow_halo:
                if c != size:
                    raise UnsupportedPallas(
                        f"halo view: offset step {c} != block dim {size}")
                if e.const != 0 and not allow_base:
                    raise UnsupportedPallas(f"offset base {e.const} in {e}")
            dim_vars.append(v)
            dims.append(DimSpec(v, c, e.const, size))
        else:
            raise UnsupportedPallas(f"unsupported offset {e}")
    return GridRef(ref=ref, block_shape=tuple(ref.shape),
                   dim_vars=tuple(dim_vars), dims=tuple(dims))


@dataclasses.dataclass
class _TNode:
    """A node of the tile-compute graph (prologue/elementwise DAGs).

    Deliberately mirrors ``lower_jnp._Node`` (same kinds, same intrinsic
    tables) — the two walkers must stay in sync when intrinsics or DAG
    shapes are added, but operate at different granularities (whole-tile
    arrays here vs broadcast-materialized operands there)."""

    kind: str  # 'load' | 'const' | 'op'
    buf: str = ""
    value: float = 0.0
    op: str = ""
    args: Tuple["_TNode", ...] = ()

    def loads(self):
        if self.kind == "load":
            yield self
        for a in self.args:
            yield from a.loads()


def _leaf_root(stmts) -> _TNode:
    """Rebuild the expression DAG of a leaf statement list; returns the
    node stored by the (single) Store."""
    env: Dict[str, _TNode] = {}
    root: Optional[_TNode] = None
    for s in stmts:
        if isinstance(s, Load):
            env[s.into] = _TNode("load", buf=s.buf)
        elif isinstance(s, Constant):
            env[s.into] = _TNode("const", value=s.value)
        elif isinstance(s, Intrinsic):
            try:
                args = tuple(env[a] for a in s.args)
            except KeyError as e:
                raise UnsupportedPallas(f"undefined scalar {e} in leaf")
            env[s.into] = _TNode("op", op=s.op, args=args)
        elif isinstance(s, Store):
            root = env.get(s.scalar)
        elif isinstance(s, Block):
            raise UnsupportedPallas("nested block inside leaf")
    if root is None:
        raise UnsupportedPallas("leaf has no store")
    return root


def _split_sides(root: _TNode, sig_of: Mapping[str, Tuple]
                 ) -> Tuple[List[_TNode], float]:
    """Split the stored DAG into operand sides + a constant scale:
    top-level ``mul`` factors are grouped by the index pattern of their
    loads, so an elementwise prologue (e.g. ``gelu(A[i,c]) * B[c,j]``)
    stays attached to its operand side.  Returns 1 or 2 sides."""
    factors: List[_TNode] = []
    scale = 1.0
    stack = [root]
    while stack:
        n = stack.pop(0)
        if n.kind == "op" and n.op == "mul":
            stack = list(n.args) + stack
        elif n.kind == "const":
            scale *= n.value
        else:
            factors.append(n)
    groups: Dict[Tuple, List[_TNode]] = {}
    order: List[Tuple] = []
    for n in factors:
        sigs = set()
        for l in n.loads():
            if l.buf not in sig_of:
                raise UnsupportedPallas(f"leaf operand {l.buf} is not a grid input")
            sigs.add(sig_of[l.buf])
        if len(sigs) != 1:
            raise UnsupportedPallas("mixed index patterns inside one operand")
        sig = sigs.pop()
        if sig not in groups:
            groups[sig] = []
            order.append(sig)
        groups[sig].append(n)
    if not 1 <= len(order) <= 2:
        raise UnsupportedPallas(f"{len(order)} distinct operand groups (need 1 or 2)")

    def fold(ns: List[_TNode]) -> _TNode:
        out = ns[0]
        for n in ns[1:]:
            out = _TNode("op", op="mul", args=(out, n))
        return out

    return [fold(groups[s]) for s in order], scale


def _split_contraction(root: _TNode, sig_of: Mapping[str, Tuple]) -> Tuple[_TNode, _TNode, float]:
    sides, scale = _split_sides(root, sig_of)
    if len(sides) != 2:
        raise UnsupportedPallas(f"{len(sides)} distinct operand groups (need 2)")
    return sides[0], sides[1], scale


@dataclasses.dataclass
class ContractionPlan:
    grid_order: List[str]
    grid_sizes: Dict[str, int]
    in_refs: List[GridRef]
    out_ref: GridRef
    red_vars: List[str]
    lhs: _TNode
    rhs: _TNode
    lhs_bufs: List[str]  # grid-input names feeding each side, in spec order
    rhs_bufs: List[str]
    scale: float
    lhs_contract: Tuple[int, ...]
    rhs_contract: Tuple[int, ...]
    epilogue: List[object]
    acc_scalar: Optional[str]


@dataclasses.dataclass
class ElementwisePlan:
    grid_order: List[str]
    grid_sizes: Dict[str, int]
    in_refs: List[GridRef]
    out_ref: GridRef
    root: _TNode


def _leaf_of(block: Block) -> Block:
    cur = block
    while True:
        subs = cur.sub_blocks()
        if not subs:
            return cur
        if len(subs) != 1:
            raise UnsupportedPallas("multiple inner blocks")
        cur = subs[0]


def _is_constrained(block: Block) -> bool:
    """Does any block of this tree carry constraints?  The emitter trusts
    the passes' proofs instead of re-deriving them: ``boundary`` tags the
    pieces whose constraints ``prune_constraints`` fully discharged with
    ``interior`` (the whole tree is clean — skip the walk), and
    ``stencil`` tags the tiles whose stencil fit it established on an
    unconstrained body with ``dense`` (skip that block's check)."""
    if "interior" in block.tags:
        return False
    return any(b.constraints for b in block.walk() if "dense" not in b.tags)


def _check_no_constraints(block: Block) -> None:
    for b in block.walk():
        if b.constraints:
            raise UnsupportedPallas(
                f"constraints in block {b.name} (halo/overflow tiles)")


def _ensure_grid(outer: Block) -> Block:
    """Canonicalize a flat (``fits_inner``) or per-point fused block into
    the grid->tile shape the emitter expects, by splitting its output
    indices at full range (a 1-step grid per output dim)."""
    if "grid" in outer.tags:
        return outer
    from .tiling import split_block

    out_ref = next((r for r in outer.refs if r.dir in (RefDir.OUT, RefDir.INOUT)), None)
    if out_ref is None:
        raise UnsupportedPallas("no output ref")
    free = outer.idx_ranges()
    out_vars = [n for e in out_ref.offsets for n in e.names() if n in free]
    tiles = {v: free[v] for v in out_vars}
    if not tiles:
        raise UnsupportedPallas("no output indices to grid over")
    grid = split_block(outer, tiles, name_suffix="g", full_tiles=True)
    # the split is a pure canonicalization: proofs about the flat block
    # (boundary's interior tag) hold for its grid form
    if "interior" in outer.tags:
        grid.add_tag("interior")
    return grid


def _collect(outer: Block):
    """Common scaffolding: grid refs, local allocs, leaf stmts, epilogue."""
    grid_ranges = {i.name: i.range for i in outer.idxs if not i.is_passthrough()}
    ins: List[GridRef] = []
    out: Optional[GridRef] = None
    local_alloc: Dict[str, Refinement] = {}
    for r in outer.refs:
        if r.dir == RefDir.IN:
            ins.append(_grid_ref(r, grid_ranges))
        elif r.dir in (RefDir.OUT, RefDir.INOUT):
            if out is not None:
                raise UnsupportedPallas("multiple outputs")
            out = _grid_ref(r, grid_ranges, allow_base=True)
        elif r.dir == RefDir.NONE:
            local_alloc[r.into] = r
    if out is None:
        raise UnsupportedPallas("no output ref")

    sub_blocks = outer.sub_blocks()
    epilogue: List[object] = []
    if sub_blocks:
        for b in sub_blocks[0].walk():
            for r in b.refs:
                if r.dir == RefDir.NONE:
                    local_alloc.setdefault(r.into, r)
        # Descend levels; at each level, trailing leaf statements after a
        # sub-block are the (pure elementwise) fused epilogue, which lifts
        # soundly from per-point to per-tile granularity.
        cur: Block = outer
        leaf_stmts: List = []
        while True:
            msubs = cur.sub_blocks()
            trailing = []
            seen = False
            for s in cur.stmts:
                if isinstance(s, Block):
                    seen = True
                elif seen:
                    trailing.append(s)
            if msubs and trailing:
                epilogue = trailing
                leaf_stmts = list(_leaf_of(msubs[0]).stmts)
                break
            if not msubs:
                leaf_stmts = list(cur.stmts)
                break
            if len(msubs) != 1:
                raise UnsupportedPallas("multiple inner blocks")
            cur = msubs[0]
    else:
        leaf_stmts = list(outer.stmts)
    return grid_ranges, ins, out, local_alloc, leaf_stmts, epilogue


def extract_contraction(outer: Block) -> ContractionPlan:
    grid_ranges, ins, out, local_alloc, leaf_stmts, epilogue = _collect(outer)
    if (out.ref.agg or "assign") not in ("add", "assign"):
        # dot_general + the scratch accumulation only realize a SUM
        raise UnsupportedPallas(
            f"contraction aggregates with '{out.ref.agg}' (only add)")
    out_vars = {v for v in out.dim_vars if v}
    red_vars = [v for v in grid_ranges if v not in out_vars]
    grid_order = [v for v in grid_ranges if v in out_vars] + red_vars

    root = _leaf_root(leaf_stmts)
    sig_of = {g.ref.into: (g.dim_vars, g.block_shape) for g in ins}
    lhs, rhs, scale = _split_contraction(root, sig_of)

    acc_scalar: Optional[str] = None
    for s in epilogue:
        if isinstance(s, Load) and s.buf in local_alloc:
            acc_scalar = s.into

    def side_bufs(node: _TNode) -> List[str]:
        seen: List[str] = []
        for l in node.loads():
            if l.buf not in seen:
                seen.append(l.buf)
        return seen

    lhs_bufs, rhs_bufs = side_bufs(lhs), side_bufs(rhs)
    lhs_gr = next(g for g in ins if g.ref.into == lhs_bufs[0])
    rhs_gr = next(g for g in ins if g.ref.into == rhs_bufs[0])

    def contract_axes(gr: GridRef) -> List[int]:
        axes = []
        for d in range(gr.ref.rank):
            v = gr.dim_vars[d]
            if v is not None and v in out_vars:
                continue
            axes.append(d)
        return axes

    lhs_c, rhs_c = contract_axes(lhs_gr), contract_axes(rhs_gr)
    lhs_final, rhs_final, used = [], [], set()
    for a in lhs_c:
        for b in rhs_c:
            bv, av = rhs_gr.dim_vars[b], lhs_gr.dim_vars[a]
            if b in used or lhs_gr.block_shape[a] != rhs_gr.block_shape[b]:
                continue
            if av is not None and bv is not None and av != bv:
                continue  # distinct reduction vars never pair
            lhs_final.append(a)
            rhs_final.append(b)
            used.add(b)
            break
    if not lhs_final:
        raise UnsupportedPallas("no contraction dims found")

    return ContractionPlan(
        grid_order=grid_order, grid_sizes=grid_ranges, in_refs=ins, out_ref=out,
        red_vars=red_vars, lhs=lhs, rhs=rhs, lhs_bufs=lhs_bufs, rhs_bufs=rhs_bufs,
        scale=scale, lhs_contract=tuple(lhs_final), rhs_contract=tuple(rhs_final),
        epilogue=epilogue, acc_scalar=acc_scalar,
    )


def extract_elementwise(outer: Block) -> ElementwisePlan:
    grid_ranges, ins, out, _local, leaf_stmts, epilogue = _collect(outer)
    if epilogue:
        raise UnsupportedPallas("elementwise block with trailing epilogue")
    root = _leaf_root(leaf_stmts)
    # broadcast legality: each input's addressed dims must line up with the
    # trailing dims of the output tile (numpy broadcasting in the kernel)
    out_dv = list(out.dim_vars)
    for g in ins:
        dv = list(g.dim_vars)
        tail = out_dv[len(out_dv) - len(dv):] if len(dv) <= len(out_dv) else None
        if tail is None:
            raise UnsupportedPallas(f"input {g.ref.into} has higher rank than output")
        for d, v in enumerate(dv):
            if v is None and g.block_shape[d] == 1:
                continue
            if v != tail[d] and g.block_shape[d] != 1:
                raise UnsupportedPallas(
                    f"input {g.ref.into} dim {d} does not broadcast against the output")
    grid_order = [v for v in grid_ranges]
    if any(v not in {d for d in out.dim_vars if d} for v in grid_order):
        raise UnsupportedPallas("elementwise block with reduction index")
    return ElementwisePlan(grid_order=grid_order, grid_sizes=grid_ranges,
                           in_refs=ins, out_ref=out, root=root)


# --------------------------------------------------------------------------
# Windowed (halo / masked) extraction
# --------------------------------------------------------------------------
@dataclasses.dataclass
class WindowedPlan:
    """A constraint- or halo-carrying block as the windowed kernel sees it:
    grid refs (halo views allowed), the tile-level addressing of each
    input, enumerated window vars, and the constraint exprs that become
    masks over the output tile."""

    grid_order: List[str]
    grid_sizes: Dict[str, int]
    in_refs: List[GridRef]
    out_ref: GridRef
    red_vars: List[str]                      # grid vars revisiting the output
    tile_ranges: Dict[str, int]
    out_axis_vars: Tuple[Optional[str], ...]  # tile var per output dim
    inner_offsets: Dict[str, Tuple]          # ref.into -> tile-level offsets
    window_vars: List[str]
    agg: str                                 # "add" | "assign"
    sides: Optional[List[_TNode]]            # contraction sides (agg=add)
    root: Optional[_TNode]                   # full DAG (agg=assign)
    scale: float
    constraint_exprs: List                   # affine exprs, each ">= 0"


def extract_windowed(outer: Block) -> WindowedPlan:
    grid_ranges = {i.name: i.range for i in outer.idxs if not i.is_passthrough()}
    subs = outer.sub_blocks()
    if len(subs) != 1:
        raise UnsupportedPallas("windowed path needs exactly one tile block")
    if any(not isinstance(s, Block) for s in outer.stmts):
        raise UnsupportedPallas("windowed path does not support fused epilogues")
    tile = subs[0]
    if tile.sub_blocks():
        raise UnsupportedPallas("windowed path needs a flat tile block")

    ins: List[GridRef] = []
    out: Optional[GridRef] = None
    for r in outer.refs:
        if r.dir == RefDir.IN:
            ins.append(_grid_ref(r, grid_ranges, allow_halo=True))
        elif r.dir in (RefDir.OUT, RefDir.INOUT):
            if out is not None:
                raise UnsupportedPallas("multiple outputs")
            out = _grid_ref(r, grid_ranges, allow_base=True)
        elif r.dir == RefDir.NONE and not r.is_scalar_view():
            raise UnsupportedPallas("windowed path with non-scalar local view")
    if out is None:
        raise UnsupportedPallas("no output ref")
    agg = out.ref.agg or "assign"
    if agg not in ("add", "assign"):
        raise UnsupportedPallas(f"windowed path cannot aggregate with '{agg}'")

    tile_ranges = tile.idx_ranges()
    inner = {r.from_buf: r for r in tile.refs}

    # output tile addressing: one plain tile var (or const 0) per dim
    oref = inner.get(out.ref.into)
    if oref is None:
        raise UnsupportedPallas("tile block does not address the output view")
    out_axis_vars: List[Optional[str]] = []
    for e in oref.offsets:
        if e.is_const():
            if e.const != 0:
                raise UnsupportedPallas(f"non-zero inner output offset {e}")
            out_axis_vars.append(None)
        elif len(e.terms) == 1 and e.const == 0 and e.terms[0][1] == 1:
            out_axis_vars.append(e.terms[0][0])
        else:
            raise UnsupportedPallas(f"output tile offset {e} is not a plain index")
    out_vars = {v for v in out_axis_vars if v}

    # tile addressing of each input + window-var discovery
    inner_offsets: Dict[str, Tuple] = {}
    window: set = set()
    for gr in ins:
        ir = inner.get(gr.ref.into)
        if ir is None:
            raise UnsupportedPallas(f"tile block does not address input {gr.ref.into}")
        for e in ir.offsets:
            for n, c in e.terms:
                if n not in tile_ranges:
                    raise UnsupportedPallas(f"inner offset var {n} is not a tile index")
                if c <= 0:
                    raise UnsupportedPallas(f"negative inner offset step in {e}")
            names = [n for n in e.names() if tile_ranges.get(n, 1) > 1]
            if len(names) > 1:
                carriers = [n for n in names if n in out_vars] or names
                carrier = max(carriers, key=lambda n: tile_ranges[n])
                window.update(n for n in names if n != carrier)
        inner_offsets[gr.ref.into] = tuple(ir.offsets)

    # constraints close over window vars: any constraint var that is
    # neither an output-tile coordinate nor a grid index must be enumerated
    exprs = [c.expr for c in outer.constraints] + [c.expr for c in tile.constraints]
    for _ in range(4):
        extra = set()
        for e in exprs:
            for n in e.names():
                if n in out_vars or n in grid_ranges or n in window:
                    continue
                if n in tile_ranges:
                    extra.add(n)
                else:
                    raise UnsupportedPallas(f"constraint var {n} is not in scope")
        if not extra:
            break
        window |= extra
    if window & out_vars:
        raise UnsupportedPallas(
            f"window vars {sorted(window & out_vars)} address the output")
    window_vars = sorted(window)
    n_steps = 1
    for v in window_vars:
        n_steps *= tile_ranges[v]
    if n_steps > MAX_WINDOW_STEPS:
        raise UnsupportedPallas(f"window too large ({n_steps} unrolled steps)")

    out_grid_vars = {v for v in out.dim_vars if v}
    red_vars = [v for v in grid_ranges if v not in out_grid_vars]
    grid_order = [v for v in grid_ranges if v in out_grid_vars] + red_vars

    root = _leaf_root(tile.stmts)
    sides: Optional[List[_TNode]] = None
    scale = 1.0
    if agg == "add":
        sig_of = {gr.ref.into: tuple(str(e) for e in inner_offsets[gr.ref.into])
                  for gr in ins}
        sides, scale = _split_sides(root, sig_of)
        root = None
    else:
        # assign must be a pure per-point map: no enumerated windows, no
        # leftover reduction axes (a raced overwrite otherwise)
        if window_vars:
            raise UnsupportedPallas("assign block with window vars")
        if red_vars:
            raise UnsupportedPallas("assign block with grid reduction vars")
        leftover = [v for v, r in tile_ranges.items()
                    if r > 1 and v not in out_vars]
        if leftover:
            raise UnsupportedPallas(f"assign block with reduction tile vars {leftover}")

    return WindowedPlan(
        grid_order=grid_order, grid_sizes=grid_ranges, in_refs=ins, out_ref=out,
        red_vars=red_vars, tile_ranges=tile_ranges,
        out_axis_vars=tuple(out_axis_vars), inner_offsets=inner_offsets,
        window_vars=window_vars, agg=agg, sides=sides, root=root, scale=scale,
        constraint_exprs=exprs,
    )


# --------------------------------------------------------------------------
# Kernel emission
# --------------------------------------------------------------------------
def _eval_tnode(n: _TNode, tiles: Mapping[str, jnp.ndarray], dtype=None):
    if n.kind == "load":
        return tiles[n.buf]
    if n.kind == "const":
        return jnp.asarray(n.value, dtype or jnp.float32)
    args = [_eval_tnode(a, tiles, dtype) for a in n.args]
    fn = _J_UNARY[n.op] if len(args) == 1 and n.op in _J_UNARY else _J_BINARY[n.op]
    return fn(*args)


def _apply_epilogue(plan: ContractionPlan, acc, tile_args: Dict[str, jnp.ndarray]):
    env: Dict[str, jnp.ndarray] = {}
    result = acc
    for s in plan.epilogue:
        if isinstance(s, Load):
            env[s.into] = acc if s.into == plan.acc_scalar else tile_args[s.buf]
        elif isinstance(s, Constant):
            env[s.into] = jnp.asarray(s.value, acc.dtype)
        elif isinstance(s, Intrinsic):
            args = [env[a] for a in s.args]
            fn = _J_UNARY[s.op] if len(args) == 1 and s.op in _J_UNARY else _J_BINARY[s.op]
            env[s.into] = fn(*args)
        elif isinstance(s, Store):
            result = env[s.scalar]
    return result


def _dimension_semantics(grid_order: List[str], red_vars) -> Optional[object]:
    """Mark parallel (output) grid axes for Mosaic; reduction axes are
    'arbitrary' because the scratch accumulation carries state across
    their steps."""
    red = set(red_vars)
    sem = tuple("arbitrary" if v in red else "parallel" for v in grid_order)
    try:
        return pltpu.TPUCompilerParams(dimension_semantics=sem)
    except Exception:  # pragma: no cover - API drift across jax versions
        return None


def _index_map_for(gr: GridRef, gpos: Mapping[str, int]):
    def imap(*gidx):
        return tuple(gidx[gpos[v]] if v is not None else 0 for v in gr.dim_vars)
    return imap


def _halo_spec(gr: GridRef, grid_sizes: Mapping[str, int],
               buf_shape: Tuple[int, ...], gpos: Mapping[str, int]):
    """Emission plan for a halo-windowed input: ``prepare`` gathers the
    overlapping tiles once per input (pad to cover the base/overflow, then
    a strided gather per grid-addressed dim — halo rows materialized once,
    duplicated by the margin/step ratio), and the returned BlockSpec
    indexes the gathered array block-aligned (leading grid axes of extent
    1)."""
    dims = gr.dims
    lead_vars = [d.var for d in dims if d.var is not None]
    pads = []
    total = 1
    for d, bdim in zip(dims, buf_shape):
        g = grid_sizes[d.var] if d.var is not None else 1
        lo = d.base
        hi = d.base + (d.step * (g - 1) if d.var is not None else 0) + d.size
        pads.append((max(0, -lo), max(0, hi - bdim)))
        total *= g * d.size if d.var is not None else d.size
    if total * np.dtype(gr.ref.dtype).itemsize > MAX_HALO_BYTES:
        raise UnsupportedPallas(
            f"materialized halo view of {gr.ref.from_buf} too large "
            f"({total} elems)")

    def prepare(arr: jnp.ndarray) -> jnp.ndarray:
        if any(p != (0, 0) for p in pads):
            arr = jnp.pad(arr, pads)
        lead = 0
        for i, d in enumerate(dims):
            start = d.base + pads[i][0]
            if d.var is None:
                arr = jax.lax.slice_in_dim(arr, start, start + d.size,
                                           axis=lead + i)
            else:
                g = grid_sizes[d.var]
                idx = start + d.step * jnp.arange(g)[:, None] + jnp.arange(d.size)[None, :]
                arr = jnp.take(arr, idx, axis=lead + i)
                arr = jnp.moveaxis(arr, lead + i, lead)
                lead += 1
        return arr

    block_shape = (1,) * len(lead_vars) + tuple(d.size for d in dims)

    def imap(*gidx):
        return tuple(gidx[gpos[v]] for v in lead_vars) + (0,) * len(dims)

    return prepare, block_shape, imap


def _tile_slice(arr: jnp.ndarray, exprs, tile_ranges: Mapping[str, int],
                wenv: Mapping[str, int]) -> Tuple[jnp.ndarray, List[str]]:
    """Static slice of a tile for one window position: each offset expr,
    after substituting the window vars, must reduce to ``c*v + k`` or a
    constant.  Returns (sliced array, axis var names)."""
    index: List[object] = []
    axes: List[str] = []
    for e in exprs:
        ep = e.partial_eval(wenv)
        if ep.is_const():
            index.append(ep.const)
            continue
        if len(ep.terms) != 1:
            raise UnsupportedPallas(f"multi-var tile access {ep} after windowing")
        (v, c), k = ep.terms[0], ep.const
        r = tile_ranges[v]
        index.append(slice(k, k + c * (r - 1) + 1, c))
        axes.append(v)
    return arr[tuple(index)], axes


def _eval_plain(n: _TNode, sliced: Mapping[str, Tuple], dtype):
    """Evaluate a one-sided DAG on sliced tiles (all loads of a side share
    one index signature, so shapes agree elementwise)."""
    if n.kind == "load":
        return sliced[n.buf][0]
    if n.kind == "const":
        return jnp.asarray(n.value, dtype)
    args = [_eval_plain(a, sliced, dtype) for a in n.args]
    fn = _J_UNARY[n.op] if len(args) == 1 and n.op in _J_UNARY else _J_BINARY[n.op]
    return fn(*args)


def _eval_dag_axes(n: _TNode, sliced: Mapping[str, Tuple],
                   tile_ranges: Mapping[str, int], dtype):
    """Evaluate a full (assign) DAG on sliced tiles, threading axis names
    and broadcasting args onto the union axis order."""
    if n.kind == "load":
        return sliced[n.buf]
    if n.kind == "const":
        return jnp.asarray(n.value, dtype), []
    vals = [_eval_dag_axes(a, sliced, tile_ranges, dtype) for a in n.args]
    union: List[str] = []
    for _, ax in vals:
        for v in ax:
            if v not in union:
                union.append(v)
    bargs = []
    for arr, ax in vals:
        if not ax:
            bargs.append(arr)
            continue
        perm = [ax.index(v) for v in union if v in ax]
        a = jnp.transpose(arr, perm)
        a = a.reshape([tile_ranges[v] if v in ax else 1 for v in union])
        bargs.append(a)
    fn = _J_UNARY[n.op] if len(bargs) == 1 and n.op in _J_UNARY else _J_BINARY[n.op]
    return fn(*bargs), union


def _contract_sides(sides_vals: List[Tuple[jnp.ndarray, List[str]]],
                    out_vars: set, acc_dtype) -> Tuple[jnp.ndarray, List[str]]:
    """Contract 1 or 2 evaluated sides: shared non-output axes feed
    ``dot_general`` (shared output axes batch), leftover non-output axes
    are summed out."""
    if len(sides_vals) == 1:
        val, axes = sides_vals[0]
        val = val.astype(acc_dtype)
    else:
        (la, lax), (ra, rax) = sides_vals
        shared = [v for v in lax if v in rax]
        contract = [v for v in shared if v not in out_vars]
        batch = [v for v in shared if v in out_vars]
        dn = ((tuple(lax.index(v) for v in contract),
               tuple(rax.index(v) for v in contract)),
              (tuple(lax.index(v) for v in batch),
               tuple(rax.index(v) for v in batch)))
        val = jax.lax.dot_general(la, ra, dn, preferred_element_type=acc_dtype)
        axes = batch + [v for v in lax if v not in shared] + \
            [v for v in rax if v not in shared]
    extra = [v for v in axes if v not in out_vars]
    if extra:
        val = jnp.sum(val, axis=tuple(axes.index(v) for v in extra))
        axes = [v for v in axes if v in out_vars]
    return val, axes


def _emit_windowed(plan: WindowedPlan, interpret: bool,
                   mp: Optional[memplan.BlockPlan] = None,
                   buffers: Optional[Mapping[str, TensorDecl]] = None) -> Callable:
    grid = tuple(plan.grid_sizes[v] for v in plan.grid_order)
    gpos = {v: i for i, v in enumerate(plan.grid_order)}
    out_block = plan.out_ref.block_shape
    out_dtype = np.dtype(plan.out_ref.ref.dtype)
    acc_dtype = _acc_dtype(plan.out_ref.ref.dtype)
    has_red = bool(plan.red_vars)
    if mp is not None and ((mp.acc_bytes > 0) != has_red
                           or set(mp.red_vars) != set(plan.red_vars)):
        raise UnsupportedPallas(
            f"memory plan disagrees with emitter: plan acc={mp.acc_bytes}B "
            f"red={sorted(mp.red_vars)} vs emitter red={sorted(plan.red_vars)}")

    preps: List[Tuple[Optional[Callable], Tuple[int, ...]]] = []
    in_specs = []
    for gr in plan.in_refs:
        if gr.halo:
            if buffers is None or gr.ref.from_buf not in buffers:
                raise UnsupportedPallas(
                    f"halo view of {gr.ref.from_buf} needs the buffer shape")
            prep, bshape, imap = _halo_spec(
                gr, plan.grid_sizes, tuple(buffers[gr.ref.from_buf].shape), gpos)
            preps.append((prep, bshape))
            in_specs.append(pl.BlockSpec(bshape, imap))
        else:
            preps.append((None, gr.block_shape))
            in_specs.append(pl.BlockSpec(gr.block_shape, _index_map_for(gr, gpos)))
    out_spec = pl.BlockSpec(out_block, _index_map_for(plan.out_ref, gpos))
    out_full_shape = tuple(
        s * (plan.grid_sizes[v] if v else 1)
        for s, v in zip(out_block, plan.out_ref.dim_vars))

    combos = list(itertools.product(
        *[range(plan.tile_ranges[v]) for v in plan.window_vars])) or [()]
    out_vars = {v for v in plan.out_axis_vars if v}
    out_axis_pos = {v: d for d, v in enumerate(plan.out_axis_vars) if v}
    cast_ints = np.dtype(out_dtype).kind in "iu"
    has_mask = bool(plan.constraint_exprs)

    def to_out_block(val: jnp.ndarray, axes: List[str]) -> jnp.ndarray:
        target = [v for v in plan.out_axis_vars if v is not None and v in axes]
        perm = [axes.index(v) for v in target]
        if perm != list(range(len(axes))):
            val = jnp.transpose(val, perm)
        shape = [plan.tile_ranges[v] if (v is not None and v in axes) else 1
                 for v in plan.out_axis_vars]
        return jnp.broadcast_to(val.reshape(shape), out_block)

    def step_mask(wenv: Mapping[str, int]):
        mask = None
        for e in plan.constraint_exprs:
            ep = e.partial_eval(wenv)
            if ep.is_const():
                if ep.const >= 0:
                    continue
                m = jnp.zeros(out_block, jnp.bool_)
            else:
                acc = jnp.full(out_block, ep.const, jnp.int32)
                for n, c in ep.terms:
                    if n in out_axis_pos:
                        acc = acc + c * jax.lax.broadcasted_iota(
                            jnp.int32, out_block, out_axis_pos[n])
                    else:
                        acc = acc + c * pl.program_id(gpos[n])
                m = acc >= 0
            mask = m if mask is None else mask & m
        return mask

    def kernel(*refs):
        if has_red:
            *ins, out_ref, acc_ref = refs
        else:
            *ins, out_ref = refs
            acc_ref = None
        tiles = {}
        for (prep, _bshape), gr, ref in zip(preps, plan.in_refs, ins):
            t = ref[...]
            if t.shape != gr.block_shape:
                t = t.reshape(gr.block_shape)
            tiles[gr.ref.into] = t
        total = None
        for combo in combos:
            wenv = dict(zip(plan.window_vars, combo))
            sliced = {}
            for gr in plan.in_refs:
                arr, axes = _tile_slice(tiles[gr.ref.into],
                                        plan.inner_offsets[gr.ref.into],
                                        plan.tile_ranges, wenv)
                if cast_ints:
                    arr = arr.astype(acc_dtype)
                sliced[gr.ref.into] = (arr, axes)
            if plan.sides is not None:
                vals = []
                for side in plan.sides:
                    axes = next((sliced[l.buf][1] for l in side.loads()), [])
                    vals.append((_eval_plain(side, sliced, acc_dtype), axes))
                val, axes = _contract_sides(vals, out_vars, acc_dtype)
            else:
                val, axes = _eval_dag_axes(plan.root, sliced,
                                           plan.tile_ranges, acc_dtype)
            val = to_out_block(val, axes).astype(acc_dtype)
            if plan.scale != 1.0:
                val = val * jnp.asarray(plan.scale, acc_dtype)
            if has_mask:
                mask = step_mask(wenv)
                if mask is not None:
                    # masked store: constrained-out points contribute the
                    # aggregation identity (0 for add; assign buffers are
                    # zero-initialized, paper Fig. 4's "overflow elements
                    # removed by constraints")
                    val = jnp.where(mask, val, jnp.zeros_like(val))
            total = val if total is None else total + val
        if has_red:
            first = functools.reduce(
                jnp.logical_and,
                [pl.program_id(gpos[v]) == 0 for v in plan.red_vars])
            last = functools.reduce(
                jnp.logical_and,
                [pl.program_id(gpos[v]) == plan.grid_sizes[v] - 1
                 for v in plan.red_vars])

            @pl.when(first)
            def _init():
                acc_ref[...] = jnp.zeros(out_block, acc_dtype)

            acc_ref[...] += total

            @pl.when(last)
            def _flush():
                out_ref[...] = acc_ref[...].astype(out_ref.dtype)
        else:
            out_ref[...] = total.astype(out_ref.dtype)

    kwargs = {}
    if not interpret:
        cp = _dimension_semantics(plan.grid_order,
                                  mp.red_vars if mp is not None else plan.red_vars)
        if cp is not None:
            kwargs["compiler_params"] = cp
    scratch = [pltpu.VMEM(out_block, acc_dtype)] if has_red else []
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_full_shape, out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )

    def fn(arrays: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        args = []
        for (prep, _), gr in zip(preps, plan.in_refs):
            a = jnp.asarray(arrays[gr.ref.from_buf])
            args.append(prep(a) if prep is not None else a)
        return call(*args)

    fn.out_shape = out_full_shape
    fn.out_dtype = out_dtype
    fn.out_base = plan.out_ref.base
    fn.in_bufs = [g.ref.from_buf for g in plan.in_refs]
    return fn


def _emit_contraction(plan: ContractionPlan, interpret: bool,
                      mp: Optional[memplan.BlockPlan] = None) -> Callable:
    grid = tuple(plan.grid_sizes[v] for v in plan.grid_order)
    gpos = {v: i for i, v in enumerate(plan.grid_order)}

    side = set(plan.lhs_bufs) | set(plan.rhs_bufs)
    operand_grs = [g for g in plan.in_refs if g.ref.into in side]
    extra = [g for g in plan.in_refs if g.ref.into not in side]
    order = operand_grs + extra

    dnums = ((plan.lhs_contract, plan.rhs_contract), ((), ()))
    out_dtype = np.dtype(plan.out_ref.ref.dtype)
    acc_dtype = _acc_dtype(plan.out_ref.ref.dtype)
    cast_ints = np.dtype(out_dtype).kind in "iu"
    out_block = plan.out_ref.block_shape
    has_red = bool(plan.red_vars)
    # The memory plan decides scratch residency: a revisited output plans
    # one partial-sum tile that must agree with the emitter's own
    # reduction analysis — a mismatch means the schedule placed the
    # accumulator differently than this kernel would use it.
    if mp is not None:
        if (mp.acc_bytes > 0) != has_red or set(mp.red_vars) != set(plan.red_vars):
            raise UnsupportedPallas(
                f"memory plan disagrees with emitter: plan acc={mp.acc_bytes}B "
                f"red={sorted(mp.red_vars)} vs emitter red={sorted(plan.red_vars)}")
        out_elems = 1
        for s in out_block:
            out_elems *= s
        if has_red and mp.acc_bytes != out_elems * 4:
            raise UnsupportedPallas(
                f"planned scratch {mp.acc_bytes}B != f32 out tile {out_elems * 4}B")

    def kernel(*refs):
        if has_red:
            *ins, out_ref, acc_ref = refs
        else:
            *ins, out_ref = refs
            acc_ref = None
        tiles = {g.ref.into: ins[i][...] for i, g in enumerate(order)}
        if cast_ints:
            tiles = {k: v.astype(acc_dtype) for k, v in tiles.items()}
        lhs = _eval_tnode(plan.lhs, tiles)
        rhs = _eval_tnode(plan.rhs, tiles)
        part = jax.lax.dot_general(lhs, rhs, dnums, preferred_element_type=acc_dtype)
        part = part.reshape(out_block)
        if plan.scale != 1.0:
            part = part * jnp.asarray(plan.scale, part.dtype)
        tile_args = {g.ref.into: tiles[g.ref.into] for g in extra}
        if has_red:
            first = functools.reduce(
                jnp.logical_and, [pl.program_id(gpos[v]) == 0 for v in plan.red_vars]
            )
            last = functools.reduce(
                jnp.logical_and,
                [pl.program_id(gpos[v]) == plan.grid_sizes[v] - 1 for v in plan.red_vars],
            )

            @pl.when(first)
            def _init():
                acc_ref[...] = jnp.zeros(out_block, acc_dtype)

            acc_ref[...] += part

            @pl.when(last)
            def _flush():
                val = acc_ref[...]
                if plan.epilogue:
                    val = _apply_epilogue(plan, val, tile_args)
                out_ref[...] = val.astype(out_ref.dtype)
        else:
            val = part
            if plan.epilogue:
                val = _apply_epilogue(plan, val, tile_args)
            out_ref[...] = val.astype(out_ref.dtype)

    in_specs = [pl.BlockSpec(g.block_shape, _index_map_for(g, gpos)) for g in order]
    out_spec = pl.BlockSpec(out_block, _index_map_for(plan.out_ref, gpos))
    out_full_shape = tuple(
        s * (plan.grid_sizes[v] if v else 1)
        for s, v in zip(out_block, plan.out_ref.dim_vars)
    )

    kwargs = {}
    if not interpret:
        # planned slots gate the semantics: grid axes that stream the
        # output may be reordered/parallelized by Mosaic; axes that
        # revisit the planned accumulator carry state and stay arbitrary
        cp = _dimension_semantics(plan.grid_order,
                                  mp.red_vars if mp is not None else plan.red_vars)
        if cp is not None:
            kwargs["compiler_params"] = cp
    scratch = []
    if has_red:
        # sized by the memory plan when available (acc_bytes == f32 out
        # tile, verified above), else by the emitter's own analysis
        scratch = [pltpu.VMEM(out_block, acc_dtype)]
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_full_shape, out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )

    def fn(arrays: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        args = [jnp.asarray(arrays[g.ref.from_buf]) for g in order]
        return call(*args)

    fn.out_shape = out_full_shape
    fn.out_dtype = out_dtype
    fn.out_base = plan.out_ref.base
    fn.in_bufs = [g.ref.from_buf for g in order]
    return fn


def _emit_elementwise(plan: ElementwisePlan, interpret: bool) -> Callable:
    grid = tuple(plan.grid_sizes[v] for v in plan.grid_order)
    gpos = {v: i for i, v in enumerate(plan.grid_order)}
    out_block = plan.out_ref.block_shape
    out_dtype = np.dtype(plan.out_ref.ref.dtype)

    def kernel(*refs):
        *ins, out_ref = refs
        tiles = {g.ref.into: ins[i][...] for i, g in enumerate(plan.in_refs)}
        val = _eval_tnode(plan.root, tiles, jnp.dtype(out_dtype))
        out_ref[...] = jnp.broadcast_to(val, out_block).astype(out_ref.dtype)

    kwargs = {}
    if not interpret:
        cp = _dimension_semantics(plan.grid_order, ())
        if cp is not None:
            kwargs["compiler_params"] = cp
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(g.block_shape, _index_map_for(g, gpos))
                  for g in plan.in_refs],
        out_specs=pl.BlockSpec(out_block, _index_map_for(plan.out_ref, gpos)),
        out_shape=jax.ShapeDtypeStruct(
            tuple(s * (plan.grid_sizes[v] if v else 1)
                  for s, v in zip(out_block, plan.out_ref.dim_vars)),
            out_dtype,
        ),
        interpret=interpret,
        **kwargs,
    )

    def fn(arrays: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        args = [jnp.asarray(arrays[g.ref.from_buf]) for g in plan.in_refs]
        return call(*args)

    fn.out_shape = tuple(s * (plan.grid_sizes[v] if v else 1)
                         for s, v in zip(out_block, plan.out_ref.dim_vars))
    fn.out_dtype = out_dtype
    fn.out_base = plan.out_ref.base
    fn.in_bufs = [g.ref.from_buf for g in plan.in_refs]
    return fn


def lower_op_pallas(outer: Block, interpret: bool = False,
                    pipeline_depth: int = 2,
                    buffers: Optional[Mapping[str, TensorDecl]] = None) -> Callable:
    """Returns fn(arrays: dict) -> output array for one optimized op block
    or fusion group (a single ``pallas_call``).  ``pipeline_depth`` is the
    hardware's DMA-pipeline depth (``HardwareConfig.pipeline_depth``),
    threaded into the memory plan so its slot figures match the schedule's;
    ``buffers`` (the program's declarations) sizes the padded operand of
    halo views.

    Emission paths are tried in order — dense contraction / elementwise
    for constraint-free aligned blocks, then the windowed (halo + masked
    store) path — and when *every* path rejects the block, the raised
    ``UnsupportedPallas`` carries each path's reason (the per-block
    fallback trace the driver records)."""
    outer = _ensure_grid(outer)
    out_ref = next((r for r in outer.refs if r.dir in (RefDir.OUT, RefDir.INOUT)), None)
    if out_ref is None:
        raise UnsupportedPallas("no output ref")
    # the memory plan of this kernel's grid block: slot classification
    # (streamed / resident / halo / accumulator) that sizes the VMEM
    # scratch and gates dimension_semantics below
    mp = memplan.plan_block(outer, depth=pipeline_depth)
    agg = out_ref.agg or "assign"
    constrained = _is_constrained(outer)

    fn: Optional[Callable] = None
    errors: List[str] = []

    def attempt(name: str, build: Callable[[], Callable]) -> None:
        nonlocal fn
        if fn is not None:
            return
        try:
            fn = build()
        except UnsupportedPallas as e:
            errors.append(f"{name}: {e}")

    if not constrained:
        if agg == "assign" and not outer.sub_blocks():
            attempt("elementwise",
                    lambda: _emit_elementwise(extract_elementwise(outer), interpret))
        elif agg == "assign":
            # a fused group's outer agg is on its local accumulator; decide
            # by whether a reduction sub-structure exists — both reasons
            # are recorded when neither path fits
            attempt("contraction",
                    lambda: _emit_contraction(extract_contraction(outer), interpret, mp=mp))
            attempt("elementwise",
                    lambda: _emit_elementwise(extract_elementwise(outer), interpret))
        else:
            attempt("contraction",
                    lambda: _emit_contraction(extract_contraction(outer), interpret, mp=mp))
    # the general halo/masked path: constraint-carrying blocks (boundary
    # remainders, conv halos) and halo views of constraint-free interiors
    attempt("windowed",
            lambda: _emit_windowed(extract_windowed(outer), interpret,
                                   mp=mp, buffers=buffers))
    if fn is None:
        raise UnsupportedPallas("; ".join(errors))
    fn.out_buf = out_ref.from_buf
    return fn


# --------------------------------------------------------------------------
# Program composition: per-block hybrid lowering
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Unit:
    """One lowering unit: the top-level blocks sharing a semantic member
    set (a fusion group, or the boundary pieces of one op — pieces
    partition an iteration space and must lower, or fall back, together)."""

    members: List[str]
    blocks: List[Block]
    first: int
    level: int

    @property
    def name(self) -> str:
        return "+".join(self.members)


def _units_of(prog: Program) -> List[_Unit]:
    from .passes.fuse import members_of

    units: Dict[Tuple[str, ...], _Unit] = {}
    order: List[Tuple[str, ...]] = []
    for i, s in enumerate(prog.entry.stmts):
        if not isinstance(s, Block):
            continue
        key = tuple(members_of(s))
        if key not in units:
            units[key] = _Unit(members=list(key), blocks=[], first=i, level=1 << 30)
            order.append(key)
        u = units[key]
        u.blocks.append(s)
        for t in s.tags:
            if t.startswith("sched:"):
                u.level = min(u.level, int(t.split(":", 1)[1]))
    for u in units.values():
        if u.level == 1 << 30:
            u.level = u.first
    return [units[k] for k in order]


def _clip_extents(fn, decl: TensorDecl, block_name: str) -> Tuple[int, ...]:
    """In-bounds extent of the kernel's output region (an overflow-rounded
    boundary piece writes a view whose tail rows the constraints proved
    dead — they are sliced off before placement)."""
    base = getattr(fn, "out_base", (0,) * len(fn.out_shape))
    if len(base) != len(decl.shape) or len(fn.out_shape) != len(decl.shape):
        raise UnsupportedPallas(
            f"{block_name}: kernel writes rank-{len(fn.out_shape)} region "
            f"into rank-{len(decl.shape)} buffer {decl.name}")
    clip = []
    for b, s, d in zip(base, fn.out_shape, decl.shape):
        if b < 0 or b >= d:
            raise UnsupportedPallas(
                f"{block_name}: output region base {base} outside buffer "
                f"{decl.name}{decl.shape}")
        clip.append(min(s, d - b))
    return tuple(clip)


def _place(env: Dict[str, jnp.ndarray], decl: TensorDecl, fn,
           out: jnp.ndarray) -> jnp.ndarray:
    """Place a kernel's output region into its buffer (identity when the
    kernel covers the whole buffer)."""
    base = getattr(fn, "out_base", (0,) * len(fn.out_shape))
    if all(b == 0 for b in base) and tuple(fn.out_shape) == tuple(decl.shape):
        return out
    clip = fn.out_clip
    if clip != tuple(fn.out_shape):
        out = out[tuple(slice(0, c) for c in clip)]
    cur = env.get(decl.name)
    if cur is None:
        cur = jnp.zeros(decl.shape, np.dtype(decl.dtype))
    return jax.lax.dynamic_update_slice(cur, out.astype(cur.dtype), base)


def lower_program_hybrid(prog: Program, interpret: bool = False,
                         pipeline_depth: int = 2,
                         strict: bool = False,
                         profile: bool = False,
                         force_jnp_units: Optional[set] = None) -> Callable:
    """Lower every op block / fusion group to one Pallas kernel and
    compose the units in wavefront order; intermediates between groups
    live in outer memory (HBM).

    The backend degrades **per unit**: a unit whose blocks cannot lower
    falls back to the jnp backend for just those semantic ops
    (``lower_group_jnp``), the reason is recorded on the returned
    callable (``block_backends`` / ``block_reasons``), and every other
    unit keeps its kernels.  ``strict=True`` restores the all-or-nothing
    contract (raise on the first unsupported block — the
    ``lower_program_pallas`` entry point).

    ``profile=True`` wall-times every unit per dispatch (synchronizing on
    the unit's outputs with ``jax.block_until_ready``), keeping the best
    observation per unit in ``run.unit_times`` ({unit name: seconds}) —
    the measured side of the cost-model residual log.

    ``force_jnp_units`` (unit names, the "+"-joined member form) skips
    the Pallas attempt for those units — the tuning DB's replay of a
    measured per-unit backend choice (a unit that *measured* faster on
    the jnp path is not re-lowered to Pallas just because it legally
    could be)."""
    blocks = [s for s in prog.entry.stmts if isinstance(s, Block)]
    if not blocks:
        raise UnsupportedPallas("no op blocks")
    units = _units_of(prog)
    semantic = prog.source

    steps: List[Tuple[_Unit, str, object]] = []
    backends: Dict[str, str] = {}
    reasons: Dict[str, str] = {}
    written_regions: Dict[str, List[Tuple[Tuple[int, ...], Tuple[int, ...]]]] = {}
    written: set = set()
    n_pallas = 0
    for u in units:
        try:
            if force_jnp_units and u.name in force_jnp_units:
                raise UnsupportedPallas("tuned: measured faster on jnp")
            kernels = []
            regions = []
            for b in u.blocks:
                fn = lower_op_pallas(b, interpret=interpret,
                                     pipeline_depth=pipeline_depth,
                                     buffers=prog.buffers)
                decl = prog.buffers.get(fn.out_buf)
                if decl is None:
                    raise UnsupportedPallas(
                        f"{b.name}: kernel writes unknown buffer {fn.out_buf}")
                fn.out_clip = _clip_extents(fn, decl, b.name)
                base = getattr(fn, "out_base", (0,) * len(fn.out_shape))
                for obase, oclip in written_regions.get(fn.out_buf, []) + regions:
                    if all(b0 < o0 + c0 and o0 < b0 + c1 for b0, c1, o0, c0 in
                           zip(base, fn.out_clip, obase, oclip)):
                        # two writers of one region cannot be composed by
                        # placement (and the jnp group executor would
                        # clobber, not accumulate) — refuse the program
                        raise _ProgramFallback(
                            f"{b.name}: overlapping writes to {fn.out_buf}")
                regions.append((base, fn.out_clip))
                kernels.append(fn)
            for fn, region in zip(kernels, regions):
                written_regions.setdefault(fn.out_buf, []).append(region)
                written.add(fn.out_buf)
            steps.append((u, "pallas", kernels))
            backends[u.name] = "pallas"
            n_pallas += len(kernels)
        except _ProgramFallback:
            raise
        except UnsupportedPallas as e:
            if strict:
                raise UnsupportedPallas(f"{u.blocks[0].name}: {e}")
            if semantic is None:
                raise UnsupportedPallas(
                    f"{u.blocks[0].name}: {e} (and no semantic source for a "
                    f"per-block jnp fallback)")
            from .lower_jnp import lower_group_jnp

            gfn = lower_group_jnp(semantic, u.members)
            steps.append((u, "jnp", gfn))
            backends[u.name] = "jnp"
            reasons[u.name] = str(e)
            for n in u.members:
                for s in semantic.entry.stmts:
                    if isinstance(s, Block) and s.name == n:
                        for r in s.refs:
                            if r.dir in (RefDir.OUT, RefDir.INOUT):
                                if r.from_buf in written:
                                    raise _ProgramFallback(
                                        f"{s.name}: multiple units write "
                                        f"{r.from_buf}")
                                written.add(r.from_buf)
                                # a jnp unit writes the whole buffer: any
                                # later writer overlaps by construction
                                d = prog.buffers.get(r.from_buf)
                                if d is not None:
                                    written_regions.setdefault(
                                        r.from_buf, []).append(
                                        ((0,) * len(d.shape), tuple(d.shape)))

    missing = [o for o in prog.outputs if o not in written]
    if missing:
        raise UnsupportedPallas(f"outputs {missing} not produced by any kernel")
    # wavefront composition: units ordered by schedule level (ties by
    # program order) — the order the pipelined cost model prices
    steps.sort(key=lambda s: (s[0].level, s[0].first))
    outs = list(prog.outputs)
    buffers = prog.buffers

    unit_times: Dict[str, float] = {}

    def run(arrays: Mapping[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        env: Dict[str, jnp.ndarray] = {k: jnp.asarray(v) for k, v in arrays.items()}
        for u, kind, obj in steps:
            if profile:
                t0 = time.perf_counter()
            if kind == "pallas":
                for fn in obj:
                    env[fn.out_buf] = _place(env, buffers[fn.out_buf], fn, fn(env))
                if profile:
                    jax.block_until_ready([env[fn.out_buf] for fn in obj])
            else:
                updates = obj(env)
                env.update(updates)
                if profile:
                    jax.block_until_ready(list(updates.values()))
            if profile:
                dt = time.perf_counter() - t0
                prev = unit_times.get(u.name)
                unit_times[u.name] = dt if prev is None or dt < prev else prev
        return {n: env[n] for n in outs}

    run.n_kernels = n_pallas + sum(1 for _, kind, _ in steps if kind == "jnp")
    run.n_pallas = n_pallas
    run.block_backends = backends
    run.block_reasons = reasons
    run.unit_times = unit_times
    return run


def lower_program_pallas(prog: Program, interpret: bool = False,
                         pipeline_depth: int = 2) -> Callable:
    """Strict whole-program lowering: every op block / fusion group must
    lower to a Pallas kernel, else ``UnsupportedPallas`` (the caller
    falls back to the jnp backend wholesale)."""
    return lower_program_hybrid(prog, interpret=interpret,
                                pipeline_depth=pipeline_depth, strict=True)
