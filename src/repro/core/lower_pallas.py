"""Pallas backend: lower optimized (tiled/stenciled/fused) Stripe blocks to
``pl.pallas_call`` with explicit ``BlockSpec`` VMEM tiling.

TPU adaptation of Stripe's hardware lowering (see DESIGN.md): Stripe's
refinement-with-location (explicit DMA between memory units) maps to the
declarative BlockSpec (block shape + index_map); the optimization passes
*choose* the BlockSpec parameters:

* the grid = the outer ("grid") block's iteration space, ordered so
  reduction indices vary fastest (output block revisiting => VMEM-resident
  accumulation in a float32 scratch); parallel output dimensions are
  declared via ``dimension_semantics`` so Mosaic may reorder/parallelize
  them;
* each refinement of the grid block becomes one BlockSpec: its view shape
  is the block shape and its per-dimension affine offsets give the
  index_map (offsets must step in whole blocks — halo views fall back to
  the jnp backend);
* a whole **fusion group** (fuse.py) executes inside a single
  ``pallas_call`` as a tile-compute graph: elementwise *prologue* DAGs
  transform the input tiles, the MXU contraction runs via
  ``jax.lax.dot_general`` with f32 accumulation kept in a VMEM scratch
  across reduction grid steps, and the *epilogue* DAG (bias/activation
  chains, diamond joins — second elementwise inputs become extra
  BlockSpecs) is applied when the final reduction step completes
  (``pl.when``);
* plain elementwise blocks lower to a map kernel (no scratch).

``lower_program_pallas`` lowers every op block / fusion group of a
program to one kernel each and composes them; any unsupported block
raises ``UnsupportedPallas`` and the driver falls back to the jnp
backend, recording the reason.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import memplan
from .ir import Block, Constant, Intrinsic, Load, Program, Refinement, RefDir, Store
from .lower_jnp import _J_BINARY, _J_UNARY


class UnsupportedPallas(Exception):
    pass


# --------------------------------------------------------------------------
# Pattern extraction
# --------------------------------------------------------------------------
@dataclasses.dataclass
class GridRef:
    ref: Refinement
    block_shape: Tuple[int, ...]
    dim_vars: Tuple[Optional[str], ...]  # grid var addressing each dim


def _grid_ref(ref: Refinement, grid_ranges: Mapping[str, int]) -> GridRef:
    dim_vars: List[Optional[str]] = []
    for e, size in zip(ref.offsets, ref.shape):
        if e.is_const():
            if e.const != 0:
                raise UnsupportedPallas(f"non-zero const offset {e}")
            dim_vars.append(None)
        elif len(e.terms) == 1 and e.const == 0:
            (v, c) = e.terms[0]
            if v not in grid_ranges:
                raise UnsupportedPallas(f"offset var {v} is not a grid index")
            if c != size:
                raise UnsupportedPallas(f"halo view: offset step {c} != block dim {size}")
            dim_vars.append(v)
        else:
            raise UnsupportedPallas(f"unsupported offset {e}")
    return GridRef(ref=ref, block_shape=tuple(ref.shape), dim_vars=tuple(dim_vars))


@dataclasses.dataclass
class _TNode:
    """A node of the tile-compute graph (prologue/elementwise DAGs).

    Deliberately mirrors ``lower_jnp._Node`` (same kinds, same intrinsic
    tables) — the two walkers must stay in sync when intrinsics or DAG
    shapes are added, but operate at different granularities (whole-tile
    arrays here vs broadcast-materialized operands there)."""

    kind: str  # 'load' | 'const' | 'op'
    buf: str = ""
    value: float = 0.0
    op: str = ""
    args: Tuple["_TNode", ...] = ()

    def loads(self):
        if self.kind == "load":
            yield self
        for a in self.args:
            yield from a.loads()


def _leaf_root(stmts) -> _TNode:
    """Rebuild the expression DAG of a leaf statement list; returns the
    node stored by the (single) Store."""
    env: Dict[str, _TNode] = {}
    root: Optional[_TNode] = None
    for s in stmts:
        if isinstance(s, Load):
            env[s.into] = _TNode("load", buf=s.buf)
        elif isinstance(s, Constant):
            env[s.into] = _TNode("const", value=s.value)
        elif isinstance(s, Intrinsic):
            try:
                args = tuple(env[a] for a in s.args)
            except KeyError as e:
                raise UnsupportedPallas(f"undefined scalar {e} in leaf")
            env[s.into] = _TNode("op", op=s.op, args=args)
        elif isinstance(s, Store):
            root = env.get(s.scalar)
        elif isinstance(s, Block):
            raise UnsupportedPallas("nested block inside leaf")
    if root is None:
        raise UnsupportedPallas("leaf has no store")
    return root


def _split_contraction(root: _TNode, sig_of: Mapping[str, Tuple]) -> Tuple[_TNode, _TNode, float]:
    """Split the stored DAG into (lhs, rhs, scale): top-level ``mul``
    factors are grouped by the index pattern of their loads, so an
    elementwise prologue (e.g. ``gelu(A[i,c]) * B[c,j]``) stays attached
    to its operand side."""
    factors: List[_TNode] = []
    scale = 1.0
    stack = [root]
    while stack:
        n = stack.pop(0)
        if n.kind == "op" and n.op == "mul":
            stack = list(n.args) + stack
        elif n.kind == "const":
            scale *= n.value
        else:
            factors.append(n)
    groups: Dict[Tuple, List[_TNode]] = {}
    order: List[Tuple] = []
    for n in factors:
        sigs = set()
        for l in n.loads():
            if l.buf not in sig_of:
                raise UnsupportedPallas(f"leaf operand {l.buf} is not a grid input")
            sigs.add(sig_of[l.buf])
        if len(sigs) != 1:
            raise UnsupportedPallas("mixed index patterns inside one operand")
        sig = sigs.pop()
        if sig not in groups:
            groups[sig] = []
            order.append(sig)
        groups[sig].append(n)
    if len(order) != 2:
        raise UnsupportedPallas(f"{len(order)} distinct operand groups (need 2)")

    def fold(ns: List[_TNode]) -> _TNode:
        out = ns[0]
        for n in ns[1:]:
            out = _TNode("op", op="mul", args=(out, n))
        return out

    return fold(groups[order[0]]), fold(groups[order[1]]), scale


@dataclasses.dataclass
class ContractionPlan:
    grid_order: List[str]
    grid_sizes: Dict[str, int]
    in_refs: List[GridRef]
    out_ref: GridRef
    red_vars: List[str]
    lhs: _TNode
    rhs: _TNode
    lhs_bufs: List[str]  # grid-input names feeding each side, in spec order
    rhs_bufs: List[str]
    scale: float
    lhs_contract: Tuple[int, ...]
    rhs_contract: Tuple[int, ...]
    epilogue: List[object]
    acc_scalar: Optional[str]


@dataclasses.dataclass
class ElementwisePlan:
    grid_order: List[str]
    grid_sizes: Dict[str, int]
    in_refs: List[GridRef]
    out_ref: GridRef
    root: _TNode


def _leaf_of(block: Block) -> Block:
    cur = block
    while True:
        subs = cur.sub_blocks()
        if not subs:
            return cur
        if len(subs) != 1:
            raise UnsupportedPallas("multiple inner blocks")
        cur = subs[0]


def _check_no_constraints(block: Block) -> None:
    for b in block.walk():
        if b.constraints:
            raise UnsupportedPallas(
                f"constraints in block {b.name} (halo/overflow tiles)")


def _ensure_grid(outer: Block) -> Block:
    """Canonicalize a flat (``fits_inner``) or per-point fused block into
    the grid->tile shape the emitter expects, by splitting its output
    indices at full range (a 1-step grid per output dim)."""
    if "grid" in outer.tags:
        return outer
    from .tiling import split_block

    out_ref = next((r for r in outer.refs if r.dir in (RefDir.OUT, RefDir.INOUT)), None)
    if out_ref is None:
        raise UnsupportedPallas("no output ref")
    free = outer.idx_ranges()
    out_vars = [n for e in out_ref.offsets for n in e.names() if n in free]
    tiles = {v: free[v] for v in out_vars}
    if not tiles:
        raise UnsupportedPallas("no output indices to grid over")
    return split_block(outer, tiles, name_suffix="g", full_tiles=True)


def _collect(outer: Block):
    """Common scaffolding: grid refs, local allocs, leaf stmts, epilogue."""
    grid_ranges = {i.name: i.range for i in outer.idxs if not i.is_passthrough()}
    ins: List[GridRef] = []
    out: Optional[GridRef] = None
    local_alloc: Dict[str, Refinement] = {}
    for r in outer.refs:
        if r.dir == RefDir.IN:
            ins.append(_grid_ref(r, grid_ranges))
        elif r.dir in (RefDir.OUT, RefDir.INOUT):
            if out is not None:
                raise UnsupportedPallas("multiple outputs")
            out = _grid_ref(r, grid_ranges)
        elif r.dir == RefDir.NONE:
            local_alloc[r.into] = r
    if out is None:
        raise UnsupportedPallas("no output ref")

    sub_blocks = outer.sub_blocks()
    epilogue: List[object] = []
    if sub_blocks:
        for b in sub_blocks[0].walk():
            for r in b.refs:
                if r.dir == RefDir.NONE:
                    local_alloc.setdefault(r.into, r)
        # Descend levels; at each level, trailing leaf statements after a
        # sub-block are the (pure elementwise) fused epilogue, which lifts
        # soundly from per-point to per-tile granularity.
        cur: Block = outer
        leaf_stmts: List = []
        while True:
            msubs = cur.sub_blocks()
            trailing = []
            seen = False
            for s in cur.stmts:
                if isinstance(s, Block):
                    seen = True
                elif seen:
                    trailing.append(s)
            if msubs and trailing:
                epilogue = trailing
                leaf_stmts = list(_leaf_of(msubs[0]).stmts)
                break
            if not msubs:
                leaf_stmts = list(cur.stmts)
                break
            if len(msubs) != 1:
                raise UnsupportedPallas("multiple inner blocks")
            cur = msubs[0]
    else:
        leaf_stmts = list(outer.stmts)
    return grid_ranges, ins, out, local_alloc, leaf_stmts, epilogue


def extract_contraction(outer: Block) -> ContractionPlan:
    grid_ranges, ins, out, local_alloc, leaf_stmts, epilogue = _collect(outer)
    out_vars = {v for v in out.dim_vars if v}
    red_vars = [v for v in grid_ranges if v not in out_vars]
    grid_order = [v for v in grid_ranges if v in out_vars] + red_vars

    root = _leaf_root(leaf_stmts)
    sig_of = {g.ref.into: (g.dim_vars, g.block_shape) for g in ins}
    lhs, rhs, scale = _split_contraction(root, sig_of)

    acc_scalar: Optional[str] = None
    for s in epilogue:
        if isinstance(s, Load) and s.buf in local_alloc:
            acc_scalar = s.into

    def side_bufs(node: _TNode) -> List[str]:
        seen: List[str] = []
        for l in node.loads():
            if l.buf not in seen:
                seen.append(l.buf)
        return seen

    lhs_bufs, rhs_bufs = side_bufs(lhs), side_bufs(rhs)
    lhs_gr = next(g for g in ins if g.ref.into == lhs_bufs[0])
    rhs_gr = next(g for g in ins if g.ref.into == rhs_bufs[0])

    def contract_axes(gr: GridRef) -> List[int]:
        axes = []
        for d in range(gr.ref.rank):
            v = gr.dim_vars[d]
            if v is not None and v in out_vars:
                continue
            axes.append(d)
        return axes

    lhs_c, rhs_c = contract_axes(lhs_gr), contract_axes(rhs_gr)
    lhs_final, rhs_final, used = [], [], set()
    for a in lhs_c:
        for b in rhs_c:
            bv, av = rhs_gr.dim_vars[b], lhs_gr.dim_vars[a]
            if b in used or lhs_gr.block_shape[a] != rhs_gr.block_shape[b]:
                continue
            if av is not None and bv is not None and av != bv:
                continue  # distinct reduction vars never pair
            lhs_final.append(a)
            rhs_final.append(b)
            used.add(b)
            break
    if not lhs_final:
        raise UnsupportedPallas("no contraction dims found")

    return ContractionPlan(
        grid_order=grid_order, grid_sizes=grid_ranges, in_refs=ins, out_ref=out,
        red_vars=red_vars, lhs=lhs, rhs=rhs, lhs_bufs=lhs_bufs, rhs_bufs=rhs_bufs,
        scale=scale, lhs_contract=tuple(lhs_final), rhs_contract=tuple(rhs_final),
        epilogue=epilogue, acc_scalar=acc_scalar,
    )


def extract_elementwise(outer: Block) -> ElementwisePlan:
    grid_ranges, ins, out, _local, leaf_stmts, epilogue = _collect(outer)
    if epilogue:
        raise UnsupportedPallas("elementwise block with trailing epilogue")
    root = _leaf_root(leaf_stmts)
    # broadcast legality: each input's addressed dims must line up with the
    # trailing dims of the output tile (numpy broadcasting in the kernel)
    out_dv = list(out.dim_vars)
    for g in ins:
        dv = list(g.dim_vars)
        tail = out_dv[len(out_dv) - len(dv):] if len(dv) <= len(out_dv) else None
        if tail is None:
            raise UnsupportedPallas(f"input {g.ref.into} has higher rank than output")
        for d, v in enumerate(dv):
            if v is None and g.block_shape[d] == 1:
                continue
            if v != tail[d] and g.block_shape[d] != 1:
                raise UnsupportedPallas(
                    f"input {g.ref.into} dim {d} does not broadcast against the output")
    grid_order = [v for v in grid_ranges]
    if any(v not in {d for d in out.dim_vars if d} for v in grid_order):
        raise UnsupportedPallas("elementwise block with reduction index")
    return ElementwisePlan(grid_order=grid_order, grid_sizes=grid_ranges,
                           in_refs=ins, out_ref=out, root=root)


# --------------------------------------------------------------------------
# Kernel emission
# --------------------------------------------------------------------------
def _eval_tnode(n: _TNode, tiles: Mapping[str, jnp.ndarray], dtype=None):
    if n.kind == "load":
        return tiles[n.buf]
    if n.kind == "const":
        return jnp.asarray(n.value, dtype or jnp.float32)
    args = [_eval_tnode(a, tiles, dtype) for a in n.args]
    fn = _J_UNARY[n.op] if len(args) == 1 and n.op in _J_UNARY else _J_BINARY[n.op]
    return fn(*args)


def _apply_epilogue(plan: ContractionPlan, acc, tile_args: Dict[str, jnp.ndarray]):
    env: Dict[str, jnp.ndarray] = {}
    result = acc
    for s in plan.epilogue:
        if isinstance(s, Load):
            env[s.into] = acc if s.into == plan.acc_scalar else tile_args[s.buf]
        elif isinstance(s, Constant):
            env[s.into] = jnp.asarray(s.value, acc.dtype)
        elif isinstance(s, Intrinsic):
            args = [env[a] for a in s.args]
            fn = _J_UNARY[s.op] if len(args) == 1 and s.op in _J_UNARY else _J_BINARY[s.op]
            env[s.into] = fn(*args)
        elif isinstance(s, Store):
            result = env[s.scalar]
    return result


def _dimension_semantics(grid_order: List[str], red_vars) -> Optional[object]:
    """Mark parallel (output) grid axes for Mosaic; reduction axes are
    'arbitrary' because the scratch accumulation carries state across
    their steps."""
    red = set(red_vars)
    sem = tuple("arbitrary" if v in red else "parallel" for v in grid_order)
    try:
        return pltpu.TPUCompilerParams(dimension_semantics=sem)
    except Exception:  # pragma: no cover - API drift across jax versions
        return None


def _emit_contraction(plan: ContractionPlan, interpret: bool,
                      mp: Optional[memplan.BlockPlan] = None) -> Callable:
    grid = tuple(plan.grid_sizes[v] for v in plan.grid_order)
    gpos = {v: i for i, v in enumerate(plan.grid_order)}

    side = set(plan.lhs_bufs) | set(plan.rhs_bufs)
    operand_grs = [g for g in plan.in_refs if g.ref.into in side]
    extra = [g for g in plan.in_refs if g.ref.into not in side]
    order = operand_grs + extra

    def index_map_for(gr: GridRef):
        def imap(*gidx):
            return tuple(gidx[gpos[v]] if v is not None else 0 for v in gr.dim_vars)
        return imap

    dnums = ((plan.lhs_contract, plan.rhs_contract), ((), ()))
    out_dtype = np.dtype(plan.out_ref.ref.dtype)
    out_block = plan.out_ref.block_shape
    has_red = bool(plan.red_vars)
    # The memory plan decides scratch residency: a revisited output plans
    # one f32 partial-sum tile that must agree with the emitter's own
    # reduction analysis — a mismatch means the schedule placed the
    # accumulator differently than this kernel would use it.
    if mp is not None:
        if (mp.acc_bytes > 0) != has_red or set(mp.red_vars) != set(plan.red_vars):
            raise UnsupportedPallas(
                f"memory plan disagrees with emitter: plan acc={mp.acc_bytes}B "
                f"red={sorted(mp.red_vars)} vs emitter red={sorted(plan.red_vars)}")
        out_elems = 1
        for s in out_block:
            out_elems *= s
        if has_red and mp.acc_bytes != out_elems * 4:
            raise UnsupportedPallas(
                f"planned scratch {mp.acc_bytes}B != f32 out tile {out_elems * 4}B")

    def kernel(*refs):
        if has_red:
            *ins, out_ref, acc_ref = refs
        else:
            *ins, out_ref = refs
            acc_ref = None
        tiles = {g.ref.into: ins[i][...] for i, g in enumerate(order)}
        lhs = _eval_tnode(plan.lhs, tiles)
        rhs = _eval_tnode(plan.rhs, tiles)
        part = jax.lax.dot_general(lhs, rhs, dnums, preferred_element_type=jnp.float32)
        part = part.reshape(out_block)
        if plan.scale != 1.0:
            part = part * jnp.asarray(plan.scale, part.dtype)
        tile_args = {g.ref.into: tiles[g.ref.into] for g in extra}
        if has_red:
            first = functools.reduce(
                jnp.logical_and, [pl.program_id(gpos[v]) == 0 for v in plan.red_vars]
            )
            last = functools.reduce(
                jnp.logical_and,
                [pl.program_id(gpos[v]) == plan.grid_sizes[v] - 1 for v in plan.red_vars],
            )

            @pl.when(first)
            def _init():
                acc_ref[...] = jnp.zeros(out_block, jnp.float32)

            acc_ref[...] += part

            @pl.when(last)
            def _flush():
                val = acc_ref[...]
                if plan.epilogue:
                    val = _apply_epilogue(plan, val, tile_args)
                out_ref[...] = val.astype(out_ref.dtype)
        else:
            val = part
            if plan.epilogue:
                val = _apply_epilogue(plan, val, tile_args)
            out_ref[...] = val.astype(out_ref.dtype)

    in_specs = [pl.BlockSpec(g.block_shape, index_map_for(g)) for g in order]
    out_spec = pl.BlockSpec(out_block, index_map_for(plan.out_ref))
    out_full_shape = tuple(
        s * (plan.grid_sizes[v] if v else 1)
        for s, v in zip(out_block, plan.out_ref.dim_vars)
    )

    kwargs = {}
    if not interpret:
        # planned slots gate the semantics: grid axes that stream the
        # output may be reordered/parallelized by Mosaic; axes that
        # revisit the planned accumulator carry state and stay arbitrary
        cp = _dimension_semantics(plan.grid_order,
                                  mp.red_vars if mp is not None else plan.red_vars)
        if cp is not None:
            kwargs["compiler_params"] = cp
    scratch = []
    if has_red:
        # sized by the memory plan when available (acc_bytes == f32 out
        # tile, verified above), else by the emitter's own analysis
        scratch = [pltpu.VMEM(out_block, jnp.float32)]
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_full_shape, out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )

    def fn(arrays: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        args = [jnp.asarray(arrays[g.ref.from_buf]) for g in order]
        return call(*args)

    fn.out_shape = out_full_shape
    fn.out_dtype = out_dtype
    fn.in_bufs = [g.ref.from_buf for g in order]
    return fn


def _emit_elementwise(plan: ElementwisePlan, interpret: bool) -> Callable:
    grid = tuple(plan.grid_sizes[v] for v in plan.grid_order)
    gpos = {v: i for i, v in enumerate(plan.grid_order)}
    out_block = plan.out_ref.block_shape
    out_dtype = np.dtype(plan.out_ref.ref.dtype)

    def index_map_for(gr: GridRef):
        def imap(*gidx):
            return tuple(gidx[gpos[v]] if v is not None else 0 for v in gr.dim_vars)
        return imap

    def kernel(*refs):
        *ins, out_ref = refs
        tiles = {g.ref.into: ins[i][...] for i, g in enumerate(plan.in_refs)}
        val = _eval_tnode(plan.root, tiles, jnp.dtype(out_dtype))
        out_ref[...] = jnp.broadcast_to(val, out_block).astype(out_ref.dtype)

    kwargs = {}
    if not interpret:
        cp = _dimension_semantics(plan.grid_order, ())
        if cp is not None:
            kwargs["compiler_params"] = cp
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(g.block_shape, index_map_for(g)) for g in plan.in_refs],
        out_specs=pl.BlockSpec(out_block, index_map_for(plan.out_ref)),
        out_shape=jax.ShapeDtypeStruct(
            tuple(s * (plan.grid_sizes[v] if v else 1)
                  for s, v in zip(out_block, plan.out_ref.dim_vars)),
            out_dtype,
        ),
        interpret=interpret,
        **kwargs,
    )

    def fn(arrays: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        args = [jnp.asarray(arrays[g.ref.from_buf]) for g in plan.in_refs]
        return call(*args)

    fn.out_shape = tuple(s * (plan.grid_sizes[v] if v else 1)
                         for s, v in zip(out_block, plan.out_ref.dim_vars))
    fn.out_dtype = out_dtype
    fn.in_bufs = [g.ref.from_buf for g in plan.in_refs]
    return fn


def lower_op_pallas(outer: Block, interpret: bool = False,
                    pipeline_depth: int = 2) -> Callable:
    """Returns fn(arrays: dict) -> output array for one optimized op block
    or fusion group (a single ``pallas_call``).  ``pipeline_depth`` is the
    hardware's DMA-pipeline depth (``HardwareConfig.pipeline_depth``),
    threaded into the memory plan so its slot figures match the schedule's."""
    outer = _ensure_grid(outer)
    _check_no_constraints(outer)
    out_ref = next((r for r in outer.refs if r.dir in (RefDir.OUT, RefDir.INOUT)), None)
    if out_ref is None:
        raise UnsupportedPallas("no output ref")
    # the memory plan of this kernel's grid block: slot classification
    # (streamed / resident / accumulator) that sizes the VMEM scratch and
    # gates dimension_semantics below
    mp = memplan.plan_block(outer, depth=pipeline_depth)
    agg = out_ref.agg or "assign"
    if agg == "assign" and not outer.sub_blocks():
        fn = _emit_elementwise(extract_elementwise(outer), interpret)
    elif agg == "assign":
        # a fused group's outer agg is on its local accumulator; decide by
        # whether a reduction sub-structure exists
        try:
            fn = _emit_contraction(extract_contraction(outer), interpret, mp=mp)
        except UnsupportedPallas as contraction_err:
            try:
                fn = _emit_elementwise(extract_elementwise(outer), interpret)
            except UnsupportedPallas:
                # the sub-block structure says "contraction"; its error is
                # the one worth recording as the fallback reason
                raise contraction_err
    else:
        fn = _emit_contraction(extract_contraction(outer), interpret, mp=mp)
    fn.out_buf = out_ref.from_buf
    return fn


def lower_program_pallas(prog: Program, interpret: bool = False,
                         pipeline_depth: int = 2) -> Callable:
    """Lower every op block / fusion group to one Pallas kernel and
    compose them in program order; intermediates between groups live in
    outer memory (HBM).  Raises ``UnsupportedPallas`` (whole-program jnp
    fallback) when any block cannot lower."""
    blocks = [s for s in prog.entry.stmts if isinstance(s, Block)]
    if not blocks:
        raise UnsupportedPallas("no op blocks")
    kernels = []
    written = set()
    for b in blocks:
        try:
            fn = lower_op_pallas(b, interpret=interpret,
                                 pipeline_depth=pipeline_depth)
        except UnsupportedPallas as e:
            raise UnsupportedPallas(f"{b.name}: {e}")
        decl = prog.buffers.get(fn.out_buf)
        if decl is None or tuple(decl.shape) != tuple(fn.out_shape):
            raise UnsupportedPallas(
                f"{b.name}: kernel writes {fn.out_shape}, buffer is "
                f"{tuple(decl.shape) if decl else None}")
        if fn.out_buf in written:
            raise UnsupportedPallas(f"{b.name}: {fn.out_buf} written twice")
        written.add(fn.out_buf)
        kernels.append(fn)
    outs = list(prog.outputs)
    missing = [o for o in outs if o not in written]
    if missing:
        raise UnsupportedPallas(f"outputs {missing} not produced by any kernel")

    def run(arrays: Mapping[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        env: Dict[str, jnp.ndarray] = {k: jnp.asarray(v) for k, v in arrays.items()}
        for fn in kernels:
            env[fn.out_buf] = fn(env)
        return {n: env[n] for n in outs}

    run.n_kernels = len(kernels)
    return run
