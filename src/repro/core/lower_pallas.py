"""Pallas backend: lower optimized (tiled/stenciled/fused) Stripe blocks to
``pl.pallas_call`` with explicit ``BlockSpec`` VMEM tiling.

TPU adaptation of Stripe's hardware lowering (see DESIGN.md): Stripe's
refinement-with-location (explicit DMA between memory units) maps to the
declarative BlockSpec (block shape + index_map); the optimization passes
*choose* the BlockSpec parameters:

* the grid = the outer ("grid") block's iteration space, ordered so
  reduction indices vary fastest (output block revisiting => VMEM-resident
  accumulation in a float32 scratch);
* each refinement of the grid block becomes one BlockSpec: its view shape
  is the block shape and its per-dimension affine offsets give the
  index_map (offsets must step in whole blocks — halo views fall back to
  the jnp backend);
* an inner block tagged ``mxu`` (stencil pass) or a flat contraction tile
  lowers to ``jax.lax.dot_general`` with f32 accumulation;
* fused epilogue statements (fusion pass) lower to elementwise jnp ops
  applied when the final reduction step completes (``pl.when``).

Supported pattern: contractions whose tile compute is a (batched) matmul
plus an optional elementwise epilogue.  Everything else falls back to the
jnp backend — ``lower_op_pallas`` raises ``UnsupportedPallas``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ir import Block, Constant, Intrinsic, Load, Refinement, RefDir, Store
from .lower_jnp import _J_BINARY, _J_UNARY


class UnsupportedPallas(Exception):
    pass


# --------------------------------------------------------------------------
# Pattern extraction
# --------------------------------------------------------------------------
@dataclasses.dataclass
class GridRef:
    ref: Refinement
    block_shape: Tuple[int, ...]
    dim_vars: Tuple[Optional[str], ...]  # grid var addressing each dim


def _grid_ref(ref: Refinement, grid_ranges: Mapping[str, int]) -> GridRef:
    dim_vars: List[Optional[str]] = []
    for e, size in zip(ref.offsets, ref.shape):
        if e.is_const():
            if e.const != 0:
                raise UnsupportedPallas(f"non-zero const offset {e}")
            dim_vars.append(None)
        elif len(e.terms) == 1 and e.const == 0:
            (v, c) = e.terms[0]
            if v not in grid_ranges:
                raise UnsupportedPallas(f"offset var {v} is not a grid index")
            if c != size:
                raise UnsupportedPallas(f"halo view: offset step {c} != block dim {size}")
            dim_vars.append(v)
        else:
            raise UnsupportedPallas(f"unsupported offset {e}")
    return GridRef(ref=ref, block_shape=tuple(ref.shape), dim_vars=tuple(dim_vars))


@dataclasses.dataclass
class ContractionPlan:
    grid_order: List[str]
    grid_sizes: Dict[str, int]
    in_refs: List[GridRef]
    out_ref: GridRef
    red_vars: List[str]
    lhs: str
    rhs: str
    lhs_contract: Tuple[int, ...]
    rhs_contract: Tuple[int, ...]
    epilogue: List[object]
    acc_scalar: Optional[str]


def _leaf_of(block: Block) -> Block:
    cur = block
    while True:
        subs = cur.sub_blocks()
        if not subs:
            return cur
        if len(subs) != 1:
            raise UnsupportedPallas("multiple inner blocks")
        cur = subs[0]


def extract_contraction(outer: Block) -> ContractionPlan:
    grid_ranges = {i.name: i.range for i in outer.idxs if not i.is_passthrough()}
    ins: List[GridRef] = []
    out: Optional[GridRef] = None
    local_alloc: Dict[str, Refinement] = {}
    for r in outer.refs:
        if r.dir == RefDir.IN:
            ins.append(_grid_ref(r, grid_ranges))
        elif r.dir in (RefDir.OUT, RefDir.INOUT):
            if out is not None:
                raise UnsupportedPallas("multiple outputs")
            out = _grid_ref(r, grid_ranges)
        elif r.dir == RefDir.NONE:
            local_alloc[r.into] = r
    if out is None:
        raise UnsupportedPallas("no output ref")

    out_vars = {v for v in out.dim_vars if v}
    red_vars = [v for v in grid_ranges if v not in out_vars]
    grid_order = [v for v in grid_ranges if v in out_vars] + red_vars

    # ---- locate leaf compute + epilogue ------------------------------------
    sub_blocks = outer.sub_blocks()
    epilogue: List[object] = []
    acc_scalar: Optional[str] = None
    if sub_blocks:
        for b in sub_blocks[0].walk():
            for r in b.refs:
                if r.dir == RefDir.NONE:
                    local_alloc.setdefault(r.into, r)
        # Descend levels; at each level, trailing leaf statements after a
        # sub-block are the (pure elementwise) fused epilogue, which lifts
        # soundly from per-point to per-tile granularity.
        cur: Block = outer
        leaf_stmts = []
        while True:
            msubs = cur.sub_blocks()
            trailing = []
            seen = False
            for s in cur.stmts:
                if isinstance(s, Block):
                    seen = True
                elif seen:
                    trailing.append(s)
            if msubs and trailing:
                epilogue = trailing
                leaf_stmts = list(_leaf_of(msubs[0]).stmts)
                break
            if not msubs:
                leaf_stmts = list(cur.stmts)
                break
            if len(msubs) != 1:
                raise UnsupportedPallas("multiple inner blocks")
            cur = msubs[0]
    else:
        leaf_stmts = list(outer.stmts)

    # ---- parse the leaf: two loads -> mul -> store(add) --------------------
    loads: Dict[str, str] = {}
    mul_args: Optional[Tuple[str, str]] = None
    for s in leaf_stmts:
        if isinstance(s, Load):
            loads[s.into] = s.buf
        elif isinstance(s, Intrinsic) and s.op == "mul" and len(s.args) == 2:
            mul_args = (loads.get(s.args[0], ""), loads.get(s.args[1], ""))
        elif isinstance(s, Intrinsic):
            raise UnsupportedPallas(f"leaf intrinsic {s.op}")
    if mul_args is None or not all(mul_args):
        raise UnsupportedPallas("leaf is not a 2-operand contraction")

    for s in epilogue:
        if isinstance(s, Load) and s.buf in local_alloc:
            acc_scalar = s.into

    grid_in_names = {g.ref.into for g in ins}
    lhs_local, rhs_local = mul_args
    if lhs_local not in grid_in_names or rhs_local not in grid_in_names:
        raise UnsupportedPallas("leaf operands are not grid inputs")
    lhs_gr = next(g for g in ins if g.ref.into == lhs_local)
    rhs_gr = next(g for g in ins if g.ref.into == rhs_local)

    def contract_axes(gr: GridRef) -> List[int]:
        axes = []
        for d in range(gr.ref.rank):
            v = gr.dim_vars[d]
            if v is not None and v in out_vars:
                continue
            axes.append(d)
        return axes

    lhs_c, rhs_c = contract_axes(lhs_gr), contract_axes(rhs_gr)
    lhs_final, rhs_final, used = [], [], set()
    for a in lhs_c:
        for b in rhs_c:
            if b not in used and lhs_gr.block_shape[a] == rhs_gr.block_shape[b]:
                lhs_final.append(a)
                rhs_final.append(b)
                used.add(b)
                break
    if not lhs_final:
        raise UnsupportedPallas("no contraction dims found")

    return ContractionPlan(
        grid_order=grid_order, grid_sizes=grid_ranges, in_refs=ins, out_ref=out,
        red_vars=red_vars, lhs=lhs_local, rhs=rhs_local,
        lhs_contract=tuple(lhs_final), rhs_contract=tuple(rhs_final),
        epilogue=epilogue, acc_scalar=acc_scalar,
    )


# --------------------------------------------------------------------------
# Kernel emission
# --------------------------------------------------------------------------
def _apply_epilogue(plan: ContractionPlan, acc, tile_args: Dict[str, jnp.ndarray]):
    env: Dict[str, jnp.ndarray] = {}
    result = acc
    for s in plan.epilogue:
        if isinstance(s, Load):
            env[s.into] = acc if s.into == plan.acc_scalar else tile_args[s.buf]
        elif isinstance(s, Constant):
            env[s.into] = jnp.asarray(s.value, acc.dtype)
        elif isinstance(s, Intrinsic):
            args = [env[a] for a in s.args]
            fn = _J_UNARY[s.op] if len(args) == 1 and s.op in _J_UNARY else _J_BINARY[s.op]
            env[s.into] = fn(*args)
        elif isinstance(s, Store):
            result = env[s.scalar]
    return result


def lower_op_pallas(outer: Block, interpret: bool = False) -> Callable:
    """Returns fn(arrays: dict) -> output array for one optimized op block."""
    plan = extract_contraction(outer)
    grid = tuple(plan.grid_sizes[v] for v in plan.grid_order)
    gpos = {v: i for i, v in enumerate(plan.grid_order)}

    lhs_gr = next(g for g in plan.in_refs if g.ref.into == plan.lhs)
    rhs_gr = next(g for g in plan.in_refs if g.ref.into == plan.rhs)
    extra = [g for g in plan.in_refs if g.ref.into not in (plan.lhs, plan.rhs)]

    def index_map_for(gr: GridRef):
        def imap(*gidx):
            return tuple(gidx[gpos[v]] if v is not None else 0 for v in gr.dim_vars)
        return imap

    dnums = ((plan.lhs_contract, plan.rhs_contract), ((), ()))
    out_dtype = np.dtype(plan.out_ref.ref.dtype)
    out_block = plan.out_ref.block_shape
    has_red = bool(plan.red_vars)

    def kernel(*refs):
        if has_red:
            *ins, out_ref, acc_ref = refs
        else:
            *ins, out_ref = refs
            acc_ref = None
        lhs = ins[0][...]
        rhs = ins[1][...]
        part = jax.lax.dot_general(lhs, rhs, dnums, preferred_element_type=jnp.float32)
        part = part.reshape(out_block)
        tile_args = {g.ref.into: ins[2 + i][...] for i, g in enumerate(extra)}
        if has_red:
            first = functools.reduce(
                jnp.logical_and, [pl.program_id(gpos[v]) == 0 for v in plan.red_vars]
            )
            last = functools.reduce(
                jnp.logical_and,
                [pl.program_id(gpos[v]) == plan.grid_sizes[v] - 1 for v in plan.red_vars],
            )

            @pl.when(first)
            def _init():
                acc_ref[...] = jnp.zeros(out_block, jnp.float32)

            acc_ref[...] += part

            @pl.when(last)
            def _flush():
                val = acc_ref[...]
                if plan.epilogue:
                    val = _apply_epilogue(plan, val, tile_args)
                out_ref[...] = val.astype(out_ref.dtype)
        else:
            val = part
            if plan.epilogue:
                val = _apply_epilogue(plan, val, tile_args)
            out_ref[...] = val.astype(out_ref.dtype)

    in_specs = [
        pl.BlockSpec(lhs_gr.block_shape, index_map_for(lhs_gr)),
        pl.BlockSpec(rhs_gr.block_shape, index_map_for(rhs_gr)),
    ] + [pl.BlockSpec(g.block_shape, index_map_for(g)) for g in extra]
    out_spec = pl.BlockSpec(out_block, index_map_for(plan.out_ref))
    out_full_shape = tuple(
        s * (plan.grid_sizes[v] if v else 1)
        for s, v in zip(out_block, plan.out_ref.dim_vars)
    )

    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_full_shape, out_dtype),
        scratch_shapes=[pltpu.VMEM(out_block, jnp.float32)] if has_red else [],
        interpret=interpret,
    )

    order = [lhs_gr, rhs_gr] + extra

    def fn(arrays: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        args = [jnp.asarray(arrays[g.ref.from_buf]) for g in order]
        return call(*args)

    return fn
