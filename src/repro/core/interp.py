"""Exact reference interpreter for Stripe programs (numpy, scalar loops).

This is the semantic ground truth for the Nested Polyhedral Model: it
executes arbitrary nested blocks point-by-point, honouring refinement
offsets, constraints, and aggregation operations.  It is intentionally
simple and slow — passes prove semantic preservation against it on small
shapes, and kernels/jnp lowerings are tested against it.
"""
from __future__ import annotations

import math
from typing import Dict, Mapping, Tuple

import numpy as np

from .ir import (
    AGG_IDENTITY,
    Block,
    Constant,
    Intrinsic,
    Load,
    Program,
    RefDir,
    Special,
    Store,
)

_UNARY = {
    "neg": lambda a: -a,
    "exp": math.exp,
    "log": math.log,
    "tanh": math.tanh,
    "sqrt": math.sqrt,
    "rsqrt": lambda a: 1.0 / math.sqrt(a),
    "sigmoid": lambda a: 1.0 / (1.0 + math.exp(-a)),
    "relu": lambda a: a if a > 0 else 0 * a,
    "abs": abs,
    "square": lambda a: a * a,
    "erf": math.erf,
    "gelu": lambda a: 0.5 * a * (1.0 + math.erf(a / math.sqrt(2.0))),
    "silu": lambda a: a / (1.0 + math.exp(-a)),
    "sign": lambda a: (a > 0) - (a < 0),
    "floor": math.floor,
    "cast": lambda a: a,
}
_BINARY = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "max": max,
    "min": min,
    "pow": lambda a, b: a ** b,
}

_AGG_FN = {
    "add": lambda old, new: old + new,
    "max": max,
    "min": min,
    "mul": lambda old, new: old * new,
    "assign": lambda old, new: new,
}


def _eval_intrinsic(op: str, args):
    if len(args) == 1 and op in _UNARY:
        return _UNARY[op](args[0])
    if len(args) == 2 and op in _BINARY:
        return _BINARY[op](args[0], args[1])
    if op in ("add", "mul", "max", "min"):  # n-ary fold
        out = args[0]
        for a in args[1:]:
            out = _BINARY[op](out, a)
        return out
    raise KeyError(f"unknown intrinsic {op}/{len(args)}")


class _View:
    __slots__ = ("array", "base")

    def __init__(self, array: np.ndarray, base: Tuple[int, ...]):
        self.array = array
        self.base = base


def _run_block(block: Block, env: Dict[str, int], views: Mapping[str, _View]) -> None:
    my: Dict[str, _View] = {}
    for r in block.refs:
        if r.dir == RefDir.NONE:
            ident = AGG_IDENTITY.get(r.agg or "assign", 0.0)
            arr = np.full(r.shape, ident, dtype=np.dtype(r.dtype) if "int" not in r.dtype else np.dtype(r.dtype))
            if "int" in r.dtype:
                arr = np.zeros(r.shape, dtype=np.dtype(r.dtype))
            my[r.into] = _View(arr, tuple(0 for _ in r.shape))
        else:
            pv = views[r.from_buf]
            base = tuple(b + o.eval(env) for b, o in zip(pv.base, r.offsets))
            my[r.into] = _View(pv.array, base)

    scalars: Dict[str, object] = {}
    for s in block.stmts:
        if isinstance(s, Load):
            v = my[s.buf]
            scalars[s.into] = v.array[v.base]
        elif isinstance(s, Constant):
            scalars[s.into] = s.value
        elif isinstance(s, Intrinsic):
            scalars[s.into] = _eval_intrinsic(s.op, [scalars[a] for a in s.args])
        elif isinstance(s, Store):
            v = my[s.buf]
            agg = block.ref(s.buf).agg or "assign"
            old = v.array[v.base]
            v.array[v.base] = _AGG_FN[agg](old, scalars[s.scalar])
        elif isinstance(s, Special):
            raise NotImplementedError(f"special '{s.op}' in reference interpreter")
        elif isinstance(s, Block):
            for sub_env in s.poly.points(env):
                _run_block(s, dict(sub_env), my)
        else:  # pragma: no cover
            raise TypeError(type(s))


def execute_reference(prog: Program, inputs: Mapping[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Run a Stripe program; returns all non-input buffers."""
    arrays: Dict[str, np.ndarray] = {}
    for name, d in prog.buffers.items():
        if name in prog.inputs:
            a = np.asarray(inputs[name], dtype=np.dtype(d.dtype))
            if tuple(a.shape) != d.shape:
                raise ValueError(f"input {name}: expected {d.shape}, got {a.shape}")
            arrays[name] = a.copy()
        else:
            # Identity of the first aggregation that writes this buffer.
            agg = _first_agg(prog.entry, name) or "assign"
            ident = AGG_IDENTITY.get(agg, 0.0)
            if np.dtype(d.dtype).kind in "iu" or agg == "assign":
                arrays[name] = np.zeros(d.shape, dtype=np.dtype(d.dtype))
            else:
                arrays[name] = np.full(d.shape, ident, dtype=np.dtype(d.dtype))

    views = {name: _View(arr, tuple(0 for _ in arr.shape)) for name, arr in arrays.items()}
    for env in prog.entry.poly.points({}):
        _run_block(prog.entry, dict(env), views)
    return {n: a for n, a in arrays.items() if n not in prog.inputs}


def _first_agg(block: Block, root: str, current: str | None = None) -> str | None:
    current = current or root
    for s in block.stmts:
        if isinstance(s, Store) and s.buf == current:
            return block.ref(s.buf).agg or "assign"
        if isinstance(s, Block):
            for r in s.refs:
                if r.from_buf == current:
                    got = _first_agg(s, root, r.into)
                    if got:
                        return got
                    if r.agg:
                        return r.agg
    return None
