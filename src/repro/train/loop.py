"""Training loop with fault tolerance and straggler monitoring.

``Trainer`` runs jit'd train steps over the data pipeline with:
* periodic + final atomic checkpoints (async writer),
* automatic restore-on-start (resume is bit-exact: the pipeline state and
  RNG live in the checkpoint),
* the ``train.step`` fault-injection site (:mod:`repro.reliability.faults`)
  used by tests to simulate preemption/node failure mid-run — the legacy
  ``FaultInjector`` class survives as a thin shim over it,
* a step-time watchdog that flags stragglers (slow steps) and records
  them for exclusion/rebalance at the next restart.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..data.pipeline import DataConfig, DataPipeline, PipelineState
from ..optim import adamw
from ..reliability import faults
from . import checkpoint as ckpt


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: str = ""
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0  # step slower than 3x median => straggler


class FaultInjector:
    """Raises at a chosen step (tests: simulated preemption).

    Deprecated compat shim over :mod:`repro.reliability.faults` — it
    builds a one-shot ``train.step`` rule and checks it directly, so old
    call sites (``Trainer.run(fault=...)``) keep working while new code
    installs plans with ``faults.inject(...)``."""

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step
        self._rule = (faults.fail_when(
            "train.step", lambda ctx: ctx["step"] == fail_at_step)
            if fail_at_step is not None else None)
        self._plan = (faults.FaultPlan([self._rule])
                      if self._rule is not None else None)

    @property
    def fired(self) -> bool:
        return self._rule is not None and self._rule.fired > 0

    def check(self, step: int) -> None:
        if self._plan is not None:
            self._plan.hit("train.step", step=step)


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0):
        self.times: list = []
        self.factor = factor
        self.flagged: list = []

    def record(self, step: int, dt: float) -> None:
        self.times.append(dt)
        if len(self.times) >= 8:
            med = float(np.median(self.times[-64:]))
            if dt > self.factor * med:
                self.flagged.append({"step": step, "dt": dt, "median": med})


class Trainer:
    def __init__(self, model, opt_cfg: adamw.AdamWConfig, data_cfg: DataConfig,
                 train_cfg: TrainConfig, rng: Optional[jax.Array] = None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.cfg = train_cfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.pipeline = DataPipeline(data_cfg)
        self.watchdog = StragglerWatchdog(train_cfg.straggler_factor)
        self.checkpointer = ckpt.AsyncCheckpointer(train_cfg.ckpt_dir, train_cfg.keep) if train_cfg.ckpt_dir else None

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: self.model.loss(p, batch, remat=True), has_aux=True)(params)
            new_p, new_o, info = adamw.apply_updates(params, grads, opt_state, opt_cfg)
            return new_p, new_o, {"loss": loss, **info}

        self.train_step = jax.jit(train_step, donate_argnums=(0, 1))

        self.params = self.model.init(self.rng)
        self.opt_state = adamw.init_state(self.params)
        self.step = 0
        self.history: list = []
        if train_cfg.ckpt_dir:
            self._maybe_restore()

    # ------------------------------------------------------------- restore
    def _maybe_restore(self) -> None:
        last = ckpt.latest_step(self.cfg.ckpt_dir)
        if last is None:
            return
        step, state = ckpt.restore(self.cfg.ckpt_dir, {
            "params": self.params,
            "opt_state": self.opt_state,
            "data": {"step": np.zeros((), np.int64)},
        })
        self.params = state["params"]
        self.opt_state = state["opt_state"]
        self.step = step
        self.pipeline.restore(PipelineState(step=int(state["data"]["step"])))

    def _save(self) -> None:
        if self.checkpointer is None:
            return
        self.checkpointer.save(self.step, {
            "params": self.params,
            "opt_state": self.opt_state,
            "data": {"step": np.asarray(self.pipeline.state.step, np.int64)},
        })

    # ----------------------------------------------------------------- run
    def run(self, fault: Optional[FaultInjector] = None) -> Dict[str, Any]:
        while self.step < self.cfg.steps:
            t0 = time.time()
            batch = self.pipeline.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if fault is not None:
                fault.check(self.step)
            # ambient fault plans (faults.inject) hit the same site without
            # threading an injector through the call stack
            faults.check("train.step", step=self.step)
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            self.step += 1
            dt = time.time() - t0
            self.watchdog.record(self.step, dt)
            if self.step % self.cfg.log_every == 0 or self.step == self.cfg.steps:
                self.history.append({"step": self.step, "loss": loss, "dt": dt})
            if self.cfg.ckpt_dir and (self.step % self.cfg.ckpt_every == 0 or self.step == self.cfg.steps):
                self._save()
        if self.checkpointer is not None:
            self.checkpointer.wait()
        return {"final_loss": self.history[-1]["loss"] if self.history else None,
                "history": self.history,
                "stragglers": self.watchdog.flagged}


def run_with_restarts(make_trainer: Callable[[], Trainer],
                      fault: Optional[FaultInjector] = None,
                      max_restarts: int = 3) -> Dict[str, Any]:
    """Fault-tolerant driver: on failure, rebuild the trainer (which
    restores from the last checkpoint) and continue."""
    restarts = 0
    while True:
        trainer = make_trainer()
        try:
            out = trainer.run(fault)
            out["restarts"] = restarts
            return out
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            trainer.pipeline.close()
