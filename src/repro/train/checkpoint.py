"""Fault-tolerant checkpointing.

* **atomic**: write to ``<dir>/tmp.<step>`` then ``os.rename`` — a crash
  mid-save never corrupts the latest checkpoint;
* **complete**: params, optimizer state, data-pipeline state, RNG, step,
  and a manifest with the flattened pytree structure;
* **mesh-elastic**: arrays are saved unsharded (numpy) with their pytree
  paths; ``restore`` re-shards onto whatever mesh/sharding the new job
  uses, so restarts may change pod count (elastic scaling);
* **retention**: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp
        )
        out.append((key, np.asarray(leaf)))
    return out


def save(ckpt_dir: str, step: int, state: Dict[str, Any], keep: int = 3) -> str:
    """Atomic checkpoint save.  ``state`` is a dict of pytrees / scalars."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest: Dict[str, Any] = {"step": step, "trees": {}}
    for name, tree in state.items():
        if tree is None:
            continue
        pairs = _flatten(tree)
        arrays = {f"a{i}": arr for i, (key, arr) in enumerate(pairs)}
        np.savez(os.path.join(tmp, f"{name}.npz"), **arrays)
        manifest["trees"][name] = {
            "keys": [k for k, _ in pairs],
            "treedef": _treedef_repr(tree),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _treedef_repr(tree) -> str:
    return str(jax.tree_util.tree_structure(tree))


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    if not steps:
        return None
    return int(steps[-1].split("_")[1])


def restore(ckpt_dir: str, like: Dict[str, Any], step: Optional[int] = None,
            shardings: Optional[Dict[str, Any]] = None) -> Tuple[int, Dict[str, Any]]:
    """Restore into the structure of ``like`` (pytrees of arrays or
    ShapeDtypeStructs).  ``shardings`` optionally maps tree names to
    matching sharding pytrees — arrays are placed (device_put) with them,
    which is what makes restore mesh-elastic."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    out: Dict[str, Any] = {}
    for name, tree in like.items():
        if tree is None or name not in manifest["trees"]:
            out[name] = tree
            continue
        data = np.load(os.path.join(path, f"{name}.npz"))
        leaves = [data[f"a{i}"] for i in range(len(data.files))]
        treedef = jax.tree_util.tree_structure(tree)
        like_leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(like_leaves), (
            f"{name}: checkpoint has {len(leaves)} leaves, expected {len(like_leaves)}")
        cast = []
        for saved, want in zip(leaves, like_leaves):
            arr = saved
            want_dtype = getattr(want, "dtype", None)
            if want_dtype is not None and arr.dtype != want_dtype:
                arr = arr.astype(want_dtype)
            cast.append(arr)
        restored = jax.tree_util.tree_unflatten(treedef, cast)
        if shardings and name in shardings and shardings[name] is not None:
            restored = jax.device_put(restored, shardings[name])
        out[name] = restored
    return step, out


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in flight)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state: Dict[str, Any]) -> None:
        self.wait()
        host_state = jax.tree.map(lambda a: np.asarray(a), state)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_state, self.keep), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
