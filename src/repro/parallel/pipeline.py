"""GPipe-style pipeline parallelism over a mesh axis (shard_map +
collective_permute).

The multi-pod mesh's 'pod' axis can run as a pipeline instead of pure DP
(inter-pod links are the slowest, and PP moves only activations —
microbatch boundary traffic — across them).  Schedule: GPipe with M
microbatches; bubble fraction (S-1)/(M+S-1).

``pipeline_apply`` runs ``stage_fn`` (this rank's stage params) over M
microbatches: each step, ranks process their microbatch then permute
activations forward.  Implemented with a rotating buffer so every rank
executes the same program (SPMD).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from .compat import axis_size


def pipeline_apply(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   stage_params: Any, micro_in: jnp.ndarray,
                   axis: str = "pod") -> jnp.ndarray:
    """Inside shard_map over ``axis``.

    micro_in: (M, mb, ...) — this *pipeline input* is only meaningful on
    stage 0 (others receive via permute).  Returns (M, mb, ...) outputs,
    meaningful on the last stage.
    """
    s = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m = micro_in.shape[0]
    total = m + s - 1
    fwd = [(i, (i + 1) % s) for i in range(s)]

    buf = jnp.zeros_like(micro_in[0])
    outs = jnp.zeros_like(micro_in)

    def body(t, carry):
        buf, outs = carry
        # stage 0 injects microbatch t (if in range); others use arrival
        mb_idx = jnp.clip(t, 0, m - 1)
        inject = jax.lax.dynamic_index_in_dim(micro_in, mb_idx, 0, keepdims=False)
        x = jnp.where(idx == 0, inject, buf)
        y = stage_fn(stage_params, x)
        # last stage records its result for microbatch (t - (s-1))
        out_idx = jnp.clip(t - (s - 1), 0, m - 1)
        write = (idx == s - 1) & (t >= s - 1)
        cur = jax.lax.dynamic_index_in_dim(outs, out_idx, 0, keepdims=False)
        outs = jax.lax.dynamic_update_index_in_dim(
            outs, jnp.where(write, y, cur), out_idx, 0)
        buf = jax.lax.ppermute(y, axis, fwd)
        return buf, outs

    _, outs = jax.lax.fori_loop(0, total, body, (buf, outs))
    # only the last stage wrote real outputs; psum broadcasts them (other
    # ranks hold zeros), making the result replicated over the axis
    return jax.lax.psum(outs, axis)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
