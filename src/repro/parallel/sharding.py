"""Sharding rules: pytree-path-based PartitionSpecs for params, optimizer
state, batches, and caches.

Strategy (GSPMD/pjit):

* 2-D weights are fully sharded ``P('data', 'model')`` (FSDP-style: GSPMD
  all-gathers the 'data' axis of a weight when it is consumed, which is
  what keeps dbrx-132b's 264 GB of bf16 params at ~1 GB/chip on a 256-chip
  pod);
* TP follows Megatron: column-parallel in-projections shard their output
  dim on 'model', row-parallel out-projections shard their input dim on
  'model'; the embedding shards vocab on 'model';
* MoE expert-stacked weights shard experts on 'model' (EP);
* the extra multi-pod 'pod' axis is pure data parallelism: params are
  replicated across pods, batches sharded;
* batches shard batch on ('pod','data'); decode caches shard batch on
  'data' when batch >= |data|, otherwise (long-context, batch=1) they
  shard the *sequence* dimension on 'data' (sequence-parallel decode).

This is where the Stripe partition pass's decision (bank = outer parallel
index) meets the mesh: the pass picks the logical split; GSPMD executes it.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# last-dim-rule tables: rule applies to the trailing ndims of the leaf
_COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "up_proj", "in_proj", "w_gates"}
_ROW_PARALLEL = {"wo", "w_down", "down_proj", "out_proj"}


def _rule_for(path: Tuple[str, ...], leaf) -> Tuple[Optional[str], ...]:
    name = path[-1] if path else ""
    parent = path[-2] if len(path) >= 2 else ""
    nd = leaf.ndim

    if name in ("embed",):
        # vocab over model, d replicated: fully sharding d over 'data' makes
        # GSPMD all-reduce the (B,S,V/16) logits activation (33.6 GB/chip on
        # llama3 train) instead of gathering this 65 MB weight — §Perf it.3
        return ("model", None)
    if name in ("unembed",):
        return (None, "model")
    if parent == "moe" or (name in ("w_gate", "w_up", "w_down") and nd - _stack_dims(path, leaf) == 3):
        # expert-stacked (E, D, F): EP over model
        if name in ("w_gate", "w_up", "w_down"):
            return ("model", "data", None)
        if name == "router":
            return (None, None)
    if name in _COL_PARALLEL:
        # pure Megatron TP: sharding the contraction dim over 'data' (FSDP
        # style) makes GSPMD all-reduce full-activation partial sums — 120
        # GB/layer on llama3 train (§Perf iteration 4).  Optimizer-state
        # memory is recovered by ZeRO-1 (optim/zero1.py) instead.
        return (None, "model")
    if name in _ROW_PARALLEL:
        return ("model", None)
    if name in ("patch_proj", "frame_proj"):
        return (None, "model")
    if name == "r_gates":
        return (None, None, "model")
    if name == "conv_w":
        return (None, "model")
    return None  # replicate


def _stack_dims(path: Tuple[str, ...], leaf) -> int:
    """Leading stacked-layer dims (scan over blocks adds 1; zamba mamba
    adds 2).  Heuristic: params under 'blocks'/'encoder'/'decoder' have 1,
    under 'mamba' have 2."""
    for key in path:
        if key in ("blocks", "encoder", "decoder"):
            return 1
        if key == "mamba":
            return 2
    return 0


DEFAULT_AXES = {"pod": 2, "data": 16, "model": 16}


def _axis_len(axis, sizes) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axis, 1)


def _guard(spec_dims, shape, sizes):
    """Drop any axis whose length does not divide the dim (jit requires
    exact divisibility for in_shardings)."""
    out = []
    for d, axis in enumerate(spec_dims):
        if axis is not None and shape[d] % _axis_len(axis, sizes) != 0:
            axis = None
        out.append(axis)
    return tuple(out)


def param_spec(path: Tuple[str, ...], leaf, sizes=None) -> P:
    sizes = sizes or DEFAULT_AXES
    rule = _rule_for(path, leaf)
    nd = leaf.ndim
    if rule is None:
        return P()
    rule = tuple(rule)
    base = max(nd - len(rule), 0)
    full = (None,) * base + rule[: nd - base] if len(rule) <= nd else (None,) * nd
    return P(*_guard(full, leaf.shape, sizes))


def _path_names(keypath) -> Tuple[str, ...]:
    names = []
    for k in keypath:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_specs(params: Any, sizes=None) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: param_spec(_path_names(kp), leaf, sizes), params
    )


def opt_specs(params_specs: Any, opt_state_shape: Any) -> Any:
    """m/v mirror the param specs; step is replicated."""
    return {
        "m": params_specs,
        "v": params_specs,
        "step": P(),
    }


def batch_specs(batch: Any, dp_axes=("pod", "data"), sizes=None) -> Any:
    sizes = sizes or DEFAULT_AXES

    def spec(leaf):
        nd = getattr(leaf, "ndim", len(leaf.shape))
        dims = _guard((dp_axes,) + (None,) * (nd - 1), leaf.shape, sizes)
        return P(*dims)
    return jax.tree.map(spec, batch)


def cache_specs(cache: Any, batch_size: int, dp_size: int, dp_axes=("data",), sizes=None) -> Any:
    """Decode-state sharding, key-aware:

    * KV caches ('k'/'v': (..., B, S, KV, hd)): KV heads shard on 'model'
      (GSPMD pads when KV < |model|); B shards on data when divisible,
      otherwise (long-context, B=1) the *sequence* dim shards on data
      (sequence-parallel decode — partial attention combined by GSPMD).
    * SSM/conv/sLSTM states: batch on data, head/channel dim on 'model'.
    """
    sizes = sizes or DEFAULT_AXES
    batch_ok = batch_size >= dp_size and batch_size % dp_size == 0
    tp = sizes.get("model", 1)

    def spec_for(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd == 0:
            return P()
        name = path[-1] if path else ""
        out = [None] * nd
        if name in ("k", "v") and nd >= 4:
            b_d, s_d, kv_d, hd_d = nd - 4, nd - 3, nd - 2, nd - 1
            if batch_ok:
                out[b_d] = dp_axes
            elif shape[s_d] % dp_size == 0:
                out[s_d] = dp_axes  # sequence-parallel long-context decode
            # flash-decode style: shard cached positions over 'model' — the
            # softmax/value partials GSPMD emits are O(B*H*hd), instead of
            # gathering the whole cache (hillclimb 2, EXPERIMENTS.md §Perf)
            if out[s_d] is None and shape[s_d] % tp == 0:
                out[s_d] = "model"
            elif shape[kv_d] % tp == 0:
                out[kv_d] = "model"
            elif shape[hd_d] % tp == 0:
                out[hd_d] = "model"
            return P(*_guard(tuple(out), shape, sizes))
        if name == "pos":
            return P()
        # generic state (conv: (...,B,W,C); ssd C/n: (...,B,nh,...); slstm)
        placed_dp = False
        for d, s in enumerate(shape):
            if not placed_dp and s == batch_size and batch_ok:
                out[d] = dp_axes
                placed_dp = True
                break
        for d in range(nd - 1, -1, -1):
            if out[d] is None and d != 0 and shape[d] % tp == 0 and shape[d] >= tp:
                out[d] = "model"
                break
        return P(*_guard(tuple(out), shape, sizes))

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: spec_for(_path_names(kp), leaf), cache)


def make_sharding(mesh: Mesh, tree_specs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
