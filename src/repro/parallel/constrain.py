"""Mesh-aware sharding constraints usable from model code.

``constrain(x, 'model', None, ...)`` applies
``jax.lax.with_sharding_constraint`` when tracing under a mesh whose axis
names include the requested ones, and is a no-op otherwise (so the same
model code runs in single-device smoke tests and in the 512-chip
dry-run).  Axes whose size does not divide the dim are dropped.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


def _current_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:  # older path: physical mesh context
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def constrain(x, *axes):
    """axes: one entry per dim — an axis name, a tuple of names, or None."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    sizes = dict(mesh.shape)
    spec = []
    for d, a in enumerate(axes):
        if a is None:
            spec.append(None)
            continue
        group = (a,) if isinstance(a, str) else tuple(a)
        if not all(g in names for g in group):
            spec.append(None)
            continue
        total = 1
        for g in group:
            total *= sizes[g]
        if x.shape[d] % total != 0:
            spec.append(None)
            continue
        spec.append(a if isinstance(a, str) else tuple(a))
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
