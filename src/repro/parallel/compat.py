"""Small jax-version compatibility shims for the parallel/optim layers."""
from __future__ import annotations

from typing import Optional

import jax


def _static_mesh_size(name: str) -> Optional[int]:
    """Size of axis ``name`` on the ambient mesh (a ``with mesh:``
    context), resolvable *outside* any shard_map/pmap trace."""
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and name in getattr(mesh, "shape", {}):
            return int(mesh.shape[name])
    except Exception:
        pass
    try:  # newer jax: sharding-context abstract mesh
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and name in getattr(mesh, "shape", {}):
            return int(mesh.shape[name])
    except Exception:
        pass
    return None


def axis_size(name: str, mesh=None) -> int:
    """Static size of the named mesh axis, inside *or* outside shard_map.

    Resolution order: an explicitly passed ``mesh``; the bound axis of
    the enclosing shard_map/pmap trace (``jax.lax.axis_size`` on newer
    jax, ``jax.core.axis_frame`` on 0.4.x); finally the ambient mesh of
    a ``with mesh:`` context, so helpers like the collective-matmul
    kernels and ZeRO-1 sharding arithmetic work when called at trace
    level too."""
    if mesh is not None and name in getattr(mesh, "shape", {}):
        return int(dict(mesh.shape)[name])
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        try:
            return int(fn(name))
        except Exception:
            pass
    else:
        try:
            frame = jax.core.axis_frame(name)
            return int(getattr(frame, "size", frame))
        except Exception:
            pass
    size = _static_mesh_size(name)
    if size is not None:
        return size
    raise NameError(
        f"unbound axis name {name!r}: not inside shard_map/pmap and no "
        "ambient mesh (`with mesh:`) defines it")
