"""Small jax-version compatibility shims for the parallel/optim layers."""
from __future__ import annotations

import jax


def axis_size(name: str) -> int:
    """Static size of the named mesh axis inside shard_map/pmap.

    ``jax.lax.axis_size`` only exists in newer jax releases; on older
    ones (e.g. 0.4.x) ``jax.core.axis_frame(name)`` resolves the bound
    axis and returns its (static) size."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    frame = jax.core.axis_frame(name)
    return int(getattr(frame, "size", frame))
