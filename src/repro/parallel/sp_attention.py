"""Sequence-parallel decode attention (long-context serving).

For ``long_500k`` the KV cache is sharded along the *sequence* dimension
over the data axis.  Each shard computes a flash-decode partial —
(local max m, local sum l, local weighted acc) — and the partials are
combined exactly with two ``psum``\\ s (log-sum-exp algebra).  One token's
attention over 524k cached positions thus never materializes on one chip.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def sp_decode_attention(q: jnp.ndarray, k_shard: jnp.ndarray, v_shard: jnp.ndarray,
                        valid_len_local: jnp.ndarray, sm_scale: float,
                        axis: str = "data") -> jnp.ndarray:
    """Inside shard_map.  q: (B, H, hd) replicated over ``axis``;
    k_shard/v_shard: (B, S_local, H, hd); valid_len_local: () or (B,) —
    number of valid cached positions in this shard.  Returns (B, H, hd).
    """
    b, s_loc, h, hd = k_shard.shape
    kf = k_shard.astype(jnp.float32)
    vf = v_shard.astype(jnp.float32)
    qf = q.astype(jnp.float32) * sm_scale

    logits = jnp.einsum("bhd,bshd->bhs", qf, kf)
    pos = jnp.arange(s_loc, dtype=jnp.int32)
    mask = pos[None, None, :] < jnp.reshape(valid_len_local, (-1, 1, 1))
    logits = jnp.where(mask, logits, NEG_INF)

    m_loc = jnp.max(logits, axis=-1)                       # (B, H)
    m_glob = jax.lax.pmax(m_loc, axis)
    p = jnp.exp(logits - m_glob[..., None])
    l_loc = jnp.sum(p, axis=-1)                            # (B, H)
    acc_loc = jnp.einsum("bhs,bshd->bhd", p, vf)
    l_glob = jax.lax.psum(l_loc, axis)
    acc_glob = jax.lax.psum(acc_loc, axis)
    return (acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]).astype(q.dtype)


def full_decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                              valid_len: jnp.ndarray, sm_scale: float) -> jnp.ndarray:
    """Unsharded oracle."""
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32) * sm_scale, k.astype(jnp.float32))
    pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    mask = pos[None, None, :] < jnp.reshape(valid_len, (-1, 1, 1))
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w, v.astype(jnp.float32)).astype(q.dtype)
