"""Overlapped collective matmul (ring all-gather matmul).

TP matmul x @ W with W sharded on its input dim normally requires
all-gather(x-shard) *then* matmul — serializing communication and
compute.  The ring formulation interleaves them: at each of N steps,
multiply the chunk currently held while ``collective_permute``-ing the
next chunk around the ring, hiding (N-1)/N of the transfer behind MXU
work.  This is the classic "collective matmul" (Wang et al.) used by
MaxText; here it is the beyond-paper optimization for Stripe's partition
pass output (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from .compat import axis_size


def ring_allgather_matmul(x_shard: jnp.ndarray, w: jnp.ndarray,
                          axis: str = "model") -> jnp.ndarray:
    """Sequence/batch-parallel -> column-parallel matmul with all-gather
    overlap, inside shard_map.

    x_shard: (M/N, K) — x sharded on rows over ``axis``;
    w:       (K, F_local) — this rank's column shard of W (full K).
    Returns (M, F_local): every rank's output for ALL rows — the x chunks
    travel a ring; at each step the chunk in hand is multiplied while the
    next one is in flight (overlapping (N-1)/N of the gather).
    """
    n = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m_loc, k = x_shard.shape
    perm = [(i, (i + 1) % n) for i in range(n)]
    out = jnp.zeros((n * m_loc, w.shape[1]), x_shard.dtype)

    def body(s, carry):
        out, chunk = carry
        src = (idx - s) % n  # originating rank of the chunk in hand
        rows = (chunk @ w).astype(out.dtype)
        out = jax.lax.dynamic_update_slice(out, rows, (src * m_loc, 0))
        chunk = jax.lax.ppermute(chunk, axis, perm)
        return out, chunk

    out, _ = jax.lax.fori_loop(0, n, body, (out, x_shard))
    return out


def ring_matmul_reduce_scatter(x_shard: jnp.ndarray, w_shard: jnp.ndarray,
                               axis: str = "model") -> jnp.ndarray:
    """Row-parallel matmul with ring reduce-scatter overlap, inside
    shard_map.

    x_shard: (M, K/N) — activations sharded on K (as produced by a
    preceding column-parallel layer); w_shard: (K/N, F) — W rows sharded.
    Output: (M, F/N) — this rank's F-shard of x @ W.

    The accumulator that finishes at rank r travels the ring; when it
    visits rank q at step s, q adds its local partial for column block
    ``(q + n-1 - s) mod n`` — one (M,K/N)x(K/N,F/N) matmul overlaps each
    permute.
    """
    n = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    f = w_shard.shape[1]
    assert f % n == 0
    fc = f // n
    fwd = [(i, (i + 1) % n) for i in range(n)]

    def w_cols(b):
        return jax.lax.dynamic_slice_in_dim(w_shard, b * fc, fc, axis=1)

    def partial_for(b):
        return (x_shard.astype(jnp.float32) @ w_cols(b).astype(jnp.float32))

    acc = partial_for((idx + n - 1) % n)

    def body(s, acc):
        acc = jax.lax.ppermute(acc, axis, fwd)
        b = (idx + n - 1 - s) % n
        return acc + partial_for(b)

    acc = jax.lax.fori_loop(1, n, body, acc)
    return acc.astype(x_shard.dtype)


def allgather_matmul_baseline(x_shard: jnp.ndarray, w: jnp.ndarray,
                              axis: str = "model") -> jnp.ndarray:
    """Unoverlapped baseline: gather x fully, then one big matmul."""
    x = jax.lax.all_gather(x_shard, axis, axis=0, tiled=True)
    return x @ w
