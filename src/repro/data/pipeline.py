"""Deterministic, restartable, sharded data pipeline.

Design for scale: each data-parallel rank owns a disjoint shard of an
infinite synthetic token stream (or a memory-mapped token file).  The
iterator state is two integers (epoch seed, step) — checkpointing the
pipeline is exact and O(1), and restart resumes bit-identically.  A
background prefetch thread keeps ``prefetch`` batches ready.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_shards: int = 1
    shard_id: int = 0
    seed: int = 0
    kind: str = "synthetic"     # synthetic | memmap
    path: str = ""              # for memmap
    prefetch: int = 2


class TokenStream:
    """Zipfian synthetic documents packed into fixed-length sequences.
    Deterministic in (seed, shard, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.kind == "memmap":
            self._data = np.memmap(cfg.path, dtype=np.uint16, mode="r")
        else:
            self._data = None
        # zipf-ish rank probabilities over the vocab (heavy head, long tail)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._p = p / p.sum()

    @property
    def per_shard_batch(self) -> int:
        assert self.cfg.global_batch % self.cfg.n_shards == 0
        return self.cfg.global_batch // self.cfg.n_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The batch for a given global step — pure function of (cfg, step)."""
        b, s = self.per_shard_batch, self.cfg.seq_len
        if self._data is not None:
            n = len(self._data) - (s + 1)
            rng = np.random.RandomState((self.cfg.seed, self.cfg.shard_id, step))
            starts = rng.randint(0, n, size=b)
            toks = np.stack([self._data[st : st + s + 1] for st in starts]).astype(np.int32)
        else:
            rng = np.random.RandomState((self.cfg.seed, self.cfg.shard_id, step) )
            toks = rng.choice(self.cfg.vocab, size=(b, s + 1), p=self._p).astype(np.int32)
        return {"tokens": toks[:, :s], "labels": toks[:, 1 : s + 1]}


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    @staticmethod
    def from_dict(d) -> "PipelineState":
        return PipelineState(step=int(d["step"]))


class DataPipeline:
    """Prefetching iterator with checkpointable state."""

    def __init__(self, cfg: DataConfig, state: Optional[PipelineState] = None):
        self.cfg = cfg
        self.stream = TokenStream(cfg)
        self.state = state or PipelineState()
        self._q: "queue.Queue" = queue.Queue(maxsize=max(cfg.prefetch, 1))
        self._stop = threading.Event()
        self._next_to_produce = self.state.step
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        while not self._stop.is_set():
            step = self._next_to_produce
            batch = self.stream.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            self._next_to_produce += 1

    def next(self) -> Dict[str, np.ndarray]:
        while True:
            step, batch = self._q.get()
            if step == self.state.step:  # drop stale batches after restore
                self.state.step += 1
                return batch
            if step > self.state.step:
                # producer ran ahead of a restored state: restart producer
                self._restart_producer()

    def _restart_producer(self):
        self._stop.set()
        self._thread.join(timeout=2)
        self._q = queue.Queue(maxsize=max(self.cfg.prefetch, 1))
        self._stop = threading.Event()
        self._next_to_produce = self.state.step
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def restore(self, state: PipelineState):
        self.state = PipelineState(step=state.step)
        self._restart_producer()

    def close(self):
        self._stop.set()


def build_token_file(path: str, n_tokens: int, vocab: int, seed: int = 0) -> None:
    """Utility: write a synthetic binary token file for the memmap path."""
    rng = np.random.RandomState(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = (1.0 / ranks) / (1.0 / ranks).sum()
    data = rng.choice(vocab, size=n_tokens, p=p).astype(np.uint16)
    data.tofile(path)
