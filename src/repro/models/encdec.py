"""Encoder-decoder (seamless-m4t backbone): encoder over stub frame
embeddings, decoder over text with cross-attention.  Both stacks scanned."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..nn.attention import attention, attn_init, init_kv_cache
from ..nn.core import (
    Params, apply_norm, embed_init, embed_lookup, mlp_apply, mlp_init,
    norm_init, param_dtype, softmax_xent, unembed,
)


def _enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": attn_init(k1, cfg, dtype),
        "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": norm_init(cfg.d_model, cfg.norm, dtype),
        "self_attn": attn_init(k1, cfg, dtype),
        "ln_x": norm_init(cfg.d_model, cfg.norm, dtype),
        "cross_attn": attn_init(k2, cfg, dtype),
        "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def init_params(cfg, rng) -> Params:
    dtype = param_dtype(cfg)
    k_embed, k_enc, k_dec, k_out, k_fe = jax.random.split(rng, 5)
    enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "frame_proj": embed_init(k_fe, cfg.d_model, cfg.d_model, dtype),
        "encoder": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(enc_keys),
        "enc_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "decoder": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(dec_keys),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "unembed": embed_init(k_out, cfg.d_model, cfg.padded_vocab, dtype),
    }


def encode(p: Params, cfg, frames: jnp.ndarray, remat: bool = False) -> jnp.ndarray:
    x = jnp.einsum("bsd,de->bse", frames.astype(p["frame_proj"].dtype), p["frame_proj"])

    def body(carry, params_i):
        h, _ = attention(params_i["attn"], apply_norm(params_i["ln1"], carry, cfg.norm),
                         cfg, causal=False)
        carry = carry + h
        carry = carry + mlp_apply(params_i["mlp"], apply_norm(params_i["ln2"], carry, cfg.norm), cfg.act)
        return carry, 0.0

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, p["encoder"])
    return apply_norm(p["enc_norm"], x, cfg.norm)


def _dec_block(params_i, x, cfg, memory, cache):
    h, new_cache = attention(params_i["self_attn"], apply_norm(params_i["ln1"], x, cfg.norm),
                             cfg, causal=True, cache=cache)
    x = x + h
    h, _ = attention(params_i["cross_attn"], apply_norm(params_i["ln_x"], x, cfg.norm),
                     cfg, memory=memory, causal=False)
    x = x + h
    x = x + mlp_apply(params_i["mlp"], apply_norm(params_i["ln2"], x, cfg.norm), cfg.act)
    return x, new_cache


def decode_stack(p: Params, cfg, x, memory, caches=None, remat: bool = False):
    def body(carry, layer):
        params_i, cache_i = layer
        out, new_cache = _dec_block(params_i, carry, cfg, memory, cache_i)
        return out, new_cache

    if remat:
        body = jax.checkpoint(body)
    if caches is None:
        def body_nc(carry, params_i):
            out, _ = _dec_block(params_i, carry, cfg, memory, None)
            return out, 0.0
        if remat:
            body_nc = jax.checkpoint(body_nc)
        x, _ = jax.lax.scan(body_nc, x, p["decoder"])
        return x, None
    x, new_caches = jax.lax.scan(body, x, (p["decoder"], caches))
    return x, new_caches


def _logits(p, cfg, x):
    x = apply_norm(p["final_norm"], x, cfg.norm)
    return unembed(x, p["unembed"], False)


def loss_fn(p: Params, cfg, batch, remat: bool = True):
    memory = encode(p, cfg, batch["frames"], remat=remat)
    x = embed_lookup(p["embed"], batch["tokens"])
    x, _ = decode_stack(p, cfg, x, memory, None, remat=remat)
    logits = _logits(p, cfg, x)
    loss = softmax_xent(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab)
    return loss, {"loss": loss}


def init_cache(cfg, batch: int, max_len: int, dtype) -> Any:
    one = init_kv_cache(cfg, batch, max_len, dtype)
    kv = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), one)
    return {"kv": kv, "memory": None}


def prefill(p: Params, cfg, batch, cache):
    """Runs the encoder on frames and prefills the decoder with tokens."""
    memory = encode(p, cfg, batch["frames"])
    x = embed_lookup(p["embed"], batch["tokens"])
    x, new_kv = decode_stack(p, cfg, x, memory, cache["kv"])
    return _logits(p, cfg, x[:, -1:]), {"kv": new_kv, "memory": memory}


def decode_step(p: Params, cfg, cache, tokens):
    x = embed_lookup(p["embed"], tokens)
    x, new_kv = decode_stack(p, cfg, x, cache["memory"], cache["kv"])
    return _logits(p, cfg, x), {"kv": new_kv, "memory": cache["memory"]}
