"""Decoder-only LM covering the dense, moe, and vlm families.

Layers are scanned (``jax.lax.scan`` over stacked per-layer params) so the
compiled HLO stays one-layer-sized for 32-48 layer configs; training wraps
the body in ``jax.checkpoint`` (full remat).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..nn.attention import attention, attn_init, init_kv_cache
from ..nn.core import (
    Params,
    apply_norm,
    embed_init,
    embed_lookup,
    mlp_apply,
    mlp_init,
    norm_init,
    param_dtype,
    softmax_xent,
    unembed,
)
from ..nn.moe import moe_apply, moe_init


def block_init(key, cfg, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": attn_init(k1, cfg, dtype),
        "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if cfg.moe:
        p["moe"] = moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def block_apply(p: Params, x: jnp.ndarray, cfg, cache=None):
    h, new_cache = attention(p["attn"], apply_norm(p["ln1"], x, cfg.norm), cfg,
                             causal=True, cache=cache)
    x = x + h
    if cfg.moe:
        h2, aux = moe_apply(p["moe"], apply_norm(p["ln2"], x, cfg.norm), cfg)
    else:
        h2 = mlp_apply(p["mlp"], apply_norm(p["ln2"], x, cfg.norm), cfg.act)
        aux = jnp.zeros((), jnp.float32)
    return x + h2, new_cache, aux


def init_params(cfg, rng) -> Params:
    dtype = param_dtype(cfg)
    k_embed, k_blocks, k_out, k_fe = jax.random.split(rng, 4)
    keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: block_init(k, cfg, dtype))(keys)
    p = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(k_out, cfg.d_model, cfg.padded_vocab, dtype)
    if cfg.frontend == "patches":
        # stub frontend: a learned projection applied to precomputed patch
        # embeddings (the assignment: modality frontend is a stub)
        p["patch_proj"] = embed_init(k_fe, cfg.d_model, cfg.d_model, dtype)
    return p


def _stack(p: Params, x: jnp.ndarray, cfg, caches=None, remat: bool = False):
    from ..parallel.constrain import constrain

    def body(carry, layer):
        xc = constrain(carry, ("pod", "data"), None, None)
        params_i, cache_i = layer
        out, new_cache, aux = block_apply(params_i, xc, cfg, cache_i)
        return out, (new_cache, aux)

    if remat:
        body = jax.checkpoint(body)
    if caches is None:
        def body_nc(carry, params_i):
            carry = constrain(carry, ("pod", "data"), None, None)
            out, _, aux = block_apply(params_i, carry, cfg, None)
            return out, aux
        if remat:
            body_nc = jax.checkpoint(body_nc)
        x, auxs = jax.lax.scan(body_nc, x, p["blocks"])
        return x, None, jnp.sum(auxs)
    x, (new_caches, auxs) = jax.lax.scan(body, x, (p["blocks"], caches))
    return x, new_caches, jnp.sum(auxs)


def _embed_inputs(p: Params, cfg, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    from ..parallel.constrain import constrain

    x = embed_lookup(p["embed"], batch["tokens"])
    if cfg.frontend == "patches" and "patches" in batch:
        pe = jnp.einsum("bpd,de->bpe", batch["patches"].astype(x.dtype), p["patch_proj"])
        x = jnp.concatenate([pe, x], axis=1)
    # keep activations batch-sharded through the stack (GSPMD otherwise
    # replicates the vocab-sharded gather output before re-partitioning)
    return constrain(x, ("pod", "data"), None, None)


def _logits(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    x = apply_norm(p["final_norm"], x, cfg.norm)
    w = p["embed"] if cfg.tie_embeddings else p["unembed"]
    return unembed(x, w, cfg.tie_embeddings)


def loss_fn(p: Params, cfg, batch: Dict[str, jnp.ndarray], remat: bool = True):
    x = _embed_inputs(p, cfg, batch)
    x, _, aux = _stack(p, x, cfg, None, remat=remat)
    if cfg.frontend == "patches" and "patches" in batch:
        x = x[:, batch["patches"].shape[1]:]  # loss on text positions only
    logits = _logits(p, cfg, x)
    loss = softmax_xent(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux}


def init_cache(cfg, batch: int, max_len: int, dtype) -> Any:
    one = init_kv_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), one)


def prefill(p: Params, cfg, batch: Dict[str, jnp.ndarray], cache):
    x = _embed_inputs(p, cfg, batch)
    x, new_caches, _ = _stack(p, x, cfg, cache)
    logits = _logits(p, cfg, x[:, -1:])
    return logits, new_caches


def decode_step(p: Params, cfg, cache, tokens: jnp.ndarray):
    """tokens: (B, 1)."""
    x = embed_lookup(p["embed"], tokens)
    x, new_caches, _ = _stack(p, x, cfg, cache)
    return _logits(p, cfg, x), new_caches
