"""Zamba2-style hybrid: Mamba2 backbone with one *shared* transformer
block (attention + MLP, weights shared) applied before every group of
``shared_attn_every`` mamba layers — each application has its own KV
cache (9 applications for 54 layers / 6)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..nn.attention import attention, attn_init, init_kv_cache
from ..nn.core import (
    Params, apply_norm, embed_init, embed_lookup, mlp_apply, mlp_init,
    norm_init, param_dtype, softmax_xent, unembed,
)
from ..nn.ssm import mamba2_apply, mamba2_init, mamba2_init_state


def _n_groups(cfg) -> int:
    k = cfg.hybrid.shared_attn_every
    return (cfg.n_layers + k - 1) // k


def init_params(cfg, rng) -> Params:
    dtype = param_dtype(cfg)
    k_embed, k_shared, k_mamba, k_out = jax.random.split(rng, 4)
    groups = _n_groups(cfg)
    per_group = cfg.hybrid.shared_attn_every
    keys = jax.random.split(k_mamba, groups * per_group).reshape(groups, per_group, 2)
    mamba = jax.vmap(jax.vmap(lambda k: mamba2_init(k, cfg, dtype)))(keys)
    ks = jax.random.split(k_shared, 3)
    shared = {
        "ln1": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": attn_init(ks[0], cfg, dtype),
        "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }
    return {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "mamba": mamba,
        "shared": shared,
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "unembed": embed_init(k_out, cfg.d_model, cfg.padded_vocab, dtype),
    }


def _shared_block(p: Params, x, cfg, cache):
    h, new_cache = attention(p["attn"], apply_norm(p["ln1"], x, cfg.norm),
                             cfg, causal=True, cache=cache)
    x = x + h
    x = x + mlp_apply(p["mlp"], apply_norm(p["ln2"], x, cfg.norm), cfg.act)
    return x, new_cache


def _forward(p: Params, cfg, x, caches=None, remat: bool = False):
    shared = p["shared"]

    def group_body(carry, layer):
        xc = carry
        mamba_i, cache_i = layer
        attn_cache = cache_i["attn"] if cache_i is not None else None
        xc, new_attn = _shared_block(shared, xc, cfg, attn_cache)

        def mamba_body(c2, layer2):
            params_j, st_j = layer2
            out, new_st = mamba2_apply(params_j, c2, cfg, state=st_j)
            return out, new_st

        if cache_i is None:
            def mamba_nc(c2, params_j):
                out, _ = mamba2_apply(params_j, c2, cfg, state=None)
                return out, 0.0
            xc, _ = jax.lax.scan(mamba_nc, xc, mamba_i)
            return xc, 0.0
        xc, new_states = jax.lax.scan(mamba_body, xc, (mamba_i, cache_i["mamba"]))
        return xc, {"attn": new_attn, "mamba": new_states}

    if remat:
        group_body = jax.checkpoint(group_body)
    if caches is None:
        x, _ = jax.lax.scan(lambda c, m: group_body(c, (m, None)), x, p["mamba"])
        return x, None
    x, new_caches = jax.lax.scan(group_body, x, (p["mamba"], caches))
    return x, new_caches


def _logits(p, cfg, x):
    x = apply_norm(p["final_norm"], x, cfg.norm)
    return unembed(x, p["unembed"], False)


def loss_fn(p: Params, cfg, batch, remat: bool = True):
    x = embed_lookup(p["embed"], batch["tokens"])
    x, _ = _forward(p, cfg, x, None, remat=remat)
    logits = _logits(p, cfg, x)
    loss = softmax_xent(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab)
    return loss, {"loss": loss}


def init_cache(cfg, batch: int, max_len: int, dtype) -> Any:
    groups = _n_groups(cfg)
    per_group = cfg.hybrid.shared_attn_every
    attn = init_kv_cache(cfg, batch, max_len, dtype)
    attn = jax.tree.map(lambda a: jnp.broadcast_to(a, (groups, *a.shape)), attn)
    mst = mamba2_init_state(cfg, batch, dtype)
    mst = jax.tree.map(lambda a: jnp.broadcast_to(a, (groups, per_group, *a.shape)), mst)
    return {"attn": attn, "mamba": mst}


def prefill(p: Params, cfg, batch, cache):
    x = embed_lookup(p["embed"], batch["tokens"])
    x, new_caches = _forward(p, cfg, x, cache)
    return _logits(p, cfg, x[:, -1:]), new_caches


def decode_step(p: Params, cfg, cache, tokens):
    x = embed_lookup(p["embed"], tokens)
    x, new_caches = _forward(p, cfg, x, cache)
    return _logits(p, cfg, x), new_caches
