"""Uniform model API: ``build_model(cfg)`` returns a ``Model`` with
init / loss / prefill / decode_step / init_cache, dispatching on family.

Also provides ``input_specs(cfg, shape)`` (ShapeDtypeStruct stand-ins for
the dry-run) and ``make_batch`` (small real arrays for smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec
from . import encdec, hybrid, lm, xlstm_model


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        mod = lm
    elif cfg.family == "hybrid":
        mod = hybrid
    elif cfg.family == "ssm" and cfg.xlstm is not None:
        mod = xlstm_model
    elif cfg.family == "audio" and cfg.enc_dec:
        mod = encdec
    else:
        raise ValueError(f"no model for family {cfg.family}")

    return Model(
        cfg=cfg,
        init=lambda rng: mod.init_params(cfg, rng),
        loss=lambda p, batch, remat=True: mod.loss_fn(p, cfg, batch, remat=remat),
        prefill=lambda p, batch, cache: mod.prefill(p, cfg, batch, cache),
        decode_step=lambda p, cache, tok: mod.decode_step(p, cfg, cache, tok),
        init_cache=lambda batch, max_len, dtype=None: mod.init_cache(
            cfg, batch, max_len, jnp.dtype(dtype or cfg.dtype)),
    )


# --------------------------------------------------------------------------
# Input specs (dry-run) and synthetic batches (smoke)
# --------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        specs = {"tokens": tok, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.frontend == "patches":
            specs["patches"] = jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.frontend == "frames":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": tok}
        if cfg.frontend == "patches":
            specs["patches"] = jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.frontend == "frames":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
        return specs
    # decode: one new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def make_batch(cfg: ArchConfig, shape_kind: str, batch: int, seq: int, seed: int = 0) -> Dict[str, jnp.ndarray]:
    rng = np.random.RandomState(seed)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, size=(batch, seq)), jnp.int32)
    out = {"tokens": tokens}
    if shape_kind == "train":
        out["labels"] = tokens
    if cfg.frontend == "patches":
        out["patches"] = jnp.asarray(rng.randn(batch, cfg.frontend_len, cfg.d_model) * 0.1, jnp.dtype(cfg.dtype))
    if cfg.frontend == "frames":
        out["frames"] = jnp.asarray(rng.randn(batch, seq, cfg.d_model) * 0.1, jnp.dtype(cfg.dtype))
    return out
