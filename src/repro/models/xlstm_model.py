"""xLSTM LM: mixed mLSTM/sLSTM residual blocks (unrolled — 12 layers)."""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..nn.core import (
    Params, apply_norm, embed_init, embed_lookup, norm_init, param_dtype,
    softmax_xent, unembed,
)
from ..nn.xlstm import (
    mlstm_block_apply, mlstm_block_init, mlstm_init_state,
    slstm_block_apply, slstm_block_init, slstm_init_state,
)


def _kinds(cfg) -> List[str]:
    return ["slstm" if i in cfg.xlstm.slstm_at else "mlstm" for i in range(cfg.n_layers)]


def init_params(cfg, rng) -> Params:
    dtype = param_dtype(cfg)
    keys = jax.random.split(rng, cfg.n_layers + 2)
    p: Params = {"embed": embed_init(keys[-1], cfg.padded_vocab, cfg.d_model, dtype),
                 "final_norm": norm_init(cfg.d_model, cfg.norm, dtype)}
    for i, kind in enumerate(_kinds(cfg)):
        init = mlstm_block_init if kind == "mlstm" else slstm_block_init
        p[f"layer_{i}"] = {
            "ln": norm_init(cfg.d_model, cfg.norm, dtype),
            "core": init(keys[i], cfg, dtype),
        }
    return p


def _forward(p: Params, cfg, x, states: Optional[List] = None, remat: bool = False):
    new_states: List = []
    for i, kind in enumerate(_kinds(cfg)):
        lp = p[f"layer_{i}"]
        st = states[i] if states is not None else None
        xin = apply_norm(lp["ln"], x, cfg.norm)

        def run(core, xin, st, kind=kind):
            fn = mlstm_block_apply if kind == "mlstm" else slstm_block_apply
            return fn(core, xin, cfg, state=st) if kind == "slstm" else fn(core, xin, cfg, state=st)

        if remat:
            out, new_st = jax.checkpoint(lambda c, xi, s: run(c, xi, s), static_argnums=())(lp["core"], xin, st)
        else:
            out, new_st = run(lp["core"], xin, st)
        x = x + out
        new_states.append(new_st)
    return x, (new_states if states is not None else None)


def _logits(p, cfg, x):
    x = apply_norm(p["final_norm"], x, cfg.norm)
    return unembed(x, p["embed"], True)


def loss_fn(p: Params, cfg, batch, remat: bool = True):
    x = embed_lookup(p["embed"], batch["tokens"])
    x, _ = _forward(p, cfg, x, None, remat=remat)
    logits = _logits(p, cfg, x)
    loss = softmax_xent(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab)
    return loss, {"loss": loss}


def init_cache(cfg, batch: int, max_len: int, dtype) -> Any:
    out = []
    for kind in _kinds(cfg):
        if kind == "mlstm":
            out.append(mlstm_init_state(cfg, batch, dtype))
        else:
            out.append(slstm_init_state(cfg, batch))
    return out


def prefill(p: Params, cfg, batch, cache):
    x = embed_lookup(p["embed"], batch["tokens"])
    x, new_states = _forward(p, cfg, x, cache)
    return _logits(p, cfg, x[:, -1:]), new_states


def decode_step(p: Params, cfg, cache, tokens):
    x = embed_lookup(p["embed"], tokens)
    x, new_states = _forward(p, cfg, x, cache)
    return _logits(p, cfg, x), new_states
