"""Gradient compression for cross-pod all-reduce: int8 quantization with
per-block scales and error feedback (residual carrying), halving (or
quartering) inter-pod gradient traffic."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 1024


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8 quantization.  Returns (q, scales)."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis: str, residual: jnp.ndarray | None = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """psum of an int8-quantized tensor with error feedback.

    Returns (mean-reduced value, new residual).  Call inside shard_map.
    """
    val = x.astype(jnp.float32)
    if residual is not None:
        val = val + residual
    q, scale = quantize_int8(val)
    deq = dequantize_int8(q, scale, x.shape, jnp.float32)
    new_residual = val - deq  # what quantization lost, re-applied next step
    # the collective moves ~1 byte/elem (int8) + scales instead of 4
    summed = jax.lax.psum(deq, axis)
    return summed.astype(x.dtype), new_residual


def compression_ratio(shape) -> float:
    n = 1
    for s in shape:
        n *= s
    blocks = -(-n // BLOCK)
    return (n * 4) / (n * 1 + blocks * 4)
