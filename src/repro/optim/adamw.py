"""AdamW with global-norm clipping and schedules (pure pytree functions —
no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def init_state(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)))


def apply_updates(params: Any, grads: Any, state: Dict[str, Any], cfg: AdamWConfig
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}
