"""ZeRO-1: optimizer-state sharding over the data-parallel axis.

Inside ``shard_map`` over the 'data' axis:
  1. grads are reduce-scattered (each rank owns 1/N of every gradient),
  2. the AdamW update runs on the owned shard only (m/v sharded),
  3. updated param shards are all-gathered.

Memory: optimizer state drops from 8 bytes/param to 8/N bytes/param per
replica; collective volume is identical to a plain all-reduce
(reduce-scatter + all-gather).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import adamw
from ..parallel.compat import axis_size


def _flat_size(x: jnp.ndarray) -> int:
    n = 1
    for s in x.shape:
        n *= s
    return n


def zero1_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: adamw.AdamWConfig, axis: str = "data") -> Tuple[Any, Dict[str, Any], Dict]:
    """Per-shard update — call inside shard_map with params/grads replicated
    on ``axis`` and opt state sharded (leading dim = shard)."""
    n = axis_size(axis)
    idx = jax.lax.axis_index(axis)

    def rs(g):
        flat = g.reshape(-1).astype(jnp.float32)
        pad = (-flat.size) % n
        flat = jnp.pad(flat, (0, pad))
        return jax.lax.psum_scatter(flat.reshape(n, -1), axis, scatter_dimension=0, tiled=False)

    g_shards = jax.tree.map(rs, grads)

    step = state["step"] + 1
    gnorm_sq_local = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(g_shards))
    gnorm = jnp.sqrt(jax.lax.psum(gnorm_sq_local, axis))
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = adamw.lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        flat = p.reshape(-1).astype(jnp.float32)
        pad = (-flat.size) % n
        flat = jnp.pad(flat, (0, pad)).reshape(n, -1)
        p_shard = jax.lax.dynamic_index_in_dim(flat, idx, 0, keepdims=False)
        g = g * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        delta = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps) + cfg.weight_decay * p_shard
        new_shard = p_shard - lr * delta
        full = jax.lax.all_gather(new_shard, axis, tiled=True)
        return full[: _flat_size(p)].reshape(p.shape).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(g_shards)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}


def zero1_init_state(params: Any, n_shards: int) -> Dict[str, Any]:
    """Sharded m/v as *global* flat arrays of size n*ceil(|p|/n) — shard
    them with ``P('data')`` so each rank holds its ceil(|p|/n) slice."""
    def shard_zeros(p):
        size = _flat_size(p)
        per = -(-size // n_shards)
        return jnp.zeros((n_shards * per,), jnp.float32)

    return {
        "m": jax.tree.map(shard_zeros, params),
        "v": jax.tree.map(shard_zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
