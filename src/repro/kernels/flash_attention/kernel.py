"""Flash attention forward (TPU Pallas).

Structure: grid (batch*heads, q_blocks, kv_blocks) with the kv dimension
innermost; running (m, l, acc) state lives in VMEM scratch and persists
across kv steps; the output block is written on the last kv step.  Causal
blocks above the diagonal are skipped entirely (block-level early out).

The q/kv block sizes are chosen by the Stripe autotiler's roofline cost
model over the QK^T contraction (see ``choose_block_sizes``) — the paper's
"hardware config decides parameters, not the kernel author" discipline.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def choose_block_sizes(seq_q: int, seq_k: int, head_dim: int) -> Tuple[int, int]:
    """Stripe autotiler picks (block_q, block_k) for the attention score
    contraction S[q,k] += Q[q,d] * K[k,d].

    The search result is memoized through the compilation cache (memory
    LRU + on-disk store), so repeated calls — and warm processes — skip
    the autotile search entirely.
    """
    from ...core import cache as stripe_cache
    from ...core.hwconfig import get_config

    hw = get_config("tpu_v5e")
    params = {"cost": "roofline", "search": "pow2", "mem_cap_frac": 0.2, "count_untiled": True}
    memo_version = 1  # bump when the clamp logic below changes

    def search():
        from ...core.frontend import single_op_program
        from ...core.passes.autotile import choose_tiling

        prog = single_op_program(
            "S[q, k] += Q[q, d] * K[k, d]",
            {"Q": ((seq_q, head_dim), "bfloat16"), "K": ((seq_k, head_dim), "bfloat16"),
             "S": ((seq_q, seq_k), "float32")},
            out="S",
        )
        tiles, _cost = choose_tiling(prog.entry.stmts[0], hw, params)
        bq = max(min(tiles.get("q", 512), seq_q), min(128, seq_q))
        bk = max(min(tiles.get("k", 512), seq_k), min(128, seq_k))
        return [bq, bk]

    bq, bk = stripe_cache.memoize(
        "flash_attn_blocks",
        [memo_version, seq_q, seq_k, head_dim, sorted(params.items()), hw.fingerprint()],
        search)
    return int(bq), int(bk)


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               sm_scale: float, causal: bool, block_q: int, block_k: int,
               n_kv: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # skip blocks strictly above the diagonal
        run = (ki * block_k) <= (qi * block_q + block_q - 1)

    @pl.when(run if causal else (ki >= 0))
    def _step():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == n_kv - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, sm_scale: Optional[float] = None,
                    block_q: Optional[int] = None, block_k: Optional[int] = None,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D).  GQA: q heads grouped over
    kv heads.  Returns (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if sm_scale is None:
        sm_scale = float(1.0 / np.sqrt(d))
    if block_q is None or block_k is None:
        cq, ck = choose_block_sizes(sq, sk, d)
        block_q = block_q or min(cq, sq)
        block_k = block_k or min(ck, sk)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    n_q, n_kv = sq // block_q, sk // block_k

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)

    grid = (b * hq, n_q, n_kv)
    kern = functools.partial(
        _fa_kernel, sm_scale=sm_scale, causal=causal, block_q=block_q,
        block_k=block_k, n_kv=n_kv, seq_k=sk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, qi, ki, g=group: (h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
