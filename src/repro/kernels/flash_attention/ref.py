"""Pure-jnp oracle: softmax(q k^T * scale + mask) v with GQA support."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, sm_scale: float | None = None) -> jnp.ndarray:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / np.sqrt(d)
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
