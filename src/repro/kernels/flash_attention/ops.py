"""Public wrapper for flash attention."""
from .kernel import choose_block_sizes, flash_attention
from .ref import attention_ref

__all__ = ["flash_attention", "attention_ref", "choose_block_sizes"]
