"""Stripe-generated matmul kernel.

Unlike a hand-written kernel, this one is *compiled*: the op is expressed
in the Tile frontend, the TPU_V5E pass pipeline (fuse -> autotile ->
stencil -> boundary -> localize) chooses the grid, BlockSpec tile shapes
and the fused epilogue, and ``lower_op_pallas`` emits the
``pl.pallas_call``.  This module just exposes the build entry point.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

from ...core.driver import compile_cached
from ...core.frontend import TileProgram
from ...core.hwconfig import get_config
from ...core.ir import Block
from ...core.lower_pallas import lower_op_pallas
from ...core.passes import compile_program


@functools.lru_cache(maxsize=256)
def build_matmul_kernel(m: int, k: int, n: int, dtype: str = "float32",
                        act: Optional[str] = None, has_bias: bool = False,
                        interpret: bool = False) -> Callable:
    tp = TileProgram("stripe_matmul")
    tp.input("X", (m, k), dtype)
    tp.input("W", (k, n), dtype)
    if has_bias:
        tp.input("B", (n,), "float32")
    if act or has_bias:
        tp.temp("T", (m, n))
        tp.output("O", (m, n), dtype)
        tp.op("T[i, j] += X[i, c] * W[c, j]")
        expr = "T[i, j]"
        if has_bias:
            expr = f"({expr} + B[j])"
        if act:
            expr = f"{act}({expr})"
        tp.op(f"O[i, j] = {expr}")
    else:
        tp.output("O", (m, n), dtype)
        tp.op("O[i, j] += X[i, c] * W[c, j]")
    # the persistent compilation cache replays the tiling choice on warm
    # processes; the lru_cache above only helps within this one
    hw = get_config("tpu_v5e")
    prog, _record = compile_cached(tp.build(), hw)
    blocks = [s for s in prog.entry.stmts if isinstance(s, Block)]
    assert len(blocks) == 1, f"expected one fused block, got {len(blocks)}"
    fn = lower_op_pallas(blocks[0], interpret=interpret,
                         pipeline_depth=hw.pipeline_depth)

    def call(x, w, b=None):
        arrays = {"X": x, "W": w}
        if has_bias:
            arrays["B"] = b
        return fn(arrays)

    return call


def describe_kernel(m: int, k: int, n: int, dtype: str = "float32") -> str:
    """Pretty-print the optimized IR (for docs/benchmarks)."""
    tp = TileProgram("stripe_matmul")
    tp.input("X", (m, k), dtype)
    tp.input("W", (k, n), dtype)
    tp.output("O", (m, n), dtype)
    tp.op("O[i, j] += X[i, c] * W[c, j]")
    prog = compile_program(tp.build(), get_config("tpu_v5e"))
    return prog.pretty()
