"""Pure-jnp oracle for the Stripe-generated matmul(+epilogue) kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

_ACTS = {
    None: lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0),
    "silu": lambda x: x * (1.0 / (1.0 + jnp.exp(-x))),
    "sigmoid": lambda x: 1.0 / (1.0 + jnp.exp(-x)),
    "tanh": jnp.tanh,
    "square": jnp.square,
}


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
               act: Optional[str] = None) -> jnp.ndarray:
    acc = jnp.einsum("mk,kn->mn", x.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    if act == "gelu":
        import jax

        acc = jax.nn.gelu(acc, approximate=False)
    elif act is not None:
        acc = _ACTS[act](acc)
    return acc.astype(x.dtype)
