"""Jit'd public wrapper for the Stripe-generated matmul kernel."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import build_matmul_kernel
from .ref import matmul_ref


@partial(jax.jit, static_argnames=("act", "interpret"))
def _run(x, w, bias, act, interpret):
    m, k = x.shape
    n = w.shape[-1]
    fn = build_matmul_kernel(m, k, n, str(x.dtype), act, bias is not None, interpret)
    return fn(x, w, bias)


def matmul(x: jnp.ndarray, w: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
           act: Optional[str] = None, interpret: bool = True) -> jnp.ndarray:
    """act(x @ w + bias) via the Stripe-compiled Pallas kernel.

    ``interpret=True`` executes the kernel body on CPU (validation mode);
    on a real TPU pass ``interpret=False``.
    """
    return _run(x, w, bias, act, interpret)


__all__ = ["matmul", "matmul_ref"]
