"""Sequential-recurrence oracle for the chunked gated linear attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gla_ref(q, k, v, log_decay, gain, normalize: bool = True, scale: float = 1.0):
    """Step-by-step recurrence (lax.scan over time)."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    qf = q.reshape(b * h, s, dk).astype(jnp.float32) * scale
    kf = k.reshape(b * h, s, dk).astype(jnp.float32)
    vf = v.reshape(b * h, s, dv).astype(jnp.float32)
    dec = jnp.exp(log_decay.reshape(b * h, s).astype(jnp.float32))
    gn = gain.reshape(b * h, s).astype(jnp.float32)

    def step(carry, xs):
        C, n = carry
        qt, kt, vt, dt, gt = xs
        C = dt[:, None, None] * C + gt[:, None, None] * (kt[:, :, None] * vt[:, None, :])
        n = dt[:, None] * n + gt[:, None] * kt
        h_t = jnp.einsum("bd,bdp->bp", qt, C)
        if normalize:
            denom = jnp.maximum(jnp.abs(jnp.einsum("bd,bd->b", qt, n)), 1.0)
            h_t = h_t / denom[:, None]
        return (C, n), h_t

    C0 = jnp.zeros((b * h, dk, dv), jnp.float32)
    n0 = jnp.zeros((b * h, dk), jnp.float32)
    xs = (
        jnp.moveaxis(qf, 1, 0), jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0),
        jnp.moveaxis(dec, 1, 0), jnp.moveaxis(gn, 1, 0),
    )
    _, hs = jax.lax.scan(step, (C0, n0), xs)
    return jnp.moveaxis(hs, 0, 1).reshape(b, h, s, dv).astype(q.dtype)


def mlstm_ref(q, k, v, i_gate, f_gate):
    dk = q.shape[-1]
    log_decay = jax.nn.log_sigmoid(f_gate)
    gain = jnp.exp(jnp.minimum(i_gate, 8.0))
    return gla_ref(q, k, v, log_decay, gain, normalize=True, scale=float(dk) ** -0.5)
