"""Public wrappers for chunked gated linear attention / mLSTM."""
from .kernel import choose_chunk, chunked_gla, mlstm_chunk
from .ref import gla_ref, mlstm_ref

__all__ = ["chunked_gla", "mlstm_chunk", "gla_ref", "mlstm_ref", "choose_chunk"]
