"""Chunkwise-parallel gated linear attention (TPU Pallas).

One kernel serves both mLSTM (xLSTM) and Mamba2's SSD: both are linear
recurrences

    C_t = decay_t * C_{t-1} + gain_t * k_t v_t^T          (Dk x Dv state)
    n_t = decay_t * n_{t-1} + gain_t * k_t                (normalizer, optional)
    h_t = q_t @ C_t [/ max(|q_t . n_t|, 1)]

evaluated chunk-by-chunk: within a chunk the contribution is a masked
(q k^T)-style matmul (MXU work), across chunks the (Dk, Dv) state is
carried in VMEM scratch along the sequential innermost grid dimension.
This is the nested-polyhedral structure of the paper applied to a
recurrence: the chunk boundary is exactly the aggregation boundary.

Numerics: decays are passed in log space (log_decay <= 0), so every
``exp`` in the chunk math has a non-positive argument — no overflow.  The
xLSTM paper's per-step max-stabilizer is replaced by this chunk-level
log-space form (see DESIGN.md hardware-adaptation notes).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def choose_chunk(seq: int, dk: int, dv: int) -> int:
    """Stripe autotiler chooses the chunk length for the intra-chunk
    contraction H[t,p] += S[t,s] * V[s,p]; memoized through the
    compilation cache so warm processes skip the search."""
    from ...core import cache as stripe_cache
    from ...core.hwconfig import get_config

    hw = get_config("tpu_v5e")
    params = {"cost": "roofline", "search": "pow2", "mem_cap_frac": 0.1}
    memo_version = 1  # bump when the clamp logic below changes

    def search():
        from ...core.frontend import single_op_program
        from ...core.passes.autotile import choose_tiling

        prog = single_op_program(
            "H[t, p] += S[t, s] * V[s, p]",
            {"S": ((seq, seq), "float32"), "V": ((seq, dv), "float32"),
             "H": ((seq, dv), "float32")},
            out="H",
        )
        tiles, _ = choose_tiling(prog.entry.stmts[0], hw, params)
        c = min(tiles.get("t", 256), 256)
        while seq % c != 0:
            c //= 2
        return max(c, 1)

    return int(stripe_cache.memoize(
        "mlstm_chunk_len",
        [memo_version, seq, dk, dv, sorted(params.items()), hw.fingerprint()],
        search))


def _gla_kernel(q_ref, k_ref, v_ref, ld_ref, g_ref, o_ref, c_ref, n_ref, *,
                L: int, normalize: bool, scale: float):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    q = q_ref[0].astype(jnp.float32) * scale       # (L, Dk)
    k = k_ref[0].astype(jnp.float32)               # (L, Dk)
    v = v_ref[0].astype(jnp.float32)               # (L, Dv)
    ld = ld_ref[0, :, 0].astype(jnp.float32)       # (L,) log decay
    g = g_ref[0, :, 0].astype(jnp.float32)         # (L,) gain

    cum = jnp.cumsum(ld)                           # inclusive: cum_t
    # intra-chunk scores: (q_t . k_s) * exp(cum_t - cum_s) * g_s, s<=t
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    # mask *inside* the exp: above the diagonal cum_t - cum_s > 0 can
    # overflow for strong decays (inf * 0 = NaN otherwise)
    dmat = jnp.where(t_idx >= s_idx, cum[:, None] - cum[None, :], -jnp.inf)
    scores = qk * jnp.exp(dmat) * g[None, :]

    c_prev = c_ref[...]                            # (Dk, Dv)
    n_prev = n_ref[...]                            # (Dk, 1)
    h_intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    h_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        q, c_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h = h_intra + h_inter
    if normalize:
        norm = jnp.sum(scores, axis=1, keepdims=True) + jnp.exp(cum)[:, None] * (
            jax.lax.dot_general(q, n_prev, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32))
        h = h / jnp.maximum(jnp.abs(norm), 1.0)
    o_ref[0] = h.astype(o_ref.dtype)

    # ---- state update ------------------------------------------------------
    total = cum[L - 1]
    w = jnp.exp(total - cum) * g                   # per-step carry weight
    kw = k * w[:, None]
    c_ref[...] = jnp.exp(total) * c_prev + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_ref[...] = jnp.exp(total) * n_prev + jnp.sum(kw, axis=0)[:, None]


@functools.partial(jax.jit, static_argnames=("chunk", "normalize", "scale", "interpret"))
def chunked_gla(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                log_decay: jnp.ndarray, gain: jnp.ndarray,
                chunk: Optional[int] = None, normalize: bool = True,
                scale: float = 1.0, interpret: bool = False) -> jnp.ndarray:
    """q/k: (B, H, S, Dk); v: (B, H, S, Dv); log_decay/gain: (B, H, S).
    Returns (B, H, S, Dv)."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    if chunk is None:
        chunk = choose_chunk(s, dk, dv)
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    qf = q.reshape(b * h, s, dk)
    kf = k.reshape(b * h, s, dk)
    vf = v.reshape(b * h, s, dv)
    ldf = log_decay.reshape(b * h, s, 1)
    gf = gain.reshape(b * h, s, 1)

    kern = functools.partial(_gla_kernel, L=chunk, normalize=normalize, scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(b * h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((dk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, ldf, gf)
    return out.reshape(b, h, s, dv)


def mlstm_chunk(q, k, v, i_gate, f_gate, chunk: Optional[int] = None,
                interpret: bool = False) -> jnp.ndarray:
    """xLSTM mLSTM: decay = sigmoid(f), gain = exp(i) (i pre-clamped),
    normalized output, q scaled by Dk^-1/2."""
    dk = q.shape[-1]
    log_decay = jax.nn.log_sigmoid(f_gate)
    gain = jnp.exp(jnp.minimum(i_gate, 8.0))
    return chunked_gla(q, k, v, log_decay, gain, chunk=chunk, normalize=True,
                       scale=float(dk) ** -0.5, interpret=interpret)
