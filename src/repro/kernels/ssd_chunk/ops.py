"""Public wrappers for the Mamba2 SSD chunk scan."""
from .kernel import ssd_chunk
from .ref import ssd_ref

__all__ = ["ssd_chunk", "ssd_ref"]
