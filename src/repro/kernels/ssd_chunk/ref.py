"""Sequential oracle for the SSD chunk scan."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..mlstm_chunk.ref import gla_ref


def ssd_ref(x, dt, A, B, C, D: Optional[jnp.ndarray] = None):
    log_decay = dt * A[None, :, None]
    y = gla_ref(C, B, x, log_decay, dt, normalize=False, scale=1.0)
    if D is not None:
        y = y + D[None, :, None, None] * x
    return y
