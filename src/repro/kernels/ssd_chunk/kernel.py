"""Mamba2 SSD (state space duality) chunk scan — reuses the chunked gated
linear attention kernel: the SSD recurrence

    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * B_t x_t^T
    y_t = C_t @ S_t  (+ D_h * x_t)

is the un-normalized gated linear attention with q=C, k=B, v=x,
log_decay = dt*A, gain = dt.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..mlstm_chunk.kernel import chunked_gla


def ssd_chunk(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
              B: jnp.ndarray, C: jnp.ndarray, D: Optional[jnp.ndarray] = None,
              chunk: Optional[int] = None, interpret: bool = False) -> jnp.ndarray:
    """x: (Bt, H, S, P); dt: (Bt, H, S) positive; A: (H,) negative;
    B/C: (Bt, H, S, N).  Returns (Bt, H, S, P)."""
    log_decay = dt * A[None, :, None]
    y = chunked_gla(C, B, x, log_decay, dt, chunk=chunk, normalize=False,
                    scale=1.0, interpret=interpret)
    if D is not None:
        y = y + D[None, :, None, None] * x
    return y
