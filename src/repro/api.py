"""The stable public facade.

``from repro import api`` is the supported way to consume this repo;
everything in ``__all__`` below is covered by the compatibility promise,
and all ``examples/`` and ``benchmarks/`` import only through here.  The
deep module paths (``repro.core.driver``, ``repro.serving.engine``, …)
remain importable as thin compatibility aliases of the same objects, but
they are internals: they may move between minor versions, this module
may not.

Blessed surface
---------------
Compile:
    ``jit`` (= ``stripe_jit``), ``compile`` (= ``compile_cached``),
    ``TileProgram``, ``single_op_program``, ``CompiledProgram``,
    ``execute_reference``, ``validate_program``, ``lower_program_jnp``,
    ``compile_program``, ``get_pass``, ``split_block``, ``choose_tiling``,
    ``evaluate_tiling``, ``score_pass_trace``
Hardware & model configs:
    ``get_config`` (hardware registry), ``HW_REGISTRY``,
    ``HardwareConfig``, ``configs`` (architecture registry:
    ``configs.get(name)``), ``build_model``, ``make_batch``
Caching:
    ``CompilationCache``, ``get_default_cache``, ``set_default_cache``
Serving:
    ``ServingEngine``, ``WaveEngine``, ``Request``, ``SamplingParams``,
    ``EngineConfig``
Exploration:
    ``explore`` (subpackage: ``run_sweep``, ``get_space``,
    ``pareto_front``, ``dominating_baseline``, …), ``get_workloads``,
    ``roofline_hillclimb``
Kernels & training (convenience):
    ``matmul``, ``matmul_ref``, ``choose_block_sizes``, ``adamw``,
    ``TrainConfig``, ``Trainer``, ``DataConfig``
Reliability:
    ``faults`` (fault-injection module: ``faults.inject``,
    ``faults.fail_nth``, …), ``FaultPlan``, ``InjectedFault``
Observability:
    ``obs`` (subpackage: ``obs.span``, ``obs.enable_tracing``,
    ``obs.export_chrome_trace``, ``obs.metrics_snapshot``,
    ``obs.read_residuals``, …)
Autotuning:
    ``tune`` (subpackage), ``TuningDB``, ``compile_with_tilings``,
    ``fit_calibration``, ``set_calibration``, ``measure_interleaved``
"""
from __future__ import annotations

from . import configs, explore, obs, tune
from .core import (
    CompilationCache,
    CompiledProgram,
    TileProgram,
    compile_cached,
    execute_reference,
    get_default_cache,
    lower_program_jnp,
    set_default_cache,
    single_op_program,
    stripe_jit,
    validate_program,
)
from .core.driver import compile_with_tilings
from .core.cost import evaluate_tiling, score_pass_trace
from .core.hwconfig import REGISTRY as HW_REGISTRY
from .core.hwconfig import HardwareConfig, get_config
from .core.passes import compile_program, get_pass
from .core.passes.autotile import choose_tiling
from .core.tiling import split_block
from .data.pipeline import DataConfig
from .explore import dominating_baseline, get_space, pareto_front, run_sweep
from .explore.hillclimb import roofline_hillclimb
from .explore.workloads import get_workloads
from .kernels.flash_attention.ops import choose_block_sizes
from .kernels.stripe_matmul.ops import matmul, matmul_ref
from .models.build import build_model, make_batch
from .optim import adamw
from .reliability import FaultPlan, InjectedFault, faults
from .serving import EngineConfig, Request, SamplingParams, ServingEngine, WaveEngine
from .train.loop import TrainConfig, Trainer
from .tune import (
    TuningDB,
    fit_calibration,
    measure_interleaved,
    set_calibration,
)
# Multi-device: ``api.jit(..., mesh=)`` accepts a device count, a mesh
# shape tuple, or an ``api.Mesh`` (= ``jax.sharding.Mesh``).
from jax.sharding import Mesh

# The two headline verbs, under their public names.
jit = stripe_jit
compile = compile_cached  # noqa: A001 - deliberate: api.compile, never bare

__all__ = [
    # compile
    "jit", "compile", "stripe_jit", "compile_cached", "TileProgram",
    "single_op_program", "CompiledProgram", "execute_reference",
    "validate_program", "lower_program_jnp", "compile_program", "get_pass",
    "split_block", "choose_tiling", "evaluate_tiling", "score_pass_trace",
    # configs
    "get_config", "HW_REGISTRY", "HardwareConfig", "configs", "Mesh",
    "build_model", "make_batch",
    # caching
    "CompilationCache", "get_default_cache", "set_default_cache",
    # serving
    "ServingEngine", "WaveEngine", "Request", "SamplingParams", "EngineConfig",
    # exploration
    "explore", "get_workloads", "roofline_hillclimb", "run_sweep",
    "get_space", "pareto_front", "dominating_baseline",
    # kernels & training
    "matmul", "matmul_ref", "choose_block_sizes", "adamw",
    "TrainConfig", "Trainer", "DataConfig",
    # reliability
    "faults", "FaultPlan", "InjectedFault",
    # observability
    "obs",
    # autotuning
    "tune", "TuningDB", "compile_with_tilings", "fit_calibration",
    "set_calibration", "measure_interleaved",
]
