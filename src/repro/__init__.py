"""repro: Stripe (Nested Polyhedral Model) tensor compiler + multi-pod JAX
training/serving framework.  See README.md / DESIGN.md."""
