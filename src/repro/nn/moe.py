"""Mixture-of-Experts layer: top-k routing with capacity-bounded
scatter/gather dispatch (Switch-style) — expert weights are stacked on a
leading expert axis so EP shards them over the ``model`` mesh axis."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .core import Params, dense_init


def moe_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    e, f = cfg.moe.n_experts, cfg.moe.d_ff_expert
    ks = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(d)
    fscale = 1.0 / np.sqrt(f)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * fscale).astype(dtype),
    }


MOE_EXPERT_MAJOR = True


def moe_apply(p: Params, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D).  Returns (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    t = b * s
    cap = int(np.ceil(cfg.moe.capacity_factor * t * k / e))
    cap = max(cap, 4)

    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)           # (t, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch): E * sum(frac_tokens * frac_prob)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    # position of each (token, choice) within its expert's capacity
    eid = gate_idx.reshape(-1)                               # (t*k,)
    onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)         # (t*k, e)
    pos = jnp.cumsum(onehot, axis=0) - 1                     # running count
    pos_in_e = jnp.take_along_axis(pos, eid[:, None], axis=1)[:, 0]
    keep = pos_in_e < cap
    slot = jnp.where(keep, eid * cap + pos_in_e, e * cap)    # overflow slot

    # scatter tokens into (e*cap+1, d), compute experts, gather back
    from ..parallel.constrain import constrain

    src = jnp.repeat(xt, k, axis=0)                          # (t*k, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].add(src * keep[:, None].astype(x.dtype))
    h = buf[: e * cap].reshape(e, cap, d)
    # EP: keep expert-major tensors sharded on 'model' so the expert FFN
    # einsums stay local (the dispatch becomes an all-to-all instead of
    # GSPMD all-gathering the expert weights -- see EXPERIMENTS.md §Perf)
    h = constrain(h, "model", "data", None) if MOE_EXPERT_MAJOR else h
    a = cfg.act.split("_")[0] if cfg.act.endswith("_glu") else None
    if cfg.act.endswith("_glu"):
        act_fn = jax.nn.silu if a == "silu" else (lambda z: jax.nn.gelu(z, approximate=True))
        g = act_fn(jnp.einsum("ecd,edf->ecf", h, p["w_gate"]))
        u = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
        o = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])
    else:
        u = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", h, p["w_up"])))
        o = jnp.einsum("ecf,efd->ecd", u, p["w_down"])
    o = constrain(o, "model", "data", None) if MOE_EXPERT_MAJOR else o
    flat = jnp.concatenate([o.reshape(e * cap, d), jnp.zeros((1, d), o.dtype)], axis=0)
    # ---- combine: weight in expert-major layout, then ONE scatter-add back
    # to token-major (t, d).  (The naive flat[slot] gather materializes a
    # replicated (t*k, d) f32 tensor that GSPMD all-reduces — 103 GB/chip
    # on dbrx prefill; see EXPERIMENTS.md §Perf iteration 1.)
    w_buf = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].add(
        gate_vals.reshape(-1) * keep)
    ow = flat * w_buf[:, None].astype(flat.dtype)            # (e*cap+1, d)
    tok_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)  # (t*k,)
    tok_of_slot = (
        jnp.full((e * cap + 1,), -1, jnp.int32).at[slot].max(jnp.where(keep, tok_ids, -1))
    )
    dest = jnp.where(tok_of_slot >= 0, tok_of_slot, t)       # sink row for empty
    out = jnp.zeros((t + 1, d), flat.dtype).at[dest].add(ow)[:t]
    out = constrain(out, ("data",), None)
    return out.reshape(b, s, d), aux
