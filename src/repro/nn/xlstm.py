"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential scan with exponential-gate stabilization)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .core import Params, causal_conv1d, dense_init, linear
from .scan_ops import chunked_gla_jnp, gla_decode_step


# ---------------------------------------------------------------- mLSTM
def mlstm_dims(cfg):
    inner = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    nh = cfg.xlstm.n_heads
    hd = inner // nh
    return inner, nh, hd


def mlstm_block_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    inner, nh, hd = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(ks[0], d, 2 * inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.xlstm.conv_width, inner), jnp.float32) * 0.2).astype(dtype),
        "wq": dense_init(ks[2], inner, inner, dtype),
        "wk": dense_init(ks[3], inner, inner, dtype),
        "wv": dense_init(ks[4], inner, inner, dtype),
        "w_igate": dense_init(ks[5], inner, nh, jnp.float32, scale=0.01),
        "w_fgate": dense_init(ks[6], inner, nh, jnp.float32, scale=0.01),
        "b_igate": jnp.zeros((nh,), jnp.float32),
        "b_fgate": jnp.full((nh,), 3.0, jnp.float32),  # init: mostly remember
        "skip_scale": jnp.ones((inner,), dtype),
        "down_proj": dense_init(ks[7], inner, d, dtype),
    }


def mlstm_block_apply(p: Params, x: jnp.ndarray, cfg, chunk: int = 256,
                      state: Optional[Dict[str, jnp.ndarray]] = None):
    b, s, d = x.shape
    inner, nh, hd = mlstm_dims(cfg)
    up = linear(x, p["up_proj"])
    xin, z = jnp.split(up, 2, axis=-1)

    conv_state = state["conv"] if state is not None else None
    cx, new_conv = causal_conv1d(xin, p["conv_w"], conv_state)
    cx = jax.nn.silu(cx)

    q = linear(cx, p["wq"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    k = linear(cx, p["wk"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    v = linear(xin, p["wv"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    ig = (jnp.einsum("bsi,ih->bsh", cx.astype(jnp.float32), p["w_igate"]) + p["b_igate"]).transpose(0, 2, 1)
    fg = (jnp.einsum("bsi,ih->bsh", cx.astype(jnp.float32), p["w_fgate"]) + p["b_fgate"]).transpose(0, 2, 1)
    log_decay = jax.nn.log_sigmoid(fg)
    gain = jnp.exp(jnp.minimum(ig, 8.0))
    scale = float(hd) ** -0.5

    new_state = None
    if state is None or s > 1:
        h = chunked_gla_jnp(q, k, v, log_decay, gain, chunk=chunk, normalize=True, scale=scale)
        if state is not None:
            from .ssm import _final_state

            _, st = _final_state(q, k, v, log_decay, gain)
            new_state = {"conv": new_conv, "C": st[0], "n": st[1]}
    else:
        h, st = gla_decode_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                log_decay[:, :, 0], gain[:, :, 0],
                                (state["C"], state["n"]), normalize=True, scale=scale)
        h = h[:, :, None, :]
        new_state = {"conv": new_conv, "C": st[0], "n": st[1]}

    h = h.transpose(0, 2, 1, 3).reshape(b, s, inner)
    h = h + p["skip_scale"] * cx
    h = h * jax.nn.silu(z)
    return linear(h, p["down_proj"]), new_state


def mlstm_init_state(cfg, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    inner, nh, hd = mlstm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.xlstm.conv_width - 1, inner), dtype),
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
    }


# ---------------------------------------------------------------- sLSTM
def slstm_block_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    nh = cfg.xlstm.n_heads
    hd = d // nh
    pf = cfg.xlstm.proj_factor_slstm
    dff = int(pf * d)
    ks = jax.random.split(key, 7)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dtype),            # i,f,z,o
        "r_gates": (jax.random.normal(ks[1], (nh, hd, 4 * hd), jnp.float32) / np.sqrt(hd)).astype(dtype),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "w_up": dense_init(ks[2], d, 2 * dff, dtype),
        "w_down": dense_init(ks[3], dff, d, dtype),
    }


def slstm_block_apply(p: Params, x: jnp.ndarray, cfg,
                      state: Optional[Dict[str, jnp.ndarray]] = None):
    """Sequential sLSTM with exponential gating and max-stabilizer."""
    b, s, d = x.shape
    nh = cfg.xlstm.n_heads
    hd = d // nh
    wx = (linear(x, p["w_gates"]) + p["b_gates"]).astype(jnp.float32)  # (b,s,4d)
    wx = wx.reshape(b, s, 4, nh, hd)

    if state is None:
        h0 = jnp.zeros((b, nh, hd), jnp.float32)
        c0 = jnp.zeros((b, nh, hd), jnp.float32)
        n0 = jnp.ones((b, nh, hd), jnp.float32)
        m0 = jnp.zeros((b, nh, hd), jnp.float32)
    else:
        h0, c0, n0, m0 = state["h"], state["c"], state["n"], state["m"]

    r = p["r_gates"].astype(jnp.float32)  # (nh, hd, 4hd)

    def step(carry, wx_t):
        h, c, n, m = carry
        rec = jnp.einsum("bhd,hdk->bhk", h, r).reshape(b, nh, 4, hd).transpose(0, 2, 1, 3)
        g = wx_t + rec                       # (b,4,nh,hd)
        gi, gf, gz, go = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        logf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(logf + m, gi)
        i_p = jnp.exp(gi - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c_new = f_p * c + i_p * jnp.tanh(gz)
        n_new = f_p * n + i_p
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1.0)
        return (h_new, c_new, n_new, m_new), h_new

    wxs = jnp.moveaxis(wx, 1, 0)  # (s,b,4,nh,hd)
    (hT, cT, nT, mT), hs = jax.lax.scan(step, (h0, c0, n0, m0), wxs)
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)

    # GLU FFN (proj factor 4/3)
    up = linear(h, p["w_up"])
    a, g2 = jnp.split(up, 2, axis=-1)
    out = linear(jax.nn.gelu(a, approximate=True) * g2, p["w_down"])
    new_state = {"h": hT, "c": cT, "n": nT, "m": mT} if state is not None else None
    return out, new_state


def slstm_init_state(cfg, batch: int) -> Dict[str, jnp.ndarray]:
    nh = cfg.xlstm.n_heads
    hd = cfg.d_model // nh
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"h": z, "c": z, "n": jnp.ones_like(z), "m": z}
