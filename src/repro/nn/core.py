"""Core NN building blocks (pure-functional JAX, params = pytrees).

All dense projections route through ``repro.core.oplib.linear`` — the
Stripe-compiled op layer (einsum on the jnp backend so GSPMD shards it;
the Stripe-generated Pallas kernel on TPU backends).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import oplib

Params = Dict[str, Any]


def param_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else (1.0 / np.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def linear(x: jnp.ndarray, w: jnp.ndarray, bias: Optional[jnp.ndarray] = None,
           act: Optional[str] = None) -> jnp.ndarray:
    if oplib.get_backend() == "jnp":
        out = jnp.einsum("...k,kn->...n", x, w)
        if bias is not None:
            out = out + bias
        if act is not None:
            out = _ACT[act](out)
        return out
    return oplib.linear(x, w, bias, act)


_ACT = {
    "relu": jax.nn.relu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


# ---------------------------------------------------------------- norms
def norm_init(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        nrm = jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
        out = xf * nrm * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_head_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """qk-norm: RMS over the head dim."""
    xf = x.astype(jnp.float32)
    nrm = jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    return (xf * nrm * scale.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- RoPE
def rope_freqs(hd_rot: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd_rot, 2, dtype=jnp.float32) / hd_rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, mode: str, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S) int32.  mode: full|half|none."""
    if mode == "none":
        return x
    hd = x.shape[-1]
    rot = hd if mode == "full" else hd // 2
    freqs = rope_freqs(rot, theta)  # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., : rot // 2], x_rot[..., rot // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = xf1 * cos - xf2 * sin
    r2 = xf2 * cos + xf1 * sin
    out = jnp.concatenate([r1.astype(x.dtype), r2.astype(x.dtype), x_pass], axis=-1)
    return out


# ------------------------------------------------------------------ MLP
def mlp_init(key, d: int, d_ff: int, act: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if act.endswith("_glu"):
        return {
            "w_gate": dense_init(k1, d, d_ff, dtype),
            "w_up": dense_init(k2, d, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d, dtype),
        }
    return {"w_up": dense_init(k1, d, d_ff, dtype), "w_down": dense_init(k2, d_ff, d, dtype)}


def mlp_apply(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act.endswith("_glu"):
        a = act.split("_")[0]
        g = linear(x, p["w_gate"], act=a)
        u = linear(x, p["w_up"])
        return linear(g * u, p["w_down"])
    h = linear(x, p["w_up"], act=act)
    return linear(h, p["w_down"])


# ----------------------------------------------------------- embeddings
def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jnp.ndarray, table_or_w: jnp.ndarray, tied: bool) -> jnp.ndarray:
    if tied:
        return jnp.einsum("...d,vd->...v", x, table_or_w)
    return jnp.einsum("...d,dv->...v", x, table_or_w)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray, real_vocab: int) -> jnp.ndarray:
    """Mean token cross-entropy; logits over the padded vocab are masked."""
    lf = logits.astype(jnp.float32)
    pad = lf.shape[-1] - real_vocab
    if pad > 0:
        neg = jnp.full((pad,), -1e30, jnp.float32)
        lf = lf.at[..., real_vocab:].set(neg)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ----------------------------------------------------- causal conv (ssm)
def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, state: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv.  x: (B, S, C); w: (W, C).  Returns (y, new
    state (B, W-1, C))."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    parts = [xp[:, i : i + x.shape[1], :] * w[i] for i in range(W)]
    y = sum(parts)
    new_state = xp[:, -(W - 1):, :] if W > 1 else state
    return y, new_state
