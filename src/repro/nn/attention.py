"""GQA attention with RoPE variants, qk-norm, KV caches, cross-attention,
and selectable implementation (XLA einsum or the Pallas flash kernel)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .core import Params, apply_rope, dense_init, linear, rms_head_norm

NEG_INF = -1e30


def attn_init(key, cfg, dtype, cross: bool = False) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _split_heads(x: jnp.ndarray, n: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q: (B,S,H,hd), k: (B,T,KV,hd) -> (B,H,S,T) without materializing the
    repeated KV heads (grouped einsum)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    out = jnp.einsum("bskgd,btkd->bkgst", qg, k)
    return out.reshape(b, h, s, k.shape[1])


def _gqa_values(p: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """p: (B,H,S,T), v: (B,T,KV,hd) -> (B,S,H,hd)."""
    b, h, s, t = p.shape
    kvh = v.shape[2]
    g = h // kvh
    pg = p.reshape(b, kvh, g, s, t)
    out = jnp.einsum("bkgst,btkd->bskgd", pg, v)
    return out.reshape(b, s, h, out.shape[-1])


def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
        mask: Optional[jnp.ndarray], sm_scale: float) -> jnp.ndarray:
    """Reference attention used for training.  q: (B,S,H,hd); k/v:
    (B,T,KV,hd); mask broadcastable to (B,1,S,T) (True = attend)."""
    scores = _gqa_scores(q.astype(jnp.float32), k.astype(jnp.float32)) * sm_scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = _gqa_values(p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def causal_mask(s: int, dtype=bool) -> jnp.ndarray:
    return jnp.tril(jnp.ones((s, s), bool))[None, None]


def attention(p: Params, x: jnp.ndarray, cfg, *,
              positions: Optional[jnp.ndarray] = None,
              mask: Optional[jnp.ndarray] = None,
              causal: bool = True,
              cache: Optional[Dict[str, jnp.ndarray]] = None,
              memory: Optional[jnp.ndarray] = None,
              impl: str = "xla") -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Self- or cross-attention.

    * training/prefill: ``cache=None`` (or fresh) — full sequence.
    * decode: ``cache`` holds (k, v, pos); x is (B, 1, D).
    * cross-attention: ``memory`` is the encoder output; k/v come from it.
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    sm_scale = 1.0 / np.sqrt(hd)

    q = _split_heads(linear(x, p["wq"]), h)
    kv_src = memory if memory is not None else x
    k = _split_heads(linear(kv_src, p["wk"]), kvh)
    v = _split_heads(linear(kv_src, p["wv"]), kvh)

    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])

    if memory is None:  # self-attention: rope + cache
        if positions is None:
            if cache is not None and "pos" in cache:
                positions = cache["pos"] + jnp.arange(s, dtype=jnp.int32)[None]
            else:
                positions = jnp.arange(s, dtype=jnp.int32)[None]
        q = apply_rope(q, positions, cfg.rope, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope, cfg.rope_theta)

        if cache is not None:
            pos = cache["pos"]  # scalar int32: current length
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            t = ck.shape[1]
            kpos = jnp.arange(t, dtype=jnp.int32)
            valid = kpos[None, None, None, :] < (pos + s)
            if causal and s > 1:
                qpos = pos + jnp.arange(s, dtype=jnp.int32)
                valid = valid & (kpos[None, None, None, :] <= qpos[None, None, :, None])
            out = mha(q, ck, cv, valid, sm_scale)
            new_cache = {"k": ck, "v": cv, "pos": pos + s}
            return linear(out.reshape(b, s, h * hd), p["wo"]), new_cache

        m = mask
        if causal and m is None:
            m = causal_mask(s)
        out = mha(q, k, v, m, sm_scale)
        return linear(out.reshape(b, s, h * hd), p["wo"]), None

    # cross attention (no rope on kv, no cache mutation needed beyond reuse)
    out = mha(q, k, v, mask, sm_scale)
    return linear(out.reshape(b, s, h * hd), p["wo"]), None


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> Dict[str, jnp.ndarray]:
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
