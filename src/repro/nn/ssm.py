"""Mamba2 (SSD) block with train (chunked), prefill, and single-step
decode paths."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .core import Params, causal_conv1d, dense_init, linear
from .scan_ops import chunked_gla_jnp, gla_decode_step


def mamba2_dims(cfg):
    inner = cfg.ssm.expand * cfg.d_model
    n_heads = inner // cfg.ssm.head_dim
    return inner, n_heads, cfg.ssm.d_state


def mamba2_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    inner, nh, ns = mamba2_dims(cfg)
    conv_ch = inner + 2 * ns
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], d, 2 * inner + 2 * ns + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm.conv_width, conv_ch), jnp.float32) * 0.2).astype(dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": dense_init(ks[2], inner, d, dtype),
        "norm_scale": jnp.ones((inner,), dtype),
    }


def _gated_rmsnorm(x, z, scale, eps=1e-6):
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    nrm = jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    return (xf * nrm * scale.astype(jnp.float32)).astype(z.dtype)


def _project(p, x, cfg):
    inner, nh, ns = mamba2_dims(cfg)
    zxbcdt = linear(x, p["in_proj"])
    z, xin, B, C, dt = jnp.split(zxbcdt, [inner, 2 * inner, 2 * inner + ns, 2 * inner + 2 * ns], axis=-1)
    return z, xin, B, C, dt


def mamba2_apply(p: Params, x: jnp.ndarray, cfg, chunk: int = 256,
                 state: Optional[Dict[str, jnp.ndarray]] = None
                 ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """x: (B, S, D).  With ``state`` given, updates it (prefill->decode)."""
    b, s, d = x.shape
    inner, nh, ns = mamba2_dims(cfg)
    hd = cfg.ssm.head_dim
    z, xin, B, C, dt = _project(p, x, cfg)

    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = causal_conv1d(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, B, C = jnp.split(conv_out, [inner, inner + ns], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                           # (nh,)

    xh = xin.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)               # (B,nh,S,hd)
    Bh = jnp.broadcast_to(B[:, None], (b, nh, s, ns))
    Ch = jnp.broadcast_to(C[:, None], (b, nh, s, ns))
    dth = dt.transpose(0, 2, 1)                                        # (B,nh,S)
    log_decay = dth * A[None, :, None]

    if state is None or s > 1:
        y = chunked_gla_jnp(Ch, Bh, xh, log_decay, dth, chunk=chunk, normalize=False)
        new_ssm = None
        if state is not None:
            # prefill: also materialize the final state via a scan pass
            _, st = _final_state(Ch, Bh, xh, log_decay, dth)
            new_ssm = st
    else:
        y, st = gla_decode_step(
            Ch[:, :, 0], Bh[:, :, 0], xh[:, :, 0], log_decay[:, :, 0], dth[:, :, 0],
            (state["C"], state["n"]), normalize=False)
        y = y[:, :, None, :]
        new_ssm = (state["C"] * 0 + st[0], st[1])

    y = (y + p["D"][None, :, None, None] * xh).astype(x.dtype)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, inner)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = linear(y, p["out_proj"])
    if state is None:
        return out, None
    new_state = {"conv": new_conv, "C": new_ssm[0], "n": new_ssm[1]}
    return out, new_state


def _final_state(q, k, v, log_decay, gain):
    """Compute the end-of-sequence recurrent state (for prefill)."""
    b, h, s, dk = k.shape
    dv = v.shape[-1]
    cum = jnp.cumsum(log_decay.astype(jnp.float32), axis=-1)
    total = cum[..., -1]
    w = jnp.exp(total[..., None] - cum) * gain
    kw = k.astype(jnp.float32) * w[..., None]
    C = jnp.einsum("bhsd,bhsp->bhdp", kw, v.astype(jnp.float32))
    n = jnp.sum(kw, axis=2)
    return None, (C, n)


def mamba2_init_state(cfg, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    inner, nh, ns = mamba2_dims(cfg)
    conv_ch = inner + 2 * ns
    return {
        "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, conv_ch), dtype),
        "C": jnp.zeros((batch, nh, ns, cfg.ssm.head_dim), jnp.float32),
        "n": jnp.zeros((batch, nh, ns), jnp.float32),
    }
