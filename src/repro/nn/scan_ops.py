"""Differentiable chunked gated-linear-attention in pure jnp (scan over
chunks) — the training-path twin of the mlstm_chunk/ssd_chunk Pallas
kernels (identical math; validated against the same sequential oracle)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def chunked_gla_jnp(q, k, v, log_decay, gain, chunk: int = 256,
                    normalize: bool = True, scale: float = 1.0) -> jnp.ndarray:
    """q/k: (B,H,S,Dk); v: (B,H,S,Dv); log_decay/gain: (B,H,S)."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    n = s // chunk
    bh = b * h

    def seg(x, dlast):
        return x.reshape(bh, n, chunk, dlast).swapaxes(0, 1)  # (n, bh, L, d)

    qs = seg(q.astype(jnp.float32) * scale, dk)
    ks = seg(k.astype(jnp.float32), dk)
    vs = seg(v.astype(jnp.float32), dv)
    lds = log_decay.reshape(bh, n, chunk).swapaxes(0, 1).astype(jnp.float32)
    gs = gain.reshape(bh, n, chunk).swapaxes(0, 1).astype(jnp.float32)

    tril = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def step(carry, xs):
        C, nvec = carry                      # (bh, dk, dv), (bh, dk)
        qc, kc, vc, ldc, gc = xs
        cum = jnp.cumsum(ldc, axis=-1)       # (bh, L)
        # mask inside the exp (upper triangle would overflow: inf*0=NaN)
        dmat = jnp.where(tril[None] > 0, cum[:, :, None] - cum[:, None, :], -jnp.inf)
        scores = jnp.einsum("btd,bsd->bts", qc, kc) * jnp.exp(dmat) * gc[:, None, :]
        h_intra = jnp.einsum("bts,bsp->btp", scores, vc)
        ecum = jnp.exp(cum)
        h_inter = ecum[:, :, None] * jnp.einsum("btd,bdp->btp", qc, C)
        out = h_intra + h_inter
        if normalize:
            norm = jnp.sum(scores, axis=-1) + ecum * jnp.einsum("btd,bd->bt", qc, nvec)
            out = out / jnp.maximum(jnp.abs(norm), 1.0)[..., None]
        total = cum[:, -1]
        w = jnp.exp(total[:, None] - cum) * gc
        kw = kc * w[..., None]
        C = jnp.exp(total)[:, None, None] * C + jnp.einsum("bsd,bsp->bdp", kw, vc)
        nvec = jnp.exp(total)[:, None] * nvec + jnp.sum(kw, axis=1)
        return (C, nvec), out

    C0 = jnp.zeros((bh, dk, dv), jnp.float32)
    n0 = jnp.zeros((bh, dk), jnp.float32)
    (_, _), outs = jax.lax.scan(step, (C0, n0), (qs, ks, vs, lds, gs))
    out = outs.swapaxes(0, 1).reshape(b, h, s, dv)
    return out.astype(q.dtype)


def gla_decode_step(q, k, v, log_decay, gain, state: Tuple[jnp.ndarray, jnp.ndarray],
                    normalize: bool = True, scale: float = 1.0):
    """Single-token state update.  q/k: (B,H,Dk); v: (B,H,Dv);
    log_decay/gain: (B,H); state: (C (B,H,Dk,Dv), n (B,H,Dk))."""
    C, nvec = state
    dec = jnp.exp(log_decay.astype(jnp.float32))[..., None, None]
    g = gain.astype(jnp.float32)[..., None, None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = dec * C + g * (kf[..., :, None] * vf[..., None, :])
    nvec = dec[..., 0] * nvec + g[..., 0] * kf
    qf = q.astype(jnp.float32) * scale
    out = jnp.einsum("bhd,bhdp->bhp", qf, C)
    if normalize:
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, nvec)), 1.0)
        out = out / denom[..., None]
    return out.astype(q.dtype), (C, nvec)
