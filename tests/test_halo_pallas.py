"""Halo-aware Pallas lowering + per-block hybrid fallback.

* the paper's Fig. 4/5 conv lowers to real ``pallas_call`` kernels
  (halo views over materialized operands, constraints as masked stores)
  and matches the reference interpreter — bit-exact for the int8 Fig. 4
  program;
* interior + boundary pieces partition the iteration space exactly
  (hypothesis property over random conv shapes, reference-interpreter
  equality), and the ``boundary`` pass splits *every* constraint-carrying
  grid axis under the per-index budget;
* a non-dividing tile's boundary remainder takes the masked-store path
  while the interior piece lowers densely;
* a program containing one unsupported block keeps its other groups as
  Pallas kernels, with per-unit backend + fallback reason on the
  ``CompileRecord``.
"""
import copy

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import TileProgram, execute_reference, stripe_jit
from repro.core.frontend import single_op_program
from repro.core.hwconfig import get_config
from repro.core.ir import Program
from repro.core.lower_pallas import lower_program_hybrid
from repro.core.passes.boundary import split_boundary, _n_constraints
from repro.core.tiling import split_block


def _conv_prog(x, y, c, k, f, dtype="float32", name="conv"):
    pad = f // 2
    return single_op_program(
        f"O[x, y, k] += I[x + i - {pad}, y + j - {pad}, c] * F[i, j, c, k]",
        {"I": ((x, y, c), dtype), "F": ((f, f, c, k), dtype),
         "O": ((x, y, k), dtype if dtype != "int8" else "int32")},
        out="O", name=name)


def _conv_inputs(prog, seed=0):
    rng = np.random.RandomState(seed)
    out = {}
    for n in prog.inputs:
        d = prog.buffers[n]
        if d.dtype == "int8":
            out[n] = rng.randint(-4, 5, d.shape).astype(np.int8)
        else:
            out[n] = rng.randn(*d.shape).astype(np.float32)
    return out


# --------------------------------------------------------------- fig4 / fig5
def test_fig5_conv_lowers_to_pallas_and_matches_reference():
    """The acceptance bar: the paper's conv runs as real pallas_calls (no
    whole-program fallback) and pallas-interpret output matches the
    reference interpreter."""
    from repro.explore.workloads import fig5_conv_f32

    prog = fig5_conv_f32()
    src = copy.deepcopy(prog)
    compiled = stripe_jit(prog, get_config("tpu_v5e"), backend="pallas",
                          interpret=True, use_disk=False)
    rec = compiled.record
    assert rec.backend == "pallas", rec.fallback_reason
    assert rec.n_kernels >= 1
    assert set(rec.block_backends.values()) == {"pallas"}
    assert rec.fallback_reasons() == {}
    ins = _conv_inputs(src)
    got = np.asarray(compiled(ins)["O"])
    want = execute_reference(src, ins)["O"]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fig4_conv_int8_is_bit_exact():
    from repro.explore.workloads import fig4_conv

    prog = fig4_conv()
    src = copy.deepcopy(prog)
    compiled = stripe_jit(prog, get_config("tpu_v5e"), backend="pallas",
                          interpret=True, use_disk=False)
    assert compiled.record.backend == "pallas", compiled.record.fallback_reason
    assert compiled.record.n_kernels >= 1
    ins = _conv_inputs(src, 1)
    got = np.asarray(compiled(ins)["O"])
    want = execute_reference(src, ins)["O"]
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------- partition properties
@settings(max_examples=8, deadline=None)
@given(st.integers(5, 10), st.integers(4, 9), st.integers(1, 2),
       st.integers(1, 2), st.sampled_from([2, 3]), st.integers(2, 4),
       st.sampled_from(["remainder", "edges"]))
def test_boundary_pieces_partition_conv_iteration_space(
        x, y, c, k, f, tile, mode):
    """Interior + boundary pieces partition the iteration space exactly:
    executing the piece list reproduces the unsplit conv on random
    shapes/filters/tiles (non-dividing tiles included)."""
    prog = _conv_prog(x, y, c, k, f)
    src = copy.deepcopy(prog)
    blk = prog.entry.stmts[0]
    outer = split_block(blk, {"x": tile, "y": tile})
    pieces = split_boundary(outer, mode=mode, max_splits=4)
    prog.entry.stmts = list(pieces)
    ins = _conv_inputs(src, seed=x * 100 + y * 10 + f)
    want = execute_reference(src, ins)["O"]
    got = execute_reference(prog, ins)["O"]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # piece names are deterministic segment-start keys
    assert len({p.name for p in pieces}) == len(pieces)


def test_per_index_budget_splits_both_conv_axes():
    """The old global max_splits budget could starve later indices; the
    per-index budget splits every constraint-carrying grid axis, yielding
    a constraint-free (tagged) interior on a 2-D-tiled conv."""
    prog = _conv_prog(32, 32, 2, 2, 3, name="conv2d")
    blk = prog.entry.stmts[0]
    outer = split_block(blk, {"x": 8, "y": 8})
    pieces = split_boundary(outer, mode="edges", max_splits=4)
    split_axes = {seg[0] for p in pieces for seg in p.name.split(".")
                  if len(seg) > 1 and seg[0] in "xy" and seg[1:].isdigit()}
    assert {"x", "y"} <= split_axes
    interior = [p for p in pieces if "interior" in p.tags]
    assert interior, "no constraint-free interior piece"
    assert all(_n_constraints(p) == 0 for p in interior)
    for p in pieces:
        assert ("interior" in p.tags) != ("boundary" in p.tags)


def test_masked_remainder_non_dividing_tile():
    """A matmul tiled 8 over m=12: the interior piece lowers densely, the
    overflow remainder takes the masked-store path, and the composed
    kernels reproduce the reference."""
    tp = TileProgram("mmrem")
    tp.input("A", (12, 8))
    tp.input("B", (8, 16))
    tp.output("O", (12, 16))
    tp.op("O[m, n] += A[m, c] * B[c, n]", name="mm")
    prog = tp.build()
    src = copy.deepcopy(prog)
    blk = prog.entry.stmts[0]
    outer = split_block(blk, {"m": 8})  # 12 % 8 != 0 -> overflow constraint
    pieces = split_boundary(outer)
    assert any("interior" in p.tags for p in pieces)
    assert any("boundary" in p.tags for p in pieces)
    prog.entry.stmts = list(pieces)
    prog.source = copy.deepcopy(src)
    fn = lower_program_hybrid(prog, interpret=True)
    assert fn.n_pallas == len(pieces)  # both pieces are real kernels
    ins = {"A": np.random.RandomState(3).randn(12, 8).astype(np.float32),
           "B": np.random.RandomState(4).randn(8, 16).astype(np.float32)}
    got = np.asarray(fn(ins)["O"])
    want = execute_reference(src, ins)["O"]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(4, 8), st.integers(4, 8), st.integers(1, 3),
       st.integers(1, 3), st.sampled_from([2, 3]))
def test_property_conv_pallas_interpret_matches_reference(x, y, c, k, f):
    """End-to-end: random conv shapes through the full tpu_v5e pipeline +
    pallas-interpret equal the reference interpreter."""
    prog = _conv_prog(x, y, c, k, f)
    src = copy.deepcopy(prog)
    compiled = stripe_jit(prog, get_config("tpu_v5e"), backend="pallas",
                          interpret=True, use_disk=False)
    assert compiled.record.backend == "pallas", compiled.record.fallback_reason
    ins = _conv_inputs(src, seed=x * 1000 + y * 100 + c * 10 + f)
    got = np.asarray(compiled(ins)["O"])
    want = execute_reference(src, ins)["O"]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- per-block hybrid
def _mixed_prog():
    tp = TileProgram("mixed")
    tp.input("A", (16, 8))
    tp.input("B", (8, 16))
    tp.temp("T", (16, 16))
    tp.output("O2", (16, 16))
    tp.output("M", (16,))
    tp.op("T[i, j] += A[i, c] * B[c, j]", name="mm")
    tp.op("O2[i, j] = gelu(T[i, j])", name="act")
    tp.op("M[i] max= T[i, j]", name="rowmax")  # max-agg: no Pallas path
    return tp.build()


def test_hybrid_keeps_pallas_kernels_next_to_fallback_block():
    """One unsupported block (max-aggregation) no longer costs the whole
    program its kernels: the other groups stay Pallas and the record
    carries per-unit backend + reason."""
    prog = _mixed_prog()
    src = copy.deepcopy(prog)
    compiled = stripe_jit(prog, get_config("tpu_v5e"), backend="pallas",
                          interpret=True, use_disk=False)
    rec = compiled.record
    assert rec.backend == "pallas"
    assert rec.block_backends["rowmax"] == "jnp"
    pallas_units = [u for u, b in rec.block_backends.items() if b == "pallas"]
    assert pallas_units, rec.block_backends
    assert "rowmax" in rec.fallback_reasons()
    # satellite: BOTH attempted paths' reasons are recorded, not only the
    # contraction error
    reason = rec.fallback_reasons()["rowmax"]
    assert "contraction:" in reason and "windowed:" in reason
    ins = {"A": np.random.RandomState(0).randn(16, 8).astype(np.float32),
           "B": np.random.RandomState(1).randn(8, 16).astype(np.float32)}
    got = compiled(ins)
    want = execute_reference(src, ins)
    for out in ("O2", "M"):
        np.testing.assert_allclose(np.asarray(got[out]), want[out],
                                   rtol=1e-4, atol=1e-5)


def test_two_accumulating_writers_refuse_hybrid_and_aggregate():
    """Two ``+=`` writers into one buffer cannot be composed by region
    placement: the hybrid refuses (whole-program fallback, reason
    recorded) and the jnp path aggregates the second writer's
    contribution with the first instead of clobbering it."""
    tp = TileProgram("twowrite")
    tp.input("A", (8, 4))
    tp.input("B", (4, 8))
    tp.input("C", (8, 4))
    tp.input("D", (4, 8))
    tp.output("O", (8, 8))
    tp.op("O[i, j] += A[i, k] * B[k, j]", name="mm1")
    tp.op("O[i, j] += C[i, k] * D[k, j]", name="mm2")
    prog = tp.build()
    src = copy.deepcopy(prog)
    rng = np.random.RandomState(7)
    ins = {n: rng.randn(*src.buffers[n].shape).astype(np.float32)
           for n in src.inputs}
    want = execute_reference(src, ins)["O"]
    for backend in ("jnp", "pallas"):
        compiled = stripe_jit(copy.deepcopy(src), get_config("tpu_v5e"),
                              backend=backend, interpret=True, use_disk=False)
        assert compiled.record.backend == "jnp"
        np.testing.assert_allclose(np.asarray(compiled(ins)["O"]), want,
                                   rtol=1e-4, atol=1e-5)
    assert "writes to O" in compiled.record.fallback_reason \
        or "write O" in compiled.record.fallback_reason


def test_whole_program_fallback_still_records_reason():
    """When every unit falls back the record degrades to backend=jnp with
    the per-unit reasons surfaced."""
    tp = TileProgram("allmax")
    tp.input("X", (8, 8))
    tp.output("M", (8,))
    tp.op("M[i] max= X[i, j]", name="colmax")
    compiled = stripe_jit(tp.build(), get_config("tpu_v5e"), backend="pallas",
                          interpret=True, use_disk=False)
    rec = compiled.record
    assert rec.backend == "jnp"
    assert rec.block_backends == {"colmax": "jnp"}
    assert "colmax" in rec.fallback_reasons()


def test_memplan_prices_halo_slots():
    """The memory plan classifies a conv's overlapped input as a ``halo``
    slot and prices the margin bytes (slot = tile core + margin)."""
    from repro.core import memplan
    from repro.core.hwconfig import get_config
    from repro.core.passes import PassManager

    prog = _conv_prog(12, 16, 8, 16, 3, name="fig5")
    opt = PassManager(get_config("tpu_v5e")).run(prog)
    grids = [s for s in opt.entry.stmts
             if isinstance(s, type(opt.entry)) and "grid" in s.tags]
    assert grids
    plan = memplan.plan_block(grids[0], depth=2)
    halo_slots = [a for a in plan.allocs if a.view.kind == "halo"]
    assert halo_slots, [a.view.kind for a in plan.allocs]
    assert plan.halo_bytes > 0
    # the conv's I view: (10, 18, 8) extent over an (8, 16, 8) core
    assert any(a.view.halo_bytes == (10 * 18 * 8 - 8 * 18 * 8) * 4
               for a in halo_slots)


def test_autotile_charges_halo_traffic():
    """The roofline model charges halo materialization/refetch bytes, so
    a larger tile along the halo axis amortizes the overlap."""
    from repro.core.cost import evaluate_tiling

    prog = _conv_prog(64, 64, 4, 8, 3, name="conv64")
    blk = prog.entry.stmts[0]
    hw = get_config("tpu_v5e")
    params = dict(hw.passes[1][1])
    small = evaluate_tiling(blk, {"x": 4, "y": 4}, hw, params)
    big = evaluate_tiling(blk, {"x": 16, "y": 16}, hw, params)
    assert small.halo_bytes > big.halo_bytes > 0
    # a non-halo matmul charges nothing
    tp = TileProgram("mm")
    tp.input("A", (64, 64))
    tp.input("B", (64, 64))
    tp.output("O", (64, 64))
    tp.op("O[i, j] += A[i, c] * B[c, j]", name="mm")
    mm = tp.build().entry.stmts[0]
    assert evaluate_tiling(mm, {"i": 16, "j": 16}, hw, params).halo_bytes == 0
