"""Training loop, checkpointing, fault tolerance, data pipeline, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.data.pipeline import DataConfig, DataPipeline, PipelineState, TokenStream, build_token_file
from repro.models.build import build_model
from repro.optim import adamw
from repro.serving.engine import Request, ServingEngine
from repro.train import checkpoint as ckpt
from repro.train.loop import FaultInjector, TrainConfig, Trainer, run_with_restarts


def _tiny_cfg():
    return configs.get("llama3-8b").scaled(n_layers=2, d_model=32, n_heads=2,
                                           n_kv_heads=2, d_ff=64, vocab=64,
                                           head_dim=16, vocab_pad_multiple=16)


def _mk_trainer(tmp, steps=12, ckpt_every=4, seed=0):
    cfg = _tiny_cfg()
    model = build_model(cfg)
    data = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=seed)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    tc = TrainConfig(steps=steps, ckpt_dir=tmp, ckpt_every=ckpt_every, log_every=1)
    return Trainer(model, opt, data, tc)


# ------------------------------------------------------------ training loop
def test_training_reduces_loss(tmp_path):
    tr = _mk_trainer(str(tmp_path / "ck"), steps=30)
    out = tr.run()
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0], f"no learning: {losses[0]} -> {losses[-1]}"
    assert np.isfinite(losses[-1])


def test_fault_recovery_resumes_bit_exact(tmp_path):
    # uninterrupted run
    ref = _mk_trainer(str(tmp_path / "a"), steps=12, ckpt_every=4).run()

    # interrupted at step 6 (after the step-4 checkpoint), then restarted
    fault = FaultInjector(fail_at_step=6)
    out = run_with_restarts(lambda: _mk_trainer(str(tmp_path / "b"), steps=12, ckpt_every=4),
                            fault=fault)
    assert out["restarts"] == 1
    ref_losses = {h["step"]: h["loss"] for h in ref["history"]}
    got_losses = {h["step"]: h["loss"] for h in out["history"]}
    for s in (10, 11, 12):
        np.testing.assert_allclose(got_losses[s], ref_losses[s], rtol=1e-6,
                                   err_msg=f"step {s} diverged after restart")


def test_ambient_fault_plan_triggers_restart(tmp_path):
    # the migrated path: no injector threaded through the call stack —
    # an ambient plan on the train.step site drives the same recovery
    from repro.reliability import faults

    with faults.inject(faults.fail_when("train.step",
                                        lambda ctx: ctx["step"] == 6)) as plan:
        out = run_with_restarts(
            lambda: _mk_trainer(str(tmp_path / "amb"), steps=12, ckpt_every=4))
    assert plan.fired_counts() == {"train.step": 1}
    assert out["restarts"] == 1
    assert out["history"][-1]["step"] == 12


def test_fault_injector_shim_is_one_shot():
    # FaultInjector survives as a compat shim over the faults framework
    fi = FaultInjector(fail_at_step=2)
    assert not fi.fired
    fi.check(1)
    with pytest.raises(RuntimeError):
        fi.check(2)
    assert fi.fired
    fi.check(2)  # one-shot: the same injector never fires twice


def test_checkpoint_atomicity_and_retention(tmp_path):
    d = str(tmp_path / "ck")
    state = {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, state, keep=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2 and steps[-1].endswith(f"{5:010d}")
    assert not any(x.startswith("tmp.") for x in os.listdir(d))
    step, got = ckpt.restore(d, {"params": {"w": np.zeros((2, 3), np.float32)}})
    assert step == 5
    np.testing.assert_array_equal(got["params"]["w"], state["params"]["w"])


def test_checkpoint_elastic_restore_across_meshes(tmp_path):
    """Save unsharded, restore with an explicit (different) sharding."""
    d = str(tmp_path / "ck")
    w = np.random.RandomState(0).randn(8, 4).astype(np.float32)
    ckpt.save(d, 1, {"params": {"w": w}})
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = {"params": {"w": NamedSharding(mesh, P("data", None))}}
    _, got = ckpt.restore(d, {"params": {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}},
                          shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]), w)


# ------------------------------------------------------------ data pipeline
def test_pipeline_determinism_and_restore():
    cfg = DataConfig(vocab=128, seq_len=8, global_batch=4, seed=7)
    p1 = DataPipeline(cfg)
    batches = [p1.next() for _ in range(5)]
    p1.close()

    # restore at step 3 must reproduce batch 3 exactly
    p2 = DataPipeline(cfg, PipelineState(step=3))
    b3 = p2.next()
    p2.close()
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])


def test_pipeline_shards_are_disjoint_streams():
    a = TokenStream(DataConfig(vocab=128, seq_len=8, global_batch=8, n_shards=2, shard_id=0))
    b = TokenStream(DataConfig(vocab=128, seq_len=8, global_batch=8, n_shards=2, shard_id=1))
    ba, bb = a.batch_at(0), b.batch_at(0)
    assert ba["tokens"].shape == (4, 8)
    assert not np.array_equal(ba["tokens"], bb["tokens"])


def test_labels_are_shifted_tokens():
    s = TokenStream(DataConfig(vocab=64, seq_len=8, global_batch=2))
    b = s.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_memmap_dataset(tmp_path):
    path = str(tmp_path / "toks.bin")
    build_token_file(path, 4096, vocab=100, seed=1)
    s = TokenStream(DataConfig(vocab=100, seq_len=16, global_batch=2, kind="memmap", path=path))
    b = s.batch_at(0)
    assert b["tokens"].shape == (2, 16) and b["tokens"].max() < 100
    b2 = s.batch_at(0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50), st.integers(1, 4))
def test_property_pipeline_state_is_pure_function_of_step(step, shards):
    cfg = DataConfig(vocab=64, seq_len=4, global_batch=4 * shards, n_shards=shards, shard_id=0)
    s = TokenStream(cfg)
    np.testing.assert_array_equal(s.batch_at(step)["tokens"], s.batch_at(step)["tokens"])


# ----------------------------------------------------------------- serving
def test_serving_engine_batched_requests():
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, batch_slots=4, max_len=64)
    rng = np.random.RandomState(0)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab, size=5).astype(np.int32),
                    max_new_tokens=6) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    done = eng.run(params, max_steps=64)
    assert len(done) == 6
    for r in done:
        assert len(r.out_tokens) == 6
        assert all(0 <= t < cfg.padded_vocab for t in r.out_tokens)


def test_serving_greedy_is_deterministic():
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.array([1, 2, 3], np.int32)

    def gen():
        eng = ServingEngine(model, batch_slots=2, max_len=32)
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
        return eng.run(params, max_steps=32)[0].out_tokens

    assert gen() == gen()


# ------------------------------------------------------------- straggler
def test_straggler_watchdog_flags_slow_steps():
    from repro.train.loop import StragglerWatchdog

    w = StragglerWatchdog(factor=3.0)
    for i in range(20):
        w.record(i, 0.1)
    w.record(20, 1.0)
    assert w.flagged and w.flagged[0]["step"] == 20
