"""Unit + property tests for the Stripe core: affine math, polyhedra,
frontend lowering, the reference interpreter, and the jnp backend."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Affine,
    aff,
    Constraint,
    Index,
    Polyhedron,
    TileProgram,
    execute_reference,
    lower_program_jnp,
    single_op_program,
    validate_program,
)
from repro.core.validate import affine_map_injective


# ---------------------------------------------------------------- affine
def test_affine_algebra():
    x, y = Affine.var("x"), Affine.var("y")
    e = 2 * x + y - 3
    assert e.eval({"x": 5, "y": 1}) == 8
    assert (e - e).is_const() and (e - e).const == 0
    assert (e * 2).eval({"x": 1, "y": 1}) == 0
    assert e.coef("x") == 2 and e.coef("z") == 0


def test_affine_substitute_tiling():
    # i -> 4*io + ii   (the autotiling index split)
    i = Affine.var("i")
    acc = 3 * i + 7
    sub = acc.substitute({"i": 4 * Affine.var("io") + Affine.var("ii")})
    assert sub.eval({"io": 2, "ii": 1}) == 3 * (4 * 2 + 1) + 7


@given(
    st.dictionaries(st.sampled_from("xyzw"), st.integers(-5, 5), max_size=4),
    st.integers(-10, 10),
    st.dictionaries(st.sampled_from("xyzw"), st.integers(-3, 3), min_size=4, max_size=4),
)
def test_affine_eval_linear(terms, const, env):
    e = Affine.make(terms, const)
    manual = const + sum(c * env[n] for n, c in terms.items())
    assert e.eval(env) == manual


# ------------------------------------------------------------ polyhedron
def test_polyhedron_counts_and_bounds():
    # triangle: 0 <= i < 4, 0 <= j < 4, i + j <= 3
    p = Polyhedron(
        [Index("i", 4), Index("j", 4)],
        [Constraint(aff(3) - Affine.var("i") - Affine.var("j"))],
    )
    assert p.rect_size() == 16
    assert p.count() == 10
    lo, hi = p.expr_bounds(Affine.var("i") + Affine.var("j"))
    assert (lo, hi) == (0, 6)
    assert not p.definitely_empty()
    p2 = Polyhedron([Index("i", 4)], [Constraint(Affine.var("i") - 10)])
    assert p2.definitely_empty()


def test_passthrough_index():
    # child receives x from parent: x = 2 in env
    p = Polyhedron([Index("i", 3), Index("x", 1, affine=aff(2))])
    pts = list(p.points())
    assert all(pt["x"] == 2 for pt in pts) and len(pts) == 3


@given(st.integers(1, 6), st.integers(1, 6), st.integers(-4, 8))
def test_constraint_count_matches_bruteforce(ni, nj, bound):
    p = Polyhedron(
        [Index("i", ni), Index("j", nj)],
        [Constraint(aff(bound) - Affine.var("i") - Affine.var("j"))],
    )
    brute = sum(1 for i in range(ni) for j in range(nj) if i + j <= bound)
    assert p.count() == brute


# ------------------------------------------------------------- injectivity
def test_affine_map_injective():
    x, y = Affine.var("x"), Affine.var("y")
    # (4x + y) with y range 4 -> injective mixed radix
    assert affine_map_injective([4 * x + y], {"x": 8, "y": 4})
    # (2x + y) with y range 4 -> overlapping, not provable
    assert not affine_map_injective([2 * x + y], {"x": 8, "y": 4})
    # x and y to separate dims
    assert affine_map_injective([x, y], {"x": 8, "y": 4})
    # same var feeding two dims is fine for injectivity? we are conservative
    assert affine_map_injective([x + 5, 3 * y], {"x": 8, "y": 4})


# ------------------------------------------------------------- frontend
def _matmul_prog(m=6, k=5, n=4, dtype="float32"):
    return single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((m, k), dtype), "B": ((k, n), dtype), "O": ((m, n), dtype)},
        out="O",
    )


def test_frontend_matmul_structure():
    prog = _matmul_prog()
    assert validate_program(prog) == []
    blk = prog.entry.stmts[0]
    assert sorted(blk.idx_ranges().items()) == [("c", 5), ("i", 6), ("j", 4)]
    assert blk.constraints == []
    out = blk.ref("O_out")
    assert out.agg == "add" and out.shape == (1, 1)


def test_frontend_conv_constraints():
    prog = single_op_program(
        "O[x, k] += I[x + i - 1, c] * F[i, c, k]",
        {"I": ((8, 3), "float32"), "F": ((3, 3, 4), "float32"), "O": ((8, 4), "float32")},
        out="O",
    )
    blk = prog.entry.stmts[0]
    # halo constraints: x+i-1 >= 0 and 7 - (x+i-1) >= 0
    assert len(blk.constraints) == 2
    assert validate_program(prog) == []


def test_frontend_range_inference_errors():
    with pytest.raises(ValueError):
        single_op_program(
            "O[i] += A[i + j]",  # j never appears alone
            {"A": ((8,), "float32"), "O": ((4,), "float32")},
            out="O",
        )


# ------------------------------------------- interpreter vs jnp vs numpy
def test_matmul_interp_and_jnp():
    rng = np.random.RandomState(0)
    a = rng.randn(6, 5).astype(np.float32)
    b = rng.randn(5, 4).astype(np.float32)
    prog = _matmul_prog()
    ref = execute_reference(prog, {"A": a, "B": b})["O"]
    np.testing.assert_allclose(ref, a @ b, rtol=1e-5)
    got = lower_program_jnp(prog)({"A": a, "B": b})["O"]
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-5)


def test_conv2d_with_halo_matches_numpy():
    rng = np.random.RandomState(1)
    H, W, C, K = 6, 5, 3, 4
    i = rng.randn(H, W, C).astype(np.float32)
    f = rng.randn(3, 3, C, K).astype(np.float32)
    prog = single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((H, W, C), "float32"), "F": ((3, 3, C, K), "float32"), "O": ((H, W, K), "float32")},
        out="O",
    )
    assert validate_program(prog) == []
    # numpy oracle: same-padded conv
    pad = np.pad(i, ((1, 1), (1, 1), (0, 0)))
    want = np.zeros((H, W, K), np.float32)
    for x in range(H):
        for y in range(W):
            want[x, y] = np.tensordot(pad[x : x + 3, y : y + 3], f, axes=3)
    ref = execute_reference(prog, {"I": i, "F": f})["O"]
    np.testing.assert_allclose(ref, want, rtol=1e-4, atol=1e-5)
    got = lower_program_jnp(prog)({"I": i, "F": f})["O"]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


def test_strided_access():
    rng = np.random.RandomState(2)
    x = rng.randn(8, 6).astype(np.float32)
    prog = single_op_program(
        "O[i, j] += X[2 * i, j]",
        {"X": ((8, 6), "float32"), "O": ((4, 6), "float32")},
        out="O",
    )
    got = lower_program_jnp(prog)({"X": x})["O"]
    np.testing.assert_allclose(np.asarray(got), x[::2], rtol=1e-6)
    ref = execute_reference(prog, {"X": x})["O"]
    np.testing.assert_allclose(ref, x[::2], rtol=1e-6)


def test_max_pool_aggregation():
    rng = np.random.RandomState(3)
    x = rng.randn(8,).astype(np.float32)
    prog = single_op_program(
        "O[i] max= X[2 * i + w]",
        {"X": ((8,), "float32"), "O": ((4,), "float32")},
        out="O",
        ranges={"w": 2},
    )
    want = x.reshape(4, 2).max(1)
    np.testing.assert_allclose(execute_reference(prog, {"X": x})["O"], want)
    np.testing.assert_allclose(np.asarray(lower_program_jnp(prog)({"X": x})["O"]), want)


def test_elementwise_dag():
    rng = np.random.RandomState(4)
    a = rng.randn(5, 3).astype(np.float32)
    b = rng.randn(3,).astype(np.float32)
    prog = single_op_program(
        "O[i, j] = relu(A[i, j] + B[j]) * 2.0",
        {"A": ((5, 3), "float32"), "B": ((3,), "float32"), "O": ((5, 3), "float32")},
        out="O",
    )
    want = np.maximum(a + b, 0) * 2.0
    np.testing.assert_allclose(execute_reference(prog, {"A": a, "B": b})["O"], want, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(lower_program_jnp(prog)({"A": a, "B": b})["O"]), want, rtol=1e-6)


def test_multi_op_program_temp_chain():
    rng = np.random.RandomState(5)
    a = rng.randn(4, 3).astype(np.float32)
    b = rng.randn(3, 2).astype(np.float32)
    tp = TileProgram("mlp")
    tp.input("A", (4, 3))
    tp.input("B", (3, 2))
    tp.temp("T", (4, 2))
    tp.output("O", (4, 2))
    tp.op("T[i, j] += A[i, c] * B[c, j]")
    tp.op("O[i, j] = relu(T[i, j])")
    prog = tp.build()
    want = np.maximum(a @ b, 0)
    np.testing.assert_allclose(execute_reference(prog, {"A": a, "B": b})["O"], want, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(lower_program_jnp(prog)({"A": a, "B": b})["O"]), want, rtol=1e-5)


def test_int8_conv_like_paper():
    # the paper's Fig 4/5 example is int8 12x16x8 -> 12x16x16 with 3x3 weights
    rng = np.random.RandomState(6)
    i = rng.randint(-4, 4, size=(12, 16, 8)).astype(np.int8)
    f = rng.randint(-2, 2, size=(3, 3, 8, 16)).astype(np.int8)
    prog = single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), "int8"), "F": ((3, 3, 8, 16), "int8"), "O": ((12, 16, 16), "int32")},
        out="O",
    )
    assert validate_program(prog, limit=500000) == []
    ref = execute_reference(prog, {"I": i, "F": f})["O"]
    got = lower_program_jnp(prog)({"I": i, "F": f})["O"]
    np.testing.assert_array_equal(np.asarray(got), ref)


# ------------------------------------------------- hypothesis: contraction
@settings(max_examples=25, deadline=None)
@given(
    st.integers(2, 5), st.integers(2, 5), st.integers(2, 5),
    st.sampled_from(["+=", "max="]),
)
def test_property_contraction_matches_interp(m, k, n, agg):
    rng = np.random.RandomState(m * 100 + k * 10 + n)
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    prog = single_op_program(
        f"O[i, j] {agg} A[i, c] * B[c, j]",
        {"A": ((m, k), "float32"), "B": ((k, n), "float32"), "O": ((m, n), "float32")},
        out="O",
    )
    ref = execute_reference(prog, {"A": a, "B": b})["O"]
    got = np.asarray(lower_program_jnp(prog)({"A": a, "B": b})["O"])
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)


def test_validation_catches_race():
    # two iterations write the same element with assign -> invalid
    prog = single_op_program(
        "O[i] = A[i + j]",
        {"A": ((8,), "float32"), "O": ((4,), "float32")},
        out="O",
        ranges={"j": 2},
    )
    errs = validate_program(prog)
    assert errs and "assign" in errs[0]


def test_pretty_printer_roundtrippable_strings():
    prog = _matmul_prog()
    text = prog.pretty()
    assert "block" in text and "O_out" in text and "add" in text
